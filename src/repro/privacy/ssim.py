"""SSIM (Wang et al. 2004) in pure jnp — the paper's reconstruction metric."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _avg_pool_same(x, win: int):
    """Uniform-window local mean, NHWC, SAME padding.

    SAME windows at the border are zero-padded; dividing their sums by the
    full win² (the seed behavior) deflated border means/variances — every
    border pixel's local statistics shrank toward 0, biasing the SSIM map
    exactly where reconstructions differ most, and with it every
    boundary-leakage score the planner acts on (core/planner.py). Normalize
    by the true in-bounds window mass instead: convolve an all-ones mask
    with the same window and divide by the per-pixel count."""
    c = x.shape[-1]
    k = jnp.tile(jnp.ones((win, win, 1, 1), x.dtype), (1, 1, 1, c))

    def conv(v, kern, groups):
        return jax.lax.conv_general_dilated(
            v, kern, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)

    sums = conv(x, k, c)
    counts = conv(jnp.ones((1,) + x.shape[1:3] + (1,), x.dtype),
                  jnp.ones((win, win, 1, 1), x.dtype), 1)
    return sums / counts


def ssim(x, y, *, win: int = 7, data_range: float = 1.0) -> jax.Array:
    """Mean SSIM over batch. x, y: (B, H, W, C) in [0, data_range]."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    c1 = (0.01 * data_range) ** 2
    c2 = (0.03 * data_range) ** 2
    mx = _avg_pool_same(x, win)
    my = _avg_pool_same(y, win)
    mxx = _avg_pool_same(x * x, win)
    myy = _avg_pool_same(y * y, win)
    mxy = _avg_pool_same(x * y, win)
    vx = mxx - mx * mx
    vy = myy - my * my
    cxy = mxy - mx * my
    s = ((2 * mx * my + c1) * (2 * cxy + c2)
         / ((mx * mx + my * my + c1) * (vx + vy + c2)))
    return jnp.mean(s)


def ssim_per_image(x, y, *, win: int = 7, data_range: float = 1.0):
    return jax.vmap(lambda a, b: ssim(a[None], b[None], win=win,
                                      data_range=data_range))(x, y)
