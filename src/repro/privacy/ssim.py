"""SSIM (Wang et al. 2004) in pure jnp — the paper's reconstruction metric."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _avg_pool_same(x, win: int):
    """Uniform-window local mean, NHWC, SAME padding."""
    k = jnp.ones((win, win, 1, 1), x.dtype) / (win * win)
    c = x.shape[-1]
    k = jnp.tile(k, (1, 1, 1, c))
    return jax.lax.conv_general_dilated(
        x, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c)


def ssim(x, y, *, win: int = 7, data_range: float = 1.0) -> jax.Array:
    """Mean SSIM over batch. x, y: (B, H, W, C) in [0, data_range]."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    c1 = (0.01 * data_range) ** 2
    c2 = (0.03 * data_range) ** 2
    mx = _avg_pool_same(x, win)
    my = _avg_pool_same(y, win)
    mxx = _avg_pool_same(x * x, win)
    myy = _avg_pool_same(y * y, win)
    mxy = _avg_pool_same(x * y, win)
    vx = mxx - mx * mx
    vy = myy - my * my
    cxy = mxy - mx * my
    s = ((2 * mx * my + c1) * (2 * cxy + c2)
         / ((mx * mx + my * my + c1) * (vx + vy + c2)))
    return jnp.mean(s)


def ssim_per_image(x, y, *, win: int = 7, data_range: float = 1.0):
    return jax.vmap(lambda a, b: ssim(a[None], b[None], win=win,
                                      data_range=data_range))(x, y)
