"""Synthetic procedural image dataset for the c-GAN privacy evaluation.

ImageNet is not available offline; reconstruction-learnability only needs a
*structured, diverse* distribution, so we generate colored geometric scenes
(gradient background + rectangles + circles + stripes) deterministically
from an index. SSIM trends across partition layers are what the paper's
Fig. 7/8 measure, and these transfer: early conv features retain the scene
geometry, deep/pooled features do not.
"""
from __future__ import annotations

import numpy as np


def make_image(idx: int, size: int = 32) -> np.ndarray:
    rng = np.random.default_rng(1_000_003 * idx + 17)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    img = np.zeros((size, size, 3), np.float32)
    # gradient background
    c0, c1 = rng.random(3), rng.random(3)
    ang = rng.random() * 2 * np.pi
    t = (np.cos(ang) * xx + np.sin(ang) * yy)
    t = (t - t.min()) / (np.ptp(t) + 1e-9)
    img += c0 * (1 - t[..., None]) + c1 * t[..., None]
    # rectangles
    for _ in range(rng.integers(1, 4)):
        x0, y0 = rng.integers(0, size - 4, 2)
        w, h = rng.integers(3, size // 2, 2)
        img[y0:y0 + h, x0:x0 + w] = rng.random(3)
    # circle
    for _ in range(rng.integers(1, 3)):
        cx, cy = rng.random(2) * size
        r = rng.random() * size / 3 + 2
        mask = (xx * size - cx) ** 2 + (yy * size - cy) ** 2 < r ** 2
        img[mask] = rng.random(3)
    # stripes
    if rng.random() < 0.5:
        period = rng.integers(2, 6)
        phase = rng.integers(0, period)
        stripe = ((np.arange(size) + phase) // period) % 2 == 0
        img[:, stripe] = 0.7 * img[:, stripe] + 0.3 * rng.random(3)
    return np.clip(img, 0.0, 1.0)


def make_batch(start: int, n: int, size: int = 32) -> np.ndarray:
    return np.stack([make_image(start + i, size) for i in range(n)])


def dataset(n: int, size: int = 32, seed_offset: int = 0) -> np.ndarray:
    return make_batch(seed_offset, n, size)
