"""Adversary training loop + the paper's partition-search (Algorithm 1).

``train_adversary`` trains the c-GAN on (Θ(X), X) pairs collected from a
partition layer; ``partition_search`` walks the layers exactly as
Algorithm 1: find the first layer p whose SSIM is below threshold, then
verify p+1 and p+2 (the paper's non-monotonicity guard — max-pool outputs
can be safe while the *next conv* is reconstructable again).

``token_recovery_probe`` is the LM-family analogue (beyond-paper,
DESIGN.md §5): a linear probe recovering input token identity from
boundary hidden states; recovery accuracy plays the role of SSIM.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import layers as L
from repro.models import vgg as V
from repro.optim import adamw
from repro.privacy import cgan
from repro.privacy.data import make_batch
from repro.privacy.ssim import ssim


@dataclasses.dataclass
class AdversaryReport:
    layer: int
    ssim: float
    g_loss: float
    d_loss: float
    steps: int


def collect_features(params, images, cfg: ModelConfig, layer: int):
    """Θ(X): feature maps after ``layer`` (1-based, paper numbering).

    Features are standardized per-batch — a free transformation available
    to any adversary, needed because raw feature scales vary by orders of
    magnitude across depths.
    """
    _, feat = V.vgg_forward(params, images, cfg, capture=layer)
    if feat.ndim == 2:                      # fc features -> (B,1,1,d)
        feat = feat[:, None, None, :]
    feat = feat.astype(jnp.float32)
    mu = jnp.mean(feat)
    sd = jnp.std(feat) + 1e-6
    return (feat - mu) / sd


def train_adversary(model_params, cfg: ModelConfig, layer: int, *,
                    steps: int = 200, batch: int = 16, n_eval: int = 64,
                    lr: float = 2e-4, seed: int = 0,
                    log_every: int = 0) -> AdversaryReport:
    img_size = cfg.image_size
    probe = collect_features(
        model_params, jnp.asarray(make_batch(0, 2, img_size)), cfg, layer)
    feat_hw, feat_c = probe.shape[1], probe.shape[-1]

    g_defs, meta_g = cgan.generator_defs(feat_hw, feat_c, img_size)
    d_defs, meta_d = cgan.discriminator_defs(feat_hw, feat_c, img_size)
    key = jax.random.PRNGKey(seed)
    kg, kd = jax.random.split(key)
    gp = L.init_params(kg, g_defs, jnp.float32)
    dp = L.init_params(kd, d_defs, jnp.float32)
    tcfg = TrainConfig(learning_rate=lr, warmup_steps=0, total_steps=steps,
                       weight_decay=0.0, grad_clip=1.0, b1=0.5, b2=0.999)
    g_opt = adamw.init(gp, tcfg)
    d_opt = adamw.init(dp, tcfg)

    @jax.jit
    def step_fn(gp, dp, g_opt, d_opt, feat, real):
        dl, dgrad = jax.value_and_grad(
            lambda d_: cgan.d_loss_fn(d_, gp, feat, real, meta_g, meta_d)
        )(dp)
        dp2, d_opt2, _ = adamw.update(dgrad, d_opt, dp, tcfg,
                                      jnp.float32(lr))
        (gl, _), ggrad = jax.value_and_grad(
            lambda g_: cgan.g_loss_fn(g_, dp2, feat, real, meta_g, meta_d),
            has_aux=True)(gp)
        gp2, g_opt2, _ = adamw.update(ggrad, g_opt, gp, tcfg,
                                      jnp.float32(lr))
        return gp2, dp2, g_opt2, d_opt2, gl, dl

    gl = dl = jnp.float32(0)
    for it in range(steps):
        real = jnp.asarray(make_batch(100 + it * batch, batch, img_size))
        feat = collect_features(model_params, real, cfg, layer)
        gp, dp, g_opt, d_opt, gl, dl = step_fn(gp, dp, g_opt, d_opt,
                                               feat, real)
        if log_every and (it + 1) % log_every == 0:
            print(f"  layer {layer} step {it+1}: g={float(gl):.3f} "
                  f"d={float(dl):.3f}")

    # eval on held-out images
    real = jnp.asarray(make_batch(10_000_000, n_eval, img_size))
    feat = collect_features(model_params, real, cfg, layer)
    fake = cgan.generator_apply(gp, feat, meta_g)
    s = float(ssim(fake, real))
    return AdversaryReport(layer=layer, ssim=s, g_loss=float(gl),
                           d_loss=float(dl), steps=steps)


def partition_search(model_params, cfg: ModelConfig, *,
                     threshold: float = 0.35, steps: int = 150,
                     verify_depth: int = 2, max_layer: Optional[int] = None,
                     **kw) -> Tuple[int, List[AdversaryReport]]:
    """Algorithm 1. Returns (partition layer p, all reports)."""
    n = max_layer or len(cfg.cnn_layers) - 1
    reports: List[AdversaryReport] = []
    cache: Dict[int, AdversaryReport] = {}

    def eval_layer(l: int) -> AdversaryReport:
        if l not in cache:
            cache[l] = train_adversary(model_params, cfg, l, steps=steps,
                                       **kw)
            reports.append(cache[l])
        return cache[l]

    l = 1
    while l <= n:
        rep = eval_layer(l)
        if rep.ssim < threshold:
            # verify the next layers (non-monotone reconstructability)
            deeper = [eval_layer(m) for m in range(l + 1,
                                                   min(l + 1 + verify_depth,
                                                       n + 1))]
            if all(r.ssim < threshold for r in deeper):
                return l, reports
            # a deeper layer is reconstructable again: restart past it
            l = max(r.layer for r in deeper if r.ssim >= threshold) + 1
        else:
            l += 1
    return n, reports


# ----------------------------------------------------------------------------
# LM-family analogue: token-identity recovery probe
# ----------------------------------------------------------------------------

def token_recovery_probe(boundary_fn: Callable[[jax.Array], jax.Array],
                         vocab: int, d_model: int, *, steps: int = 100,
                         batch: int = 8, seq: int = 32, lr: float = 1e-2,
                         seed: int = 0) -> float:
    """Train a linear probe hidden->token-id; returns top-1 recovery acc.

    boundary_fn(tokens) must return the tier-1 boundary hidden states
    (what an adversary observes when tier-2 runs in the open).
    """
    key = jax.random.PRNGKey(seed)
    w = jnp.zeros((d_model, vocab), jnp.float32)

    @jax.jit
    def step_fn(w, tokens, hidden):
        def loss(w_):
            logits = hidden.astype(jnp.float32) @ w_
            return L.cross_entropy(logits, tokens, vocab)
        l, g = jax.value_and_grad(loss)(w)
        return w - lr * g, l

    for it in range(steps):
        key, k = jax.random.split(key)
        tokens = jax.random.randint(k, (batch, seq), 0, vocab)
        hidden = boundary_fn(tokens)
        w, _ = step_fn(w, tokens, hidden)

    key, k = jax.random.split(key)
    tokens = jax.random.randint(k, (batch * 4, seq), 0, vocab)
    hidden = boundary_fn(tokens)
    pred = jnp.argmax(hidden.astype(jnp.float32) @ w, axis=-1)
    return float(jnp.mean(pred == tokens))
