"""Conditional GAN adversary (paper §IV/§V): reconstruct X from Θ(X).

Generator: encoder convs -> residual blocks -> nearest-upsample decoder
(paper Fig. 6, scaled to the synthetic 32x32 dataset). Discriminator:
downsampling convs on the image, condition feature map concatenated at
matching spatial resolution, convs -> dense -> logit (paper §V-A).

Training uses the non-saturating GAN loss plus a λ·L1 reconstruction term
(pix2pix-style). The L1 term only *strengthens* the adversary — any
learnable reconstruction channel counts against privacy — so SSIM numbers
remain a conservative privacy bound.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ----------------------------------------------------------------------------
# param defs
# ----------------------------------------------------------------------------

def _conv(cin, cout, k=3):
    return L.conv_def(cin, cout, k)


def generator_defs(feat_hw: int, feat_c: int, img_size: int = 32,
                   width: int = 32):
    """feat_hw: spatial size of the condition feature map Θ(X)."""
    n_down = max(0, int(math.log2(max(feat_hw // 4, 1))))
    n_up = int(math.log2(img_size / (feat_hw / (2 ** n_down))))
    d: Dict[str, object] = {"in": _conv(feat_c, width)}
    c = width
    for i in range(n_down):
        d[f"down{i}"] = _conv(c, min(2 * c, 128))
        c = min(2 * c, 128)
    for i in range(2):
        d[f"res{i}a"] = _conv(c, c)
        d[f"res{i}b"] = _conv(c, c)
    for i in range(n_up):
        nc = max(c // 2, width)
        d[f"up{i}"] = _conv(c, nc)
        c = nc
    d["out"] = _conv(c, 3)
    return d, (n_down, n_up)


def generator_apply(p, feat, shape_meta: Tuple[int, int]) -> jax.Array:
    n_down, n_up = shape_meta
    x = jax.nn.relu(L.conv2d(p["in"], feat.astype(jnp.float32)))
    for i in range(n_down):
        x = jax.nn.relu(L.conv2d(p[f"down{i}"], x, stride=2))
    for i in range(2):
        h = jax.nn.relu(L.conv2d(p[f"res{i}a"], x))
        x = x + L.conv2d(p[f"res{i}b"], h)
    for i in range(n_up):
        B, H, W, C = x.shape
        x = jax.image.resize(x, (B, 2 * H, 2 * W, C), "nearest")
        x = jax.nn.relu(L.conv2d(p[f"up{i}"], x))
    return jax.nn.sigmoid(L.conv2d(p["out"], x))


def discriminator_defs(feat_hw: int, feat_c: int, img_size: int = 32,
                       width: int = 32):
    n_down = int(math.log2(img_size / feat_hw)) if feat_hw < img_size else 0
    d: Dict[str, object] = {"in": _conv(3, width, k=4)}
    c = width
    for i in range(n_down):
        d[f"down{i}"] = _conv(c, min(2 * c, 128), k=4)
        c = min(2 * c, 128)
    d["merge"] = _conv(c + feat_c, 128, k=4)
    d["conv2"] = _conv(128, 128, k=4)
    d["head"] = L.dense_def(128, 1, ("embed", None), bias=True)
    return d, n_down


def discriminator_apply(p, img, feat, n_down: int) -> jax.Array:
    x = jax.nn.leaky_relu(L.conv2d(p["in"], img.astype(jnp.float32)), 0.2)
    for i in range(n_down):
        x = jax.nn.leaky_relu(L.conv2d(p[f"down{i}"], x, stride=2), 0.2)
    if feat.shape[1] != x.shape[1]:     # align spatial dims if off by 2^k
        feat = jax.image.resize(
            feat, (feat.shape[0], x.shape[1], x.shape[2], feat.shape[-1]),
            "nearest")
    x = jnp.concatenate([x, feat.astype(jnp.float32)], axis=-1)
    x = jax.nn.leaky_relu(L.conv2d(p["merge"], x), 0.2)
    x = jax.nn.leaky_relu(L.conv2d(p["conv2"], x, stride=2), 0.2)
    x = jnp.mean(x, axis=(1, 2))
    return L.dense(p["head"], x)[:, 0]


# ----------------------------------------------------------------------------
# losses
# ----------------------------------------------------------------------------

def bce_logits(logit, target):
    return jnp.mean(jnp.maximum(logit, 0) - logit * target
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def g_loss_fn(gp, dp, feat, real, meta_g, meta_d, l1_weight: float = 50.0):
    fake = generator_apply(gp, feat, meta_g)
    adv = bce_logits(discriminator_apply(dp, fake, feat, meta_d), 1.0)
    l1 = jnp.mean(jnp.abs(fake - real))
    return adv + l1_weight * l1, fake


def d_loss_fn(dp, gp, feat, real, meta_g, meta_d):
    fake = jax.lax.stop_gradient(generator_apply(gp, feat, meta_g))
    lr_ = bce_logits(discriminator_apply(dp, real, feat, meta_d), 1.0)
    lf = bce_logits(discriminator_apply(dp, fake, feat, meta_d), 0.0)
    return lr_ + lf
