"""Private-inference serving loop (the paper's deployment shape).

Flow per Fig. 3a: client attests the enclave (core/attestation), seals its
input under the session key (core/sealing), the enclave unseals inside the
trust boundary, the OrigamiExecutor runs tier-1 blinded + tier-2 open, and
the result is sealed back to the client. Requests are micro-batched with
padding; the watchdog (runtime/straggler) monitors per-batch latency.

``PrivateInferenceServer`` is the synchronous single-model front end.
``serve_batch`` is the one-enclave-dispatch primitive (unseal -> filter
failed MACs -> pad -> blinded infer -> seal); ``serve`` is now a thin
compat wrapper over the async ``ServingEngine`` (runtime/engine.py), which
adds continuous micro-batching, deadlines, admission control and an N-deep
blinding-session pool (runtime/sessions.py) on top of the same primitive.

Nonce discipline: requests seal under the 64-bit rid split
``[lo, hi]``; responses under ``[lo, hi, DIRECTION_RESPONSE]`` — same
split, third word tags the direction, so no (key, nonce) pair is ever
reused between the two directions or between rids differing only in high
bits (the seed truncated the response nonce to 32 rid bits).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import tracing
from repro.core.attestation import Quote, measure_enclave, verify_quote
from repro.core.origami import OrigamiExecutor
from repro.core.sealing import SealedBox, seal, unseal
from repro.runtime.aot import bucket_for
from repro.runtime.straggler import StepWatchdog

# third nonce word for enclave->client traffic (requests use 2-word nonces;
# sealing._keystream folds nonce words sequentially, so the domains differ)
DIRECTION_RESPONSE = 0xEE

# fold_in tag deriving a fresh blinding session for an integrity retry /
# enclave recompute when the caller supplied a fixed key instead of a pool
# (a re-run must never reuse the failed attempt's one-time pads)
_RETRY_DOMAIN = 0x0E7B1


def request_nonce(rid: int) -> jax.Array:
    return jnp.asarray([rid & 0xFFFFFFFF, (rid >> 32) & 0xFFFFFFFF],
                       jnp.uint32)


def response_nonce(rid: int) -> jax.Array:
    """Full 64-bit rid split + direction tag (not the seed's 32-bit
    truncation, which reused a (key, nonce) pair across rids that differed
    only in their high 32 bits)."""
    return jnp.asarray([rid & 0xFFFFFFFF, (rid >> 32) & 0xFFFFFFFF,
                        DIRECTION_RESPONSE], jnp.uint32)


@dataclasses.dataclass
class Request:
    rid: int
    box: SealedBox
    shape: Tuple[int, ...]
    session_key: np.ndarray          # client's symmetric key material


@dataclasses.dataclass
class Response:
    rid: int
    box: Optional[SealedBox]
    ok: bool
    latency_s: float
    # integrity mark (DESIGN.md §9): True when a Freivalds check failed on
    # this request's batch and the logits were recovered (device retry or
    # enclave recompute) before sealing — served correctly, but the client
    # / operator can see the device misbehaved.
    flagged: bool = False
    # machine-readable failure cause when ok=False (DESIGN.md §12):
    # "mac_failed" (request never reached the executor),
    # "deadline_exceeded" (expired at batch formation or dispatch),
    # "shutdown" (engine closed with this request still queued),
    # "rejected" (admission control: queue full, unknown model, or a
    # duplicate in-flight rid). None on every ok=True response.
    error: Optional[str] = None


@dataclasses.dataclass
class BatchIntegrity:
    """Verification outcome of one sealed-batch dispatch (all requests in
    a batch share the blinded trace, so detection and recovery are
    batch-granular)."""
    checks: int = 0              # Freivalds checks that ran (all attempts)
    failures: int = 0            # checks that mismatched
    corrupted: int = 0           # injector ground truth (tests/benchmarks)
    retried: bool = False        # one fresh-session device retry happened
    recomputed: bool = False     # enclave recompute produced the response
    trusted: bool = False        # dispatched straight to the enclave
                                 # (quarantined backend — no checks to run)
    # multi-device plane counters (parallel/offload_sharding.py): shard
    # failures are detected AND recovered inside the op (single-shard
    # retry on another device), so they never trigger the batch-level
    # retry/recompute path — but they still flag the response.
    shard_checks: int = 0        # shard-local Freivalds checks run
    shard_failures: int = 0      # shard checks that mismatched
    shard_retries: int = 0       # single-shard re-dispatches
    shard_hedges: int = 0        # straggler duplicates launched
    shard_enclave: int = 0       # shards the enclave computed itself
    # liveness ladder (DESIGN.md §12): contained inside the op like shard
    # integrity failures — recovered before the batch ever sees them
    shard_crashes: int = 0       # dispatches that raised (contained)
    shard_timeouts: int = 0      # dispatches abandoned past the deadline

    @property
    def flagged(self) -> bool:
        return self.failures > 0 or self.shard_failures > 0


@dataclasses.dataclass
class IntegrityTotals:
    """Running sums over many dispatches (per-batch bools become counts —
    a sticky ``or`` would report 'recomputed=True' whether 1 or 50 batches
    needed the enclave)."""
    checks: int = 0
    failures: int = 0
    corrupted: int = 0
    retries: int = 0
    recomputes: int = 0
    trusted_batches: int = 0
    shard_checks: int = 0
    shard_failures: int = 0
    shard_retries: int = 0
    shard_hedges: int = 0
    shard_enclave: int = 0
    shard_crashes: int = 0
    shard_timeouts: int = 0

    def add(self, integ: BatchIntegrity) -> None:
        self.checks += integ.checks
        self.failures += integ.failures
        self.corrupted += integ.corrupted
        self.retries += integ.retried
        self.recomputes += integ.recomputed
        self.trusted_batches += integ.trusted
        self.shard_checks += integ.shard_checks
        self.shard_failures += integ.shard_failures
        self.shard_retries += integ.shard_retries
        self.shard_hedges += integ.shard_hedges
        self.shard_enclave += integ.shard_enclave
        self.shard_crashes += integ.shard_crashes
        self.shard_timeouts += integ.shard_timeouts


def _fresh_session(session_key, used: jax.Array) -> jax.Array:
    """A never-used blinding session for a device retry: next pool key
    when the caller gave us a pool, else a tagged derivation of the used
    key (one-time pads must not repeat across attempts)."""
    if callable(session_key):
        return session_key()
    return jax.random.fold_in(used, _RETRY_DOMAIN)


def _trusted_key() -> jax.Array:
    """The enclave-recompute trace draws no blinding streams, no verify
    keys and no fault keys — its session key is semantically unused, so a
    constant keeps trusted dispatches from burning pool sessions."""
    return jax.random.PRNGKey(0)


@dataclasses.dataclass
class PreparedBatch:
    """Product of the enclave stage of one sealed-batch dispatch: requests
    unsealed, failed MACs filtered, survivors stacked and zero-padded to a
    shape bucket. Everything after this (infer/verify/recovery/seal) is the
    device stage — the two-stage serving pipeline (runtime/engine.py)
    overlaps batch N+1's prepare with batch N's completion across threads,
    handing exactly this object between them."""
    requests: List[Request]
    boxes: List[Optional[SealedBox]]     # positional; None = MAC failed
    valid_idx: List[int]
    x: Optional[jax.Array]               # bucket-padded input, None if empty
    pad: int                             # zero rows added (bucket - n_valid)
    bucket: int                          # padded batch dim (0 if empty)
    integ: BatchIntegrity

    @property
    def n_valid(self) -> int:
        return len(self.valid_idx)


def prepare_sealed_batch(requests: List[Request], *, max_batch: int,
                         input_dtype: Optional[str] = None) -> PreparedBatch:
    """Enclave stage: unseal -> filter failed MACs -> bucket-pad.

    Padding goes to the smallest power-of-two shape bucket that holds the
    survivors (``aot.bucket_for``), not straight to ``max_batch``: a lone
    request in a quiet period pads to 1 row of work, not 8. The bucket is
    a pure function of the valid count, so any two paths fed the same
    request list pick the same bucket — and hence the same compiled
    executable, which is what keeps the engine bit-identical to the legacy
    oracle (XLA may legally pick different float kernels at different
    batch shapes). Zero pad rows are exact no-ops for the blinded trace:
    they never raise the activation absmax, so the quantization scale —
    and therefore every data row's logits — is untouched.
    """
    valid_idx: List[int] = []
    inputs: List[np.ndarray] = []
    with tracing.maybe_span("unseal", "crypto",
                            n_requests=len(requests)) as usp:
        for i, r in enumerate(requests):
            pt, ok = unseal(jnp.asarray(r.session_key, jnp.uint32), r.box,
                            r.shape)
            if ok:
                valid_idx.append(i)
                inputs.append(np.asarray(pt))
        tracing.annotate(usp, n_valid=len(inputs))
    boxes: List[Optional[SealedBox]] = [None] * len(requests)
    integ = BatchIntegrity()
    if not inputs:
        return PreparedBatch(requests, boxes, valid_idx, None, 0, 0, integ)
    bucket = bucket_for(len(inputs), max_batch)
    pad = bucket - len(inputs)
    x = jnp.asarray(np.stack(inputs + [np.zeros_like(inputs[0])] * pad))
    if input_dtype is not None:          # LM tokens ride as f32 payloads
        x = x.astype(input_dtype)
    return PreparedBatch(requests, boxes, valid_idx, x, pad, bucket, integ)


def execute_sealed_batch(executor: OrigamiExecutor, requests: List[Request],
                         *, input_key: str, max_batch: int,
                         session_key, input_dtype: Optional[str] = None,
                         trusted: bool = False, retry_device: bool = True
                         ) -> Tuple[List[Optional[SealedBox]], int, int,
                                    BatchIntegrity]:
    """The one sealed-batch primitive both serving paths share:
    unseal -> filter failed MACs -> bucket-pad -> blinded infer
    (Freivalds-verified per the executor's policy) -> recover on failure ->
    seal responses. Composition of ``prepare_sealed_batch`` (enclave
    stage) and ``complete_prepared_batch`` (device stage) — the pipelined
    engine calls the two halves on different threads, so this composition
    IS the single-threaded legacy oracle it is cross-checked against.

    Returns ``(boxes, n_valid, pad, integrity)`` with ``boxes`` positional —
    ``boxes[i] is None`` iff request i failed its MAC (it never reached
    the executor: no inference slot, no blinding, no telemetry skew).
    ``session_key`` may be a zero-arg callable (e.g. ``SessionPool.
    acquire``), only invoked once at least one valid request will reach
    the executor — an all-invalid batch must not burn a blinding session.

    Integrity flow (DESIGN.md §9): a failed check discards the device's
    answer; ``retry_device`` grants one re-offload under a fresh blinding
    session (a transient fault clears, a persistent adversary fails
    again), after which the enclave recomputes the batch itself —
    ``trusted=True`` (engine quarantine) skips the device entirely. The
    blinded result is session-independent, so every recovery path is
    bit-identical to an honest device's response.
    """
    prep = prepare_sealed_batch(requests, max_batch=max_batch,
                                input_dtype=input_dtype)
    if prep.x is None:
        return prep.boxes, 0, 0, prep.integ
    return complete_prepared_batch(executor, prep, input_key=input_key,
                                   session_key=session_key, trusted=trusted,
                                   retry_device=retry_device)


def complete_prepared_batch(executor: OrigamiExecutor, prep: PreparedBatch,
                            *, input_key: str, session_key,
                            trusted: bool = False, retry_device: bool = True
                            ) -> Tuple[List[Optional[SealedBox]], int, int,
                                       BatchIntegrity]:
    """Device stage: blinded infer -> verify -> §9 recovery ladder -> seal.

    ``prep.x`` must be non-None (the caller short-circuits empty batches).
    A batch that fails its Freivalds check drains through the full
    detect -> retry -> recompute ladder *inside this stage*, on whichever
    thread runs it — the pipeline never reorders or splits a batch's
    recovery."""
    requests, boxes, integ = prep.requests, prep.boxes, prep.integ
    valid_idx, pad = prep.valid_idx, prep.pad
    n_valid = prep.n_valid
    batch = {input_key: prep.x}
    if trusted:
        # the trusted trace neither blinds nor verifies, so it consumes no
        # session material — do NOT pop a pool key (its prefetched factor
        # set would be generated and never taken)
        integ.trusted = True
        with tracing.maybe_span("infer", "infer", attempt="trusted",
                                trusted=True):
            result = executor.infer(batch, session_key=_trusted_key(),
                                    trusted=True)
    else:
        def absorb_shards(res) -> None:
            if res.sharding is None:
                return
            integ.shard_checks += res.sharding.checks
            integ.shard_failures += res.sharding.failures
            integ.shard_retries += res.sharding.retries
            integ.shard_hedges += res.sharding.hedges
            integ.shard_enclave += res.sharding.enclave_shards
            integ.shard_crashes += res.sharding.crashes
            integ.shard_timeouts += res.sharding.timeouts

        with tracing.maybe_span("session.acquire", "session",
                                pooled=callable(session_key)):
            sk = session_key() if callable(session_key) else session_key
        with tracing.maybe_span("infer", "infer", attempt="blinded") as isp:
            result = executor.infer(batch, session_key=sk)
            tracing.annotate(isp, checks=result.integrity.n_checked,
                             failures=result.integrity.n_failed)
        integ.checks = result.integrity.n_checked
        integ.failures = result.integrity.n_failed
        integ.corrupted = result.integrity.n_corrupted
        absorb_shards(result)
        if not result.integrity.ok and retry_device:
            with tracing.maybe_span("session.acquire", "session",
                                    pooled=callable(session_key),
                                    retry=True):
                sk = _fresh_session(session_key, sk)
            with tracing.maybe_span("infer", "infer",
                                    attempt="retry") as isp:
                result = executor.infer(batch, session_key=sk)
                tracing.annotate(isp, checks=result.integrity.n_checked,
                                 failures=result.integrity.n_failed)
            integ.retried = True
            integ.checks += result.integrity.n_checked
            integ.failures += result.integrity.n_failed
            integ.corrupted += result.integrity.n_corrupted
            absorb_shards(result)
        if not result.integrity.ok:
            with tracing.maybe_span("infer", "infer", attempt="recompute",
                                    trusted=True):
                result = executor.infer(batch, session_key=_trusted_key(),
                                        trusted=True)
            integ.recomputed = True
        # summary of the batch's verification outcome, one span so the
        # tree reads queue -> batch -> ... -> verify -> seal even though
        # the checks themselves ran inside the infer attempts
        with tracing.maybe_span("verify", "verify", checks=integ.checks,
                                failures=integ.failures,
                                shard_checks=integ.shard_checks,
                                shard_failures=integ.shard_failures,
                                retried=integ.retried,
                                recomputed=integ.recomputed):
            pass
    with tracing.maybe_span("seal", "crypto", n_responses=len(valid_idx),
                            pad=pad):
        logits = np.asarray(result.logits, np.float32)[:n_valid]
        for row, i in enumerate(valid_idx):
            r = requests[i]
            boxes[i] = seal(jnp.asarray(r.session_key, jnp.uint32),
                            jnp.asarray(logits[row]), response_nonce(r.rid))
    return boxes, n_valid, pad, integ


class PrivateInferenceServer:
    """Batched Origami serving over a model (CNN or LM single-shot)."""

    def __init__(self, cfg: ModelConfig, params, *, mode: str = "origami",
                 max_batch: int = 8, input_key: str = "images",
                 impl: str = "fused", precompute: bool = True,
                 integrity=None, fault=None, plan=None):
        """``plan``: an explicit core/plan.PlacementPlan; when omitted the
        legacy ``mode`` kwarg compiles one (OrigamiExecutor compat)."""
        self.cfg = cfg
        self.executor = OrigamiExecutor(cfg, params, mode=mode, impl=impl,
                                        precompute=precompute,
                                        integrity=integrity, fault=fault,
                                        plan=plan)
        self.quote = measure_enclave(cfg, params, self.executor.partition,
                                     plan_digest=self.executor.plan.digest)
        self.max_batch = max_batch
        self.input_key = input_key
        self.watchdog = StepWatchdog()
        self.processed = 0
        self.batches = 0
        self.integrity_totals = IntegrityTotals()  # running serve_batch sums
        self._engine = None              # lazy ServingEngine (serve())
        # server-side root for per-batch blinding sessions (distinct from the
        # clients' sealing keys): batch k runs under fold_in(root, k). MUST
        # be fresh entropy per instance — a fixed or colliding root would
        # reuse one-time pads across server restarts/replicas, letting the
        # device subtract two blinded tensors and cancel r. 64 entropy bits
        # via two 32-bit words (PRNGKey seeds are limited to C-long range).
        w0, w1 = np.frombuffer(os.urandom(8), np.uint32)
        self._blind_root = jax.random.fold_in(jax.random.PRNGKey(int(w0)),
                                              int(w1))

    def _blind_session(self, batch_idx: int) -> jax.Array:
        return jax.random.fold_in(self._blind_root, batch_idx)

    # -- client side helpers ---------------------------------------------
    def attest(self) -> Quote:
        return self.quote

    @staticmethod
    def client_seal(key: np.ndarray, x: np.ndarray, rid: int) -> SealedBox:
        return seal(jnp.asarray(key, jnp.uint32), jnp.asarray(x),
                    request_nonce(rid))

    @staticmethod
    def client_open(key: np.ndarray, box: SealedBox,
                    shape: Tuple[int, ...]) -> np.ndarray:
        pt, ok = unseal(jnp.asarray(key, jnp.uint32), box, shape)
        assert bool(ok), "response MAC failed"
        return np.asarray(pt)

    # -- server side -------------------------------------------------------
    def serve_batch(self, requests: List[Request]) -> List[Response]:
        """One enclave dispatch. Callers own batching: more than
        ``max_batch`` requests is an error (the seed silently dropped the
        tail) — use ``serve`` for arbitrary request lists."""
        if len(requests) > self.max_batch:
            raise ValueError(
                f"serve_batch got {len(requests)} requests for max_batch="
                f"{self.max_batch}; use serve() to micro-batch")
        self.watchdog.start_step()
        t0 = time.monotonic()
        boxes, n_valid, _, integ = execute_sealed_batch(
            self.executor, requests, input_key=self.input_key,
            max_batch=self.max_batch,
            session_key=self._blind_session(self.batches))
        self.integrity_totals.add(integ)
        if n_valid:
            self.batches += 1
            # double-buffer: enqueue the NEXT session's unblinding factors
            # now, so their field matmuls overlap this batch's device
            # compute (the engine's SessionPool deepens this to N)
            self.executor.prepare_session(self._blind_session(self.batches))
            self.processed += n_valid
        self.watchdog.end_step()
        dt = time.monotonic() - t0
        # positional assembly (not keyed by rid — rids may repeat)
        return [Response(r.rid, box, box is not None, dt,
                         flagged=integ.flagged and box is not None,
                         error=None if box is not None else "mac_failed")
                for r, box in zip(requests, boxes)]

    def serve(self, requests: List[Request]) -> List[Response]:
        """Compat wrapper: drives the async ServingEngine and returns
        responses in request order (the engine completes out of order).

        Legacy contract: every request gets a real answer. The engine
        rejects a rid that is already in flight, so duplicate rids are
        submitted in waves — each wave waits for the previous occurrence
        of its rid to finish.
        """
        responses: List[Optional[Response]] = [None] * len(requests)
        waves: List[List[int]] = []
        depth: dict = {}
        for i, r in enumerate(requests):
            d = depth.get(r.rid, 0)
            depth[r.rid] = d + 1
            while len(waves) <= d:
                waves.append([])
            waves[d].append(i)
        for wave in waves:
            futures = [(i, self.engine.submit("default", requests[i]))
                       for i in wave]
            # the list is complete — don't let a partial tail batch idle
            # out the max_wait timer
            self.engine.flush()
            for i, f in futures:
                responses[i] = f.result(timeout=300.0)
        return responses

    @property
    def engine(self):
        """Lazily-built single-model ServingEngine sharing this server's
        executor (so serve() and serve_batch() hit the same caches).

        ``max_queue`` is effectively unbounded: serve() is synchronous, so
        admission control would silently shed the tail of a long request
        list the legacy loop used to serve."""
        if self._engine is None:
            from repro.runtime.engine import EngineConfig, ServingEngine
            self._engine = ServingEngine(EngineConfig(
                max_batch=self.max_batch, max_wait_ms=25.0,
                max_queue=1_000_000_000))
            self._engine.register_executor("default", self.executor,
                                           input_key=self.input_key)
        return self._engine

    def close(self) -> None:
        """Stop the compat engine's batcher + session-pool threads (they
        are daemons, but long-lived processes creating many servers should
        release them and their prefetched factor sets deterministically)."""
        if self._engine is not None:
            self._engine.close()
            self._engine = None
