"""Private-inference serving loop (the paper's deployment shape).

Flow per Fig. 3a: client attests the enclave (core/attestation), seals its
input under the session key (core/sealing), the enclave unseals inside the
trust boundary, the OrigamiExecutor runs tier-1 blinded + tier-2 open, and
the result is sealed back to the client. Requests are micro-batched with
padding; the watchdog (runtime/straggler) monitors per-batch latency.

Blinding precompute (DESIGN.md §4): each micro-batch runs under its own
blinding session key. With ``precompute=True`` (default) the executor's
``BlindedLayerCache`` quantizes tier-1 weights once at first dispatch, and
the server double-buffers unblinding factors — after dispatching batch k it
immediately enqueues factor generation for batch k+1's session, so the
``r @ W_q`` matmuls overlap device compute instead of sitting on the
request path (the paper's offline enclave precomputation).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.attestation import Quote, measure_enclave, verify_quote
from repro.core.origami import OrigamiExecutor
from repro.core.sealing import SealedBox, seal, unseal
from repro.runtime.straggler import StepWatchdog


@dataclasses.dataclass
class Request:
    rid: int
    box: SealedBox
    shape: Tuple[int, ...]
    session_key: np.ndarray          # client's symmetric key material


@dataclasses.dataclass
class Response:
    rid: int
    box: Optional[SealedBox]
    ok: bool
    latency_s: float


class PrivateInferenceServer:
    """Batched Origami serving over a model (CNN or LM single-shot)."""

    def __init__(self, cfg: ModelConfig, params, *, mode: str = "origami",
                 max_batch: int = 8, input_key: str = "images",
                 impl: str = "fused", precompute: bool = True):
        self.cfg = cfg
        self.executor = OrigamiExecutor(cfg, params, mode=mode, impl=impl,
                                        precompute=precompute)
        self.quote = measure_enclave(cfg, params,
                                     self.executor.partition)
        self.max_batch = max_batch
        self.input_key = input_key
        self.watchdog = StepWatchdog()
        self.processed = 0
        self.batches = 0
        # server-side root for per-batch blinding sessions (distinct from the
        # clients' sealing keys): batch k runs under fold_in(root, k). MUST
        # be fresh entropy per instance — a fixed or colliding root would
        # reuse one-time pads across server restarts/replicas, letting the
        # device subtract two blinded tensors and cancel r. 64 entropy bits
        # via two 32-bit words (PRNGKey seeds are limited to C-long range).
        w0, w1 = np.frombuffer(os.urandom(8), np.uint32)
        self._blind_root = jax.random.fold_in(jax.random.PRNGKey(int(w0)),
                                              int(w1))

    def _blind_session(self, batch_idx: int) -> jax.Array:
        return jax.random.fold_in(self._blind_root, batch_idx)

    # -- client side helpers ---------------------------------------------
    def attest(self) -> Quote:
        return self.quote

    @staticmethod
    def client_seal(key: np.ndarray, x: np.ndarray, rid: int) -> SealedBox:
        nonce = jnp.asarray([rid & 0xFFFFFFFF, (rid >> 32) & 0xFFFFFFFF],
                            jnp.uint32)
        return seal(jnp.asarray(key, jnp.uint32), jnp.asarray(x), nonce)

    @staticmethod
    def client_open(key: np.ndarray, box: SealedBox,
                    shape: Tuple[int, ...]) -> np.ndarray:
        pt, ok = unseal(jnp.asarray(key, jnp.uint32), box, shape)
        assert bool(ok), "response MAC failed"
        return np.asarray(pt)

    # -- server side -------------------------------------------------------
    def serve_batch(self, requests: List[Request]) -> List[Response]:
        self.watchdog.start_step()
        t0 = time.monotonic()
        inputs, valid = [], []
        for r in requests[: self.max_batch]:
            pt, ok = unseal(jnp.asarray(r.session_key, jnp.uint32), r.box,
                            r.shape)
            valid.append(bool(ok))
            inputs.append(np.asarray(pt))
        n = len(inputs)
        if n == 0:
            return []
        # pad to max_batch so one compiled executable serves all sizes
        pad = self.max_batch - n
        x = np.stack(inputs + [np.zeros_like(inputs[0])] * pad)
        result = self.executor.infer({self.input_key: jnp.asarray(x)},
                                     session_key=self._blind_session(
                                         self.batches))
        self.batches += 1
        # double-buffer: enqueue the NEXT session's unblinding factors now,
        # so their field matmuls overlap this batch's device compute
        self.executor.prepare_session(self._blind_session(self.batches))
        logits = np.asarray(result.logits, np.float32)[:n]
        self.watchdog.end_step()
        out = []
        dt = time.monotonic() - t0
        for i, r in enumerate(requests[: self.max_batch]):
            if not valid[i]:
                out.append(Response(r.rid, None, False, dt))
                continue
            box = seal(jnp.asarray(r.session_key, jnp.uint32),
                       jnp.asarray(logits[i]),
                       jnp.asarray([r.rid & 0xFFFFFFFF, 0xEE], jnp.uint32))
            out.append(Response(r.rid, box, True, dt))
        self.processed += n
        return out

    def serve(self, requests: List[Request]) -> List[Response]:
        responses = []
        for i in range(0, len(requests), self.max_batch):
            responses += self.serve_batch(requests[i:i + self.max_batch])
        return responses
