"""Async blinded-serving engine: continuous micro-batching over enclaves.

The paper's deployment (Fig. 3a) is request/response; the seed server was a
synchronous list loop — fixed-stride chunking, blinding factors generated
between batches, one model per process. ``ServingEngine`` is the serving
layer Privado-style systems put in front of enclave inference:

- **request queue with admission control**: ``submit`` returns a future
  immediately; past ``max_queue`` in-flight requests the engine sheds load
  (``Response.ok=False``) instead of growing the queue without bound, and
  per-request deadlines drop work that can no longer be served in time
  *before* it costs an unseal or an inference slot.
- **continuous micro-batcher**: requests bucket by (model, input shape).
  A bucket dispatches the moment it holds ``max_batch`` requests **or**
  its oldest request has waited ``max_wait_ms`` — no more fixed strides,
  so a full bucket never waits on an unrelated straggler.
- **out-of-order completion**: responses resolve per-request futures keyed
  by ``rid``; a later-submitted model's full bucket can (and does)
  complete before an earlier partial bucket flushes on its timer.
- **per-model executor registry**: one engine serves vgg16 and vgg19 (and
  a smoke LM) concurrently, each with its own OrigamiExecutor, attestation
  quote, blinding ``SessionPool`` (runtime/sessions.py) and partition plan
  from ``core/planner.py``.
- **graceful degradation** (DESIGN.md §12): a model whose DevicePool has
  zero serving-eligible slots (every device quarantined or breaker-open)
  falls back to verified enclave-only dispatch (``trusted=True``) with a
  ``degraded`` flag in EngineStats/snapshot — the service keeps answering,
  bit-exact, at enclave speed. Degraded batches still age the pool's
  bench cooldowns; the moment a breaker half-opens (or a quarantined slot
  reaches probation) the engine routes a blinded dispatch again so the
  plane's probe can re-admit the device, and a successful probe clears
  the flag automatically.
- **draining shutdown**: ``close()`` stops admission, lets the batcher
  flush everything already queued (bounded by the plane's liveness
  timeouts), drains the device stage, force-resolves anything left with an
  explicit ``shutdown`` error, and only then stops session pools and
  device queues — no future is ever left pending and no dispatched work
  is orphaned.
- **compile-once AOT serving** (DESIGN.md §15): every executable is
  compiled explicitly (``lower().compile()``) through a shared
  ``CompileCache`` (runtime/aot.py) — optionally persisted on disk across
  processes — and ``aot_warm`` registration pre-compiles every
  (trace kind, shape bucket) executable plus the sealing cores, so a
  model's first request never pays compile.
- **two-stage pipeline**: the dispatch splits into an enclave stage
  (unseal -> MAC-filter -> bucket-pad, on the batcher thread) and a
  device stage (blinded infer -> verify -> recovery -> seal, on a
  dedicated worker), joined by a bounded handoff queue — batch N+1's
  unseal overlaps batch N's device compute. On this box the enclave's
  crypto and the device matmuls are the two dominant phases (§14), so
  the overlap is the §15 throughput lever.

Every batch COMPLETES on the single device-stage thread in FIFO handoff
order (the enclave stage only unseals; chaos-bound models defer even that
so scripted sealed-box corruption still lands before the MAC check), so
per-model entry state, the watchdog, and the quarantine/degradation state
machines need no locking — they all live in the completion stage, exactly
as they lived in the single batcher thread before the split. Setting
``EngineConfig.pipeline=False`` collapses the two stages back onto the
batcher thread (bit-identical either way — the stages are the same two
halves of the one sealed-batch primitive).
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Deque, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.attestation import Quote, measure_enclave
from repro.core.origami import OrigamiExecutor
from repro.core.plan import PlacementPlan
from repro.core.planner import PartitionPlan, PartitionPlanner
from repro.core import tracing
from repro.runtime.aot import CompileCache, bucket_ladder
from repro.runtime.observability import MetricsRegistry, sync_struct
from repro.runtime.profiling import CriticalPathProfiler, FlightRecorder
from repro.runtime.sessions import SessionPool
from repro.runtime.straggler import StepWatchdog


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_wait_ms: float = 5.0            # bucket age that forces a flush
    max_queue: int = 256                # admission-control bound (in-flight)
    default_deadline_s: Optional[float] = None
    session_pool_depth: int = 4
    # integrity (DESIGN.md §9): grant one fresh-session device retry after
    # a failed Freivalds check before the enclave recomputes, and after
    # ``quarantine_after`` consecutive failing batches stop offloading to
    # that model's backend at all (every dispatch runs trusted). After
    # ``probation_after`` trusted batches the backend earns one probation
    # probe: a verified offload dispatch — a clean probe restores offload
    # (a transient fault heals), a dirty one re-benches it (the seed
    # quarantined forever: one bad patch window cost a model its
    # accelerator for the life of the process). Models registered with a
    # DevicePool skip this path entirely — their quarantine/probation is
    # per-DEVICE (runtime/devices.py), so one bad part never benches the
    # whole model.
    integrity_retry: bool = True
    quarantine_after: int = 3
    probation_after: int = 8
    # compile-once AOT serving (DESIGN.md §15): ``compile_cache_dir``
    # persists serialized executables across processes; ``aot_warm``
    # pre-compiles every (trace kind, shape bucket) executable — and the
    # sealing cores — at register time, so the first request never pays
    # compile. Warm is opt-in (the production launcher and benches set it)
    # because it compiles the whole bucket ladder up front, which a
    # short-lived engine hitting one shape would never amortize.
    compile_cache_dir: Optional[str] = None
    aot_warm: bool = False
    # two-stage enclave/device pipeline: ``pipeline_depth`` bounds the
    # prepared-batch handoff queue (the batcher blocks past it — natural
    # backpressure); ``pipeline=False`` collapses both stages onto the
    # batcher thread (the pre-§15 serial dispatch, bit-identical)
    pipeline: bool = True
    pipeline_depth: int = 2


@dataclasses.dataclass
class _Pending:
    model: str
    req: "Request"
    future: Future
    submit_t: float
    deadline_s: Optional[float]
    # trace plane (core/tracing.py): the per-request root span and its
    # open "queue" child, both None when the engine has no tracer
    span: Optional[object] = None
    queue_span: Optional[object] = None


@dataclasses.dataclass
class _ModelEntry:
    name: str
    cfg: ModelConfig
    executor: OrigamiExecutor
    quote: Quote
    pool: SessionPool
    plan: PartitionPlan                  # prefix-decision provenance
    placement: PlacementPlan = None      # the per-layer IR actually executed
    input_key: str = "images"
    input_dtype: Optional[str] = None    # cast unsealed floats (LM tokens)
    # integrity bookkeeping (batcher thread only — no locking needed)
    integrity_failures: int = 0          # total failed-check batches
    consec_failures: int = 0             # consecutive (resets on clean)
    quarantined: bool = False            # offload disabled, enclave serves
    trusted_streak: int = 0              # trusted batches since quarantine
    probations: int = 0                  # probe dispatches attempted
    restores: int = 0                    # probes that re-admitted offload
    # liveness / degradation bookkeeping (batcher thread only, §12)
    batches: int = 0                     # dispatches (the chaos clock)
    degraded: bool = False               # pool empty: enclave-only serving
    degradations: int = 0                # healthy -> degraded transitions
    recoveries: int = 0                  # degraded -> healthy transitions
    degraded_batches: int = 0            # batches served enclave-only
    chaos: Optional[object] = None       # runtime/chaos.ChaosController
    # flight-recorder trigger edges (batcher thread only): per-device
    # breaker/quarantine transitions are detected as counter increases
    # across dispatches, since the transitions happen inside the plane
    breaker_opens_seen: int = 0
    dev_quarantines_seen: int = 0


@dataclasses.dataclass
class _BatchWork:
    """Handoff unit between the enclave stage and the device stage.

    ``prep`` is the enclave stage's product (serving.PreparedBatch); None
    means the enclave stage was deferred into the completion stage (serial
    ``pipeline=False`` dispatch, or a chaos-bound model whose drill must
    corrupt sealed boxes before the MAC check)."""
    entry: _ModelEntry
    batch: List[_Pending]
    batch_span: Optional[object]
    prep: Optional[object]


class EngineStats:
    """Aggregate serving telemetry — a facade over ``MetricsRegistry``.

    Counters used to live as bare ints bumped with ``+=`` from the
    submit path, the batcher thread and (via snapshot reads) any caller
    thread — unsynchronized read-modify-write. Every counter now lives in
    the registry under its DESIGN.md §13 name; attribute access keeps
    working (``stats.batches`` reads the registry) so existing tests and
    benches hold, but *mutation* should go through ``inc``/``inc_many``,
    which are atomic under the registry's lock. ``stats.lock`` aliases
    that (re-entrant) lock, so legacy ``with stats.lock: stats.x += 1``
    blocks remain correct rather than deadlocking.
    """

    LAT_WINDOW = 4096
    LATENCY_HIST = "engine.latency_s"

    # attribute -> registry counter name (the §13 naming scheme: one
    # dotted namespace per stat surface)
    COUNTERS = {
        "submitted": "engine.submitted",
        "completed": "engine.completed",
        "rejected": "engine.rejected",           # admission control
        "expired": "engine.expired",             # deadline before dispatch
        "mac_failures": "engine.mac_failures",
        "batches": "engine.batches",
        "padded_slots": "engine.padded_slots",
        "batched_requests": "engine.batched_requests",
        # integrity counters (DESIGN.md §9)
        "verify_checks": "integrity.verify_checks",
        "verify_failures": "integrity.verify_failures",
        "device_retries": "integrity.device_retries",
        "recomputes": "integrity.recomputes",
        "trusted_batches": "integrity.trusted_batches",
        "quarantines": "integrity.quarantines",
        "probations": "integrity.probations",
        "probation_restores": "integrity.probation_restores",
        # multi-device plane counters (DESIGN.md §11)
        "shard_checks": "shard.checks",
        "shard_failures": "shard.failures",
        "shard_retries": "shard.retries",
        "shard_hedges": "shard.hedges",
        "shard_enclave": "shard.enclave",
        # liveness plane counters (DESIGN.md §12)
        "shard_crashes": "liveness.shard_crashes",
        "shard_timeouts": "liveness.shard_timeouts",
        "degradations": "liveness.degradations",
        "recoveries": "liveness.recoveries",
        "degraded_batches": "liveness.degraded_batches",
        "shutdown_drops": "liveness.shutdown_drops",
    }

    def __init__(self, registry: Optional[MetricsRegistry] = None) -> None:
        self.registry = registry if registry is not None else MetricsRegistry()
        self.lock = self.registry.lock
        for metric in self.COUNTERS.values():
            self.registry.set_counter(metric, 0)
        self.start_t = time.monotonic()
        self.first_batch_t: Optional[float] = None
        self.first_submit_t: Optional[float] = None
        # request-path compile seconds accrued by the time the first batch
        # completed (CompileCache.request_compile_seconds) — what separates
        # ttfb_cold_s from ttfb_warm_s
        self.first_batch_compile_s: float = 0.0

    # -- recording ---------------------------------------------------------
    def inc(self, attr: str, n: int = 1) -> None:
        """Atomically bump one counter by its legacy attribute name."""
        self.registry.inc(self.COUNTERS[attr], n)

    def inc_many(self, **deltas: int) -> None:
        """Atomically bump several counters (one lock acquisition)."""
        self.registry.inc_many(
            **{self.COUNTERS[a]: n for a, n in deltas.items()})

    def record_submit(self) -> None:
        with self.lock:
            if self.first_submit_t is None:
                self.first_submit_t = time.monotonic()
            self.inc("submitted")

    def record_batch(self, n_valid: int, pad: int,
                     request_compile_s: Optional[float] = None) -> None:
        with self.lock:
            if self.first_batch_t is None:
                self.first_batch_t = time.monotonic()
                self.first_batch_compile_s = float(request_compile_s or 0.0)
            self.inc_many(batches=1, batched_requests=n_valid,
                          padded_slots=pad)

    def record_done(self, latency_s: float) -> None:
        with self.lock:
            self.inc("completed")
            self.registry.observe(self.LATENCY_HIST, latency_s)

    # -- derived -----------------------------------------------------------
    @property
    def latencies(self) -> List[float]:
        return self.registry.hist_values(self.LATENCY_HIST)

    @property
    def time_to_first_batch_s(self) -> Optional[float]:
        if self.first_batch_t is None:
            return None
        return self.first_batch_t - self.start_t

    @property
    def ttfb_cold_s(self) -> Optional[float]:
        """First submit -> first completed batch, compile included."""
        if self.first_batch_t is None or self.first_submit_t is None:
            return None
        return self.first_batch_t - self.first_submit_t

    @property
    def ttfb_warm_s(self) -> Optional[float]:
        """``ttfb_cold_s`` minus the request-path compile seconds measured
        by the CompileCache up to the first batch — what a warmed (AOT or
        disk-cached) engine actually delivers, and the §15 bench gate.
        Equals ``ttfb_cold_s`` when registration pre-compiled everything
        (there was no request-path compile left to subtract)."""
        cold = self.ttfb_cold_s
        if cold is None:
            return None
        return max(0.0, cold - self.first_batch_compile_s)

    def _quantile(self, q: float) -> Optional[float]:
        lat = sorted(self.latencies)
        if not lat:
            return None
        return lat[min(len(lat) - 1, int(q * len(lat)))]

    def p50_latency_s(self) -> Optional[float]:
        return self._quantile(0.50)

    def p95_latency_s(self) -> Optional[float]:
        return self._quantile(0.95)

    def snapshot(self, engine: "ServingEngine") -> Dict[str, object]:
        c = {attr: self.registry.get(m) for attr, m in self.COUNTERS.items()}
        out: Dict[str, object] = {
            "submitted": c["submitted"], "completed": c["completed"],
            "rejected": c["rejected"], "expired": c["expired"],
            "mac_failures": c["mac_failures"], "batches": c["batches"],
            "padded_slots": c["padded_slots"],
            "batched_requests": c["batched_requests"],
        }
        out["queue_depth"] = engine.queue_depth()
        out["time_to_first_batch_s"] = self.time_to_first_batch_s
        out["ttfb_cold_s"] = self.ttfb_cold_s
        out["ttfb_warm_s"] = self.ttfb_warm_s
        out["p50_latency_s"] = self.p50_latency_s()
        out["p95_latency_s"] = self.p95_latency_s()
        out["aot"] = engine.aot.stats()
        out["integrity"] = {
            k: c[k] for k in (
                "verify_checks", "verify_failures", "device_retries",
                "recomputes", "trusted_batches", "quarantines",
                "probations", "probation_restores", "shard_checks",
                "shard_failures", "shard_retries", "shard_hedges",
                "shard_enclave")}
        out["liveness"] = {
            k: c[k] for k in (
                "shard_crashes", "shard_timeouts", "degradations",
                "recoveries", "degraded_batches", "shutdown_drops")}
        # per-device health of every model running a sharded offload plane
        # (quarantine is per-DEVICE there, not per-model)
        out["devices"] = {
            name: e.executor.plane.snapshot()
            for name, e in engine.models.items()
            if e.executor.plane is not None}
        out["sessions"] = {name: e.pool.stats()
                           for name, e in engine.models.items()}
        # a persistently failing refill thread silently puts every factor
        # matmul back on the hot path — surface it at the top level too,
        # not just per-model under "sessions"
        out["refill_errors"] = sum(s["refill_errors"]
                                   for s in out["sessions"].values())
        # offload counters read the *blinded*-trace snapshot so a recovery
        # (trusted) trace can never pollute them; trusted_matmuls reads the
        # trusted-trace snapshot for the same reason
        out["matmuls"] = {
            name: {"mode": e.executor.mode,
                   "plan": e.executor.plan.digest[:12],
                   "device": e.executor.telemetry_blinded.device_matmuls,
                   "enclave": e.executor.telemetry_blinded.enclave_matmuls}
            for name, e in engine.models.items()}
        # the effective policy is the executor-wide one OR the plan's
        # per-step policies (a vopen plan verifies with integrity=None —
        # reporting "off" for it would contradict the nonzero
        # verify_checks above)
        out["models"] = {
            name: {"policy": (e.executor.integrity.mode
                              if e.executor.integrity.enabled else
                              "per-step" if e.executor.plan.has_step_policies
                              else "off"),
                   "plan": e.executor.plan.digest[:12],
                   "placements": e.executor.plan.placement_string,
                   "verify_ops": e.executor.telemetry_blinded.verify_ops,
                   "verify_flops": e.executor.telemetry_blinded.verify_flops,
                   "fold_matmuls": e.executor.telemetry_blinded.fold_matmuls,
                   "trusted_matmuls":
                       e.executor.telemetry_trusted.trusted_matmuls,
                   "integrity_failures": e.integrity_failures,
                   "quarantined": e.quarantined,
                   "probations": e.probations, "restores": e.restores,
                   "degraded": e.degraded,
                   "degradations": e.degradations,
                   "recoveries": e.recoveries,
                   "degraded_batches": e.degraded_batches}
            for name, e in engine.models.items()}
        # unified registry view: publish the per-model/per-device feeder
        # surfaces (Telemetry, ShardReport, session stats, watchdog EWMAs,
        # breaker/quarantine state) as gauges, then export one consistent
        # cut — the same names the benches and DESIGN.md §13 use
        engine.sync_registry(out)
        # performance-attribution plane (§14): fold any newly completed
        # request trees and export the phase decomposition alongside the
        # metrics cut it explains
        out["phases"] = engine.profile_phases()
        out["flight_recorder"] = engine.recorder.snapshot()
        out["metrics"] = self.registry.snapshot()
        # per-bucket occupancy view of the §15 shape ladder, derived from
        # the engine.bucket.<b>.* counters the device stage bumps
        buckets: Dict[int, Dict[str, int]] = {}
        for mname, v in out["metrics"]["counters"].items():
            if mname.startswith("engine.bucket."):
                _, _, b, fld = mname.split(".")
                buckets.setdefault(int(b), {})[fld] = v
        out["buckets"] = buckets
        return out


def _counter_property(metric: str) -> property:
    def fget(self: EngineStats) -> int:
        return self.registry.get(metric)

    def fset(self: EngineStats, value: int) -> None:
        self.registry.set_counter(metric, value)

    return property(fget, fset)


for _attr, _metric in EngineStats.COUNTERS.items():
    setattr(EngineStats, _attr, _counter_property(_metric))


class ServingEngine:
    """Continuous micro-batching engine over a registry of enclaves."""

    def __init__(self, cfg: Optional[EngineConfig] = None,
                 tracer: Optional[tracing.Tracer] = None,
                 registry: Optional[MetricsRegistry] = None,
                 recorder: Optional[FlightRecorder] = None, **kw):
        self.cfg = cfg or EngineConfig(**kw)
        self.models: Dict[str, _ModelEntry] = {}
        self.tracer = tracer
        self.stats = EngineStats(registry)
        self.registry = self.stats.registry
        # performance-attribution plane: folds completed request trees into
        # the §14 phase taxonomy; always constructed (ingest is a no-op
        # without a tracer) so snapshot()["phases"] is a stable surface
        self.profiler = CriticalPathProfiler()
        # always-on post-mortem ring; callers pass a FlightRecorder with an
        # out_dir to get on-disk bundles (serve.py --postmortem-dir)
        self.recorder = recorder if recorder is not None else FlightRecorder()
        self.watchdog = StepWatchdog()
        # the shared compile-once cache (§15): attached to every registered
        # executor; counters land in this engine's registry
        self.aot = CompileCache(self.cfg.compile_cache_dir,
                                registry=self.registry)
        self._buckets: "OrderedDict[Tuple[str, Tuple[int, ...]], Deque[_Pending]]" = OrderedDict()
        self._futures: Dict[Tuple[str, int], Future] = {}   # (model, rid)
        self._in_flight = 0
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._flush_t = -1.0              # see flush()
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        # two-stage pipeline: bounded handoff of prepared batches from the
        # batcher (enclave stage) to the device-stage worker
        self._pipe: "queue_mod.Queue" = queue_mod.Queue(
            maxsize=max(1, self.cfg.pipeline_depth))
        self._pipe_inflight = 0           # handed off, not yet completed
        self._device_thread: Optional[threading.Thread] = None
        # (model, rid) completion log, bounded like EngineStats.latencies —
        # an unbounded list would leak one tuple per request forever
        self.completion_order: Deque[Tuple[str, int]] = deque(
            maxlen=EngineStats.LAT_WINDOW)

    # -- registry ----------------------------------------------------------
    def register_model(self, name: str, cfg: ModelConfig, params, *,
                       mode: str = "origami", impl: str = "fused",
                       precompute: bool = True, input_key: str = "images",
                       input_dtype: Optional[str] = None,
                       partition: Optional[int] = None,
                       privacy_floor: Optional[float] = None,
                       planner: Optional[PartitionPlanner] = None,
                       leakage: Optional[Dict[int, float]] = None,
                       integrity=None, fault=None,
                       placement: Optional[PlacementPlan] = None,
                       devices=None, shard: str = "rows",
                       hedging: bool = True, liveness=None,
                       chaos=None) -> _ModelEntry:
        """Build an executor for ``name`` and admit it to the registry.

        ``placement``: an explicit per-layer PlacementPlan (core/plan.py)
        — overrides the mode/partition path entirely. Otherwise the
        partition point comes from, in order: the explicit ``partition``
        argument, the cost-model planner (when ``privacy_floor`` or
        ``planner`` is given), or the config's declared
        ``origami.tier1_layers``, and is compiled to a prefix plan.
        ``integrity``/``fault``: Freivalds verification policy and (for
        tests/chaos drills) a dishonest-device injector, forwarded to the
        executor (core/integrity.py, runtime/faults.py).
        ``devices``: a runtime/devices.DevicePool or a simulated slot
        count — attaches the sharded multi-device offload plane
        (parallel/offload_sharding.py) with default shard geometry
        ``shard`` and straggler ``hedging``; quarantine then becomes
        per-device (the pool's) instead of per-model. ``liveness``: a
        parallel/offload_sharding.LivenessConfig for the plane's
        timeout/backoff/breaker ladder. ``chaos``: a runtime/chaos
        ChaosController — its schedule is advanced once per dispatched
        batch of this model (the drill clock).
        """
        if isinstance(devices, int):
            from repro.runtime.devices import DevicePool
            devices = DevicePool(devices)
        if placement is not None:
            plan = PartitionPlan(cfg.name, placement.mode_label,
                                 placement.boundary, "explicit",
                                 None, {}, {}, ())
            executor = OrigamiExecutor(cfg, params, impl=impl,
                                       precompute=precompute,
                                       integrity=integrity, fault=fault,
                                       plan=placement, devices=devices,
                                       shard=shard, hedging=hedging,
                                       liveness=liveness)
            return self.register_executor(name, executor,
                                          input_key=input_key,
                                          input_dtype=input_dtype, plan=plan,
                                          chaos=chaos)
        if planner is None and privacy_floor is not None:
            planner = PartitionPlanner(privacy_floor=privacy_floor)
        if planner is not None or partition is not None:
            planner = planner or PartitionPlanner()
            plan = planner.plan(cfg, params, mode=mode, partition=partition,
                                leakage=leakage)
        else:
            plan = PartitionPlan(cfg.name, mode, cfg.origami.tier1_layers,
                                 "config", None, {}, {}, ())
        executor = OrigamiExecutor(cfg, params, mode=mode,
                                   partition=plan.partition, impl=impl,
                                   precompute=precompute,
                                   integrity=integrity, fault=fault,
                                   devices=devices, shard=shard,
                                   hedging=hedging, liveness=liveness)
        return self.register_executor(name, executor, input_key=input_key,
                                      input_dtype=input_dtype, plan=plan,
                                      chaos=chaos)

    def register_executor(self, name: str, executor: OrigamiExecutor, *,
                          input_key: str = "images",
                          input_dtype: Optional[str] = None,
                          plan: Optional[PartitionPlan] = None,
                          pool: Optional[SessionPool] = None,
                          chaos=None) -> _ModelEntry:
        """Admit a pre-built executor (the legacy server's compat path)."""
        assert name not in self.models, f"model {name!r} already registered"
        plan = plan or PartitionPlan(executor.cfg.name, executor.mode,
                                     executor.partition, "explicit",
                                     None, {}, {}, ())
        entry = _ModelEntry(
            name=name, cfg=executor.cfg, executor=executor,
            # generate-capable executors attest their DECODE plan digest
            # (covers the scan-segment structure, core/plan.py §16)
            quote=measure_enclave(executor.cfg, executor.params,
                                  executor.partition,
                                  plan_digest=getattr(
                                      executor, "attested_digest",
                                      executor.plan.digest)),
            pool=pool or SessionPool(executor,
                                     depth=self.cfg.session_pool_depth),
            plan=plan, placement=executor.plan,
            input_key=input_key, input_dtype=input_dtype)
        entry.chaos = chaos
        if executor.plane is not None:
            # bad shard outcomes (verify-fail/crash/timeout) land in the
            # post-mortem ring even though the plane recovers them itself
            executor.plane.recorder = self.recorder
        if chaos is not None:
            chaos.bind(
                pool=(executor.plane.pool if executor.plane is not None
                      else None),
                sessions=entry.pool)
        executor.attach_aot(self.aot)
        if self.cfg.aot_warm:
            self.warm(entry)
        with self._lock:
            self.models[name] = entry
        return entry

    def warm(self, entry: _ModelEntry,
             warm_shape: Optional[Tuple[int, ...]] = None) -> int:
        """AOT-compile the model's serving surface before its first
        request: every (trace kind, shape bucket) executable on the §15
        ladder — which also builds the per-bucket factor caches the
        SessionPool prefetches into — plus the sealing cores for the
        request and response shapes. ``warm_shape`` overrides the
        per-request input shape; by default it is derived for CNN configs
        (image HWC) and non-CNN models are skipped (their request shapes
        aren't statically known here). Returns executables ensured."""
        import jax.numpy as jnp
        from repro.core.sealing import seal, unseal
        from repro.runtime.serving import request_nonce, response_nonce
        cfg = entry.cfg
        shape = warm_shape
        if shape is None:
            # generate-capable executors declare their own request shape
            # (the prompt length) — runtime/generate.py GenerateExecutor
            shape = getattr(entry.executor, "request_shape", None)
        if shape is None and getattr(cfg, "family", None) == "cnn":
            shape = (cfg.image_size, cfg.image_size, cfg.image_channels)
        if shape is None:
            return 0
        n = entry.executor.warm_aot(
            entry.input_key, shape, bucket_ladder(self.cfg.max_batch),
            dtype=entry.input_dtype)
        # sealing cores (core/sealing.py jits, keyed by payload/nonce
        # shape): one request-direction unseal, one response-direction seal
        key = jnp.zeros((2,), jnp.uint32)
        box = seal(key, jnp.zeros(shape, jnp.float32), request_nonce(0))
        unseal(key, box, shape)
        n_out = (getattr(entry.executor, "response_elems", None)
                 or getattr(cfg, "num_classes", None))
        if n_out:
            seal(key, jnp.zeros((int(n_out),), jnp.float32),
                 response_nonce(0))
        return n

    def attest(self, name: str) -> Quote:
        return self.models[name].quote

    # -- submission --------------------------------------------------------
    def submit(self, model: str, req: "Request",
               deadline_s: Optional[float] = None) -> Future:
        """Queue one sealed request; resolves to a ``Response``.

        Rejected (queue full / unknown model / duplicate in-flight rid)
        requests resolve immediately with ``ok=False`` — admission control
        is part of the response contract, not an exception path.
        """
        from repro.runtime.serving import Response
        fut: Future = Future()
        now = time.monotonic()
        deadline = (deadline_s if deadline_s is not None
                    else self.cfg.default_deadline_s)
        with self._cv:
            self.stats.record_submit()
            entry = self.models.get(model)
            if entry is None or self._closed:
                self.stats.inc("rejected")
                fut.set_result(Response(
                    req.rid, None, False, 0.0,
                    error="shutdown" if self._closed else "rejected"))
                return fut
            if (self._in_flight >= self.cfg.max_queue
                    or (model, req.rid) in self._futures):
                self.stats.inc("rejected")
                fut.set_result(Response(req.rid, None, False, 0.0,
                                        error="rejected"))
                return fut
            self._futures[(model, req.rid)] = fut
            p = _Pending(model, req, fut, now, deadline)
            if self.tracer is not None and self.tracer.enabled:
                # admitted requests only: a shed request never cost a stage
                p.span = self.tracer.start_span(
                    "request", "request", parent=None, rid=req.rid,
                    model=model, shape=list(req.shape))
                p.queue_span = self.tracer.start_span(
                    "queue", "queue", parent=p.span)
            bucket_key = (model, tuple(req.shape))
            bucket = self._buckets.setdefault(bucket_key, deque())
            bucket.append(p)
            self._in_flight += 1
            self._ensure_thread()
            self._cv.notify_all()
        return fut

    def submit_many(self, model: str, reqs: List["Request"],
                    deadline_s: Optional[float] = None) -> List[Future]:
        return [self.submit(model, r, deadline_s) for r in reqs]

    def future_for(self, model: str, rid: int) -> Optional[Future]:
        """The in-flight future for (model, rid), if any."""
        with self._lock:
            return self._futures.get((model, rid))

    def flush(self) -> None:
        """Dispatch everything already queued without waiting for
        max_batch or the max_wait timer — for callers that know their
        request list is complete (e.g. the synchronous serve() wrapper,
        whose tail batch would otherwise idle out the timer). Requests
        submitted after the flush batch up normally."""
        with self._cv:
            self._flush_t = time.monotonic()
            self._cv.notify_all()

    def queue_depth(self) -> int:
        """Requests not yet resolved: queued in buckets plus handed off to
        (or executing on) the device stage — so ``drain()`` waits for the
        pipeline's tail, not just for empty buckets."""
        with self._lock:
            return self._in_flight + self._pipe_inflight

    # -- batcher -----------------------------------------------------------
    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._batch_loop,
                                            name="serving-engine-batcher",
                                            daemon=True)
            self._thread.start()

    def _ensure_device_thread(self) -> None:
        if self._device_thread is None or not self._device_thread.is_alive():
            self._device_thread = threading.Thread(
                target=self._device_loop, name="serving-engine-device",
                daemon=True)
            self._device_thread.start()

    def _ready_bucket(self, now: float):
        """The ready bucket (full or past max_wait) whose head request has
        waited longest — head age, not registry order, breaks ties so a
        persistently full hot bucket cannot starve a timer-expired trickle
        bucket. Also returns the earliest upcoming flush time across
        non-ready buckets (the cv wait timeout when nothing is ready)."""
        max_wait = self.cfg.max_wait_ms / 1e3
        best_key = best_head_t = None
        next_deadline = None
        for key, bucket in self._buckets.items():
            if not bucket:
                continue
            head_t = bucket[0].submit_t
            if (len(bucket) >= self.cfg.max_batch
                    or head_t + max_wait <= now
                    or head_t <= self._flush_t):
                if best_head_t is None or head_t < best_head_t:
                    best_key, best_head_t = key, head_t
            else:
                flush_at = head_t + max_wait
                next_deadline = (flush_at if next_deadline is None
                                 else min(next_deadline, flush_at))
        return best_key, next_deadline

    def _batch_loop(self) -> None:
        from repro.runtime.serving import Response
        while True:
            with self._cv:
                while True:
                    if self._closed and self._in_flight == 0:
                        return
                    now = time.monotonic()
                    key, next_flush = self._ready_bucket(now)
                    if key is not None:
                        break
                    timeout = (None if next_flush is None
                               else max(1e-4, next_flush - now))
                    self._cv.wait(timeout=timeout)
                bucket = self._buckets[key]
                batch: List[_Pending] = []
                expired: List[_Pending] = []
                while bucket and len(batch) < self.cfg.max_batch:
                    p = bucket.popleft()
                    if (p.deadline_s is not None
                            and now - p.submit_t > p.deadline_s):
                        expired.append(p)
                    else:
                        batch.append(p)
                self._in_flight -= len(batch) + len(expired)
                if not bucket:
                    self._buckets.pop(key, None)
            for p in expired:
                self.stats.inc("expired")
                self._end_queue_span(p, expired=True)
                self._finish(p, Response(p.req.rid, None, False,
                                         time.monotonic() - p.submit_t,
                                         error="deadline_exceeded"))
            if batch:
                entry = self.models[batch[0].model]
                try:
                    if self.cfg.pipeline:
                        # enclave stage here; completion on the device
                        # thread. Chaos-bound models defer the unseal too
                        # (their drill may corrupt sealed boxes, which must
                        # land before the MAC check) — their work item just
                        # rides the same FIFO with the enclave stage folded
                        # into the completion stage.
                        work = self._stage_prepare(
                            entry, batch, unseal_now=entry.chaos is None)
                        if work is not None:
                            self._ensure_device_thread()
                            with self._lock:
                                self._pipe_inflight += len(work.batch)
                            self._pipe.put(work)   # blocks at depth: the
                            # batcher back-pressures instead of out-running
                            # the device stage without bound
                    else:
                        self._dispatch(entry, batch)
                except Exception as exc:  # noqa: BLE001 — fail the batch,
                    for p in batch:       # not the engine
                        with self._lock:
                            self._futures.pop((p.model, p.req.rid), None)
                        if not p.future.done():
                            p.future.set_exception(exc)

    def _device_loop(self) -> None:
        """Device-stage worker: completes prepared batches in handoff
        order. ALL post-dispatch bookkeeping (watchdog, integrity/
        degradation state machines, stats, flight-recorder dumps, future
        resolution) runs here and only here — the single-thread ownership
        the pre-pipeline batcher had, preserved by construction."""
        while True:
            work = self._pipe.get()
            if work is None:           # close() sentinel
                return
            try:
                self._stage_complete(work)
            except Exception as exc:   # noqa: BLE001 — fail the batch,
                for p in work.batch:   # not the pipeline
                    with self._lock:
                        self._futures.pop((p.model, p.req.rid), None)
                    if not p.future.done():
                        p.future.set_exception(exc)
            finally:
                with self._lock:
                    self._pipe_inflight -= len(work.batch)

    def _dispatch(self, entry: _ModelEntry, batch: List[_Pending]) -> None:
        """One serial enclave dispatch (``pipeline=False`` and direct
        callers): both stages back-to-back on the calling thread — the
        legacy single-threaded order, which is also why the unseal is
        deferred into the completion stage here."""
        work = self._stage_prepare(entry, batch, unseal_now=False)
        if work is not None:
            self._stage_complete(work)

    def _stage_prepare(self, entry: _ModelEntry, batch: List[_Pending],
                       unseal_now: bool) -> Optional["_BatchWork"]:
        """Enclave stage: deadline re-check, span bookkeeping and (when
        ``unseal_now``) the unseal -> MAC-filter -> bucket-pad half of the
        sealed-batch primitive. Touches no per-model mutable state — that
        all belongs to the completion stage."""
        from repro.runtime.serving import Response, prepare_sealed_batch
        # deadline re-check at dispatch time (DESIGN.md §12): formation and
        # dispatch are back-to-back on the batcher thread, but a slow
        # previous batch can age this one past its deadline — don't burn
        # device compute on work nobody can use, and tell the caller why
        now = time.monotonic()
        live: List[_Pending] = []
        for p in batch:
            if p.deadline_s is not None and now - p.submit_t > p.deadline_s:
                self.stats.inc("expired")
                self._end_queue_span(p, expired=True)
                self._finish(p, Response(p.req.rid, None, False,
                                         now - p.submit_t,
                                         error="deadline_exceeded"))
            else:
                live.append(p)
        batch = live
        if not batch:
            return None
        # trace plane: close every member's queue span, open one "batch"
        # span parented at the OLDEST request's root (the request whose
        # wait formed the batch); other members' roots carry the batch
        # span id as an attribute so their trees remain navigable
        batch_span = None
        if self.tracer is not None and self.tracer.enabled:
            for p in batch:
                self._end_queue_span(p)
            anchor = min(batch, key=lambda p: p.submit_t)
            batch_span = self.tracer.start_span(
                "batch", "batch", parent=anchor.span, model=entry.name,
                n_requests=len(batch),
                plan=entry.executor.plan.digest[:12],
                rids=[p.req.rid for p in batch[:32]])
            for p in batch:
                if p.span is not None:
                    # every member root gets the plan digest (the profiler
                    # keys on it; only the anchor has the batch child)
                    self.tracer.annotate(
                        p.span, plan=entry.executor.plan.digest[:12])
                    if p is not anchor:
                        self.tracer.annotate(
                            p.span, batch_span_id=batch_span.span_id)
        prep = None
        if unseal_now:
            try:
                with tracing.activate(self.tracer, batch_span):
                    prep = prepare_sealed_batch(
                        [p.req for p in batch],
                        max_batch=self.cfg.max_batch,
                        input_dtype=entry.input_dtype)
            except Exception:
                if batch_span is not None and self.tracer is not None:
                    self.tracer.end(batch_span)
                raise
        return _BatchWork(entry=entry, batch=batch, batch_span=batch_span,
                          prep=prep)

    def _stage_complete(self, work: "_BatchWork") -> None:
        """Device stage: infer -> verify -> §9/§12 recovery -> seal, plus
        every piece of post-dispatch bookkeeping. Single-threaded (the
        device worker, or the caller when ``pipeline=False``)."""
        from repro.runtime.serving import (Response, complete_prepared_batch,
                                           prepare_sealed_batch)
        entry, batch, batch_span = work.entry, work.batch, work.batch_span
        entry.batches += 1
        if entry.chaos is not None:
            # the drill clock: arm/disarm scripted faults for this batch
            # index (device injectors, refill faults, sealed-box corruption)
            entry.chaos.on_batch(entry.batches - 1,
                                 requests=[p.req for p in batch])
        self.watchdog.start_step()
        # probation (poolless models): a quarantined backend that has
        # served ``probation_after`` trusted batches earns ONE verified
        # offload probe — clean restores offload, dirty re-benches it.
        # The probe routes REAL client traffic back to a convicted
        # backend, so it is only safe when every offloaded op is checked
        # (the retry/recompute path then recovers any corruption before
        # sealing): a "sampled" policy would let unchecked ops carry
        # corrupt logits to clients AND could restore the backend off a
        # lucky probe, so such models stay benched (the pre-probation
        # behavior). Models with a DevicePool never take this path:
        # their quarantine/probation is per-device, and shards are
        # always checked.
        per_device = entry.executor.plane is not None
        probe = (entry.quarantined and not per_device
                 and entry.executor.integrity.mode == "full"
                 and entry.trusted_streak >= self.cfg.probation_after)
        if probe:
            entry.probations += 1
            self.stats.inc("probations")
        # graceful degradation (DESIGN.md §12): zero serving-eligible
        # devices (every slot quarantined or breaker-open) means a blinded
        # dispatch has nowhere to go — serve this batch verified
        # enclave-only instead. The moment the pool has a probe candidate
        # (half-open breaker or probation-ripe quarantine) the blinded
        # path runs again so the plane can route the probe: shards are
        # always verified, so a recovery attempt is safe with real traffic
        # (un-routable shards fall to the enclave inside the op).
        degrade_trusted = False
        if per_device:
            dpool = entry.executor.plane.pool
            can_probe = (dpool.breaker_candidate() is not None
                         or dpool.probe_candidate() is not None)
            if dpool.n_available() == 0 and not can_probe:
                degrade_trusted = True
                entry.degraded_batches += 1
                self.stats.inc("degraded_batches")
                # enclave-only batches still age the pool's cooldowns —
                # otherwise a fully-benched pool could never reach its
                # half-open / probation probe state and the degradation
                # would be permanent
                dpool.begin_dispatch()
        try:
            with tracing.activate(self.tracer, batch_span):
                prep = work.prep
                if prep is None:      # serial path / chaos: enclave stage
                    prep = prepare_sealed_batch(        # runs here instead
                        [p.req for p in batch],
                        max_batch=self.cfg.max_batch,
                        input_dtype=entry.input_dtype)
                if prep.x is None:    # every MAC failed: nothing to infer
                    boxes, n_valid, pad, integ = (prep.boxes, 0, 0,
                                                  prep.integ)
                else:
                    boxes, n_valid, pad, integ = complete_prepared_batch(
                        entry.executor, prep, input_key=entry.input_key,
                        session_key=entry.pool.acquire,  # lazy: only
                        # consumed if a valid request infers
                        trusted=(entry.quarantined and not probe)
                        or degrade_trusted,
                        retry_device=self.cfg.integrity_retry)
        finally:
            if batch_span is not None and self.tracer is not None:
                self.tracer.end(batch_span)
        if batch_span is not None and self.tracer is not None:
            self.tracer.annotate(batch_span, n_valid=n_valid, pad=pad,
                                 bucket=prep.bucket,
                                 flagged=integ.flagged,
                                 trusted=integ.trusted > 0,
                                 degraded=degrade_trusted, probe=probe)
        if n_valid:
            self.stats.record_batch(
                n_valid, pad,
                request_compile_s=self.aot.request_compile_seconds)
            # per-bucket occupancy counters for the §15 shape ladder
            self.registry.inc_many(**{
                f"engine.bucket.{prep.bucket}.batches": 1,
                f"engine.bucket.{prep.bucket}.padded_slots": pad})
        self.stats.inc_many(
            mac_failures=sum(b is None for b in boxes),
            verify_checks=integ.checks,
            verify_failures=integ.failures,
            device_retries=integ.retried,
            recomputes=integ.recomputed,
            trusted_batches=integ.trusted,
            shard_checks=integ.shard_checks,
            shard_failures=integ.shard_failures,
            shard_retries=integ.shard_retries,
            shard_hedges=integ.shard_hedges,
            shard_enclave=integ.shard_enclave,
            shard_crashes=integ.shard_crashes,
            shard_timeouts=integ.shard_timeouts)
        if integ.flagged:
            # post-mortem trigger: a Freivalds failure this batch (whatever
            # recovered it) — the span tail shows which op/shard lied
            self.recorder.dump(
                "verify_failure", tracer=self.tracer,
                registry=self.registry, model=entry.name,
                checks=integ.checks, failures=integ.failures,
                shard_failures=integ.shard_failures,
                batch_index=entry.batches - 1)
        if n_valid and entry.quarantined and not per_device:
            if probe:
                if integ.checks and not integ.failures:
                    entry.quarantined = False
                    entry.consec_failures = 0
                    entry.restores += 1
                    self.stats.inc("probation_restores")
                entry.trusted_streak = 0     # clean: healthy again; dirty:
            else:                            # restart the probation clock
                entry.trusted_streak += 1
        elif n_valid and not entry.quarantined and not per_device:
            # quarantine bookkeeping (batcher thread owns entry state): a
            # backend that keeps failing its Freivalds checks stops being
            # offloaded to until probation re-admits it.
            if integ.flagged:
                entry.integrity_failures += 1
                entry.consec_failures += 1
                if entry.consec_failures >= self.cfg.quarantine_after:
                    entry.quarantined = True
                    entry.trusted_streak = 0
                    self.stats.inc("quarantines")
                    self.recorder.dump(
                        "quarantine", tracer=self.tracer,
                        registry=self.registry, model=entry.name,
                        consec_failures=entry.consec_failures,
                        batch_index=entry.batches - 1)
            elif integ.checks:
                entry.consec_failures = 0
        elif n_valid and per_device and integ.flagged:
            entry.integrity_failures += 1    # visibility only: recovery and
                                             # health are per-device (pool)
        if per_device:
            # degraded-mode state machine (§12): the flag tracks the pool's
            # serving-eligible count, transitions counted right after the
            # dispatch that caused them (a breaker opening mid-batch
            # degrades here; a successful half-open probe recovers here)
            dpool = entry.executor.plane.pool
            available = dpool.n_available() > 0
            if entry.degraded and available:
                entry.degraded = False
                entry.recoveries += 1
                self.stats.inc("recoveries")
                self.recorder.event("recovery", model=entry.name,
                                    batch_index=entry.batches - 1)
            elif not entry.degraded and not available:
                entry.degraded = True
                entry.degradations += 1
                self.stats.inc("degradations")
                self.recorder.dump(
                    "degradation", tracer=self.tracer,
                    registry=self.registry, model=entry.name,
                    batch_index=entry.batches - 1)
            # per-device transitions happen inside the plane — detect them
            # as counter edges so breaker-opens/device-quarantines dump too
            opens = sum(s.breaker_opens for s in dpool.slots)
            quars = sum(s.quarantines for s in dpool.slots)
            if opens > entry.breaker_opens_seen:
                self.recorder.dump(
                    "breaker_open", tracer=self.tracer,
                    registry=self.registry, model=entry.name,
                    new_opens=opens - entry.breaker_opens_seen,
                    batch_index=entry.batches - 1)
            if quars > entry.dev_quarantines_seen:
                self.recorder.dump(
                    "device_quarantine", tracer=self.tracer,
                    registry=self.registry, model=entry.name,
                    new_quarantines=quars - entry.dev_quarantines_seen,
                    batch_index=entry.batches - 1)
            entry.breaker_opens_seen = opens
            entry.dev_quarantines_seen = quars
        self.watchdog.end_step()
        for p, box in zip(batch, boxes):
            self._finish(p, Response(p.req.rid, box, box is not None,
                                     time.monotonic() - p.submit_t,
                                     flagged=integ.flagged
                                     and box is not None,
                                     error=None if box is not None
                                     else "mac_failed"))

    def _end_queue_span(self, p: _Pending, expired: bool = False) -> None:
        if p.queue_span is not None and self.tracer is not None:
            if p.queue_span.t1 is None:
                self.tracer.end(p.queue_span, expired=expired)
            p.queue_span = None

    def _finish(self, p: _Pending, resp) -> None:
        if resp.ok:
            self.stats.record_done(resp.latency_s)
        self._end_queue_span(p)
        if p.span is not None and self.tracer is not None:
            self.tracer.end(p.span, ok=resp.ok, error=resp.error,
                            flagged=resp.flagged)
            p.span = None
        with self._lock:
            self.completion_order.append((p.model, p.req.rid))
            self._futures.pop((p.model, p.req.rid), None)
        # done-guard: the forced shutdown sweep (close) may have resolved
        # this future already — set_result on a done future raises and
        # would kill the batcher thread
        if not p.future.done():
            p.future.set_result(resp)

    def snapshot(self) -> Dict[str, object]:
        """Aggregate serving telemetry (EngineStats.snapshot shorthand)."""
        return self.stats.snapshot(self)

    def profile_phases(self) -> Dict[str, object]:
        """Fold completed request spans into the §14 phase decomposition."""
        if self.tracer is not None:
            self.profiler.ingest(self.tracer)
            self.profiler.export_gauges(self.registry)
        return self.profiler.report()

    def sync_registry(self, legacy: Optional[Dict[str, object]] = None
                      ) -> MetricsRegistry:
        """Publish every feeder surface into the one registry as gauges.

        The producers (executor Telemetry, plane ShardReport, DeviceSlot
        breaker/quarantine state, StepWatchdog EWMAs, session pools) keep
        their own lightweight accounting on their own hot paths; this
        pulls a consistent cut of each into the registry under the §13
        names so ``snapshot()["metrics"]`` is the single queryable view.
        ``legacy``: the partially-built legacy snapshot dict (when called
        from EngineStats.snapshot) — reused to avoid re-walking planes.
        """
        reg = self.registry
        reg.gauges({"engine.queue_depth": self.queue_depth(),
                    "engine.watchdog.p50_s": self.watchdog.p50 or 0.0,
                    "engine.watchdog.flagged_steps":
                        self.watchdog.flagged_steps})
        for name, e in self.models.items():
            sync_struct(reg, f"model.{name}.telemetry",
                        e.executor.telemetry_blinded,
                        ("blinded_bytes", "returned_bytes",
                         "offloaded_flops", "enclave_flops",
                         "enclave_peak_feature_bytes", "calls",
                         "device_matmuls", "enclave_matmuls", "verify_ops",
                         "verify_flops", "fold_matmuls"))
            reg.gauge(f"model.{name}.telemetry.trusted_matmuls",
                      e.executor.telemetry_trusted.trusted_matmuls)
            for k, v in e.pool.stats().items():
                if isinstance(v, (int, float)):
                    reg.gauge(f"session.{name}.{k}", v)
            reg.gauges({f"model.{name}.quarantined": int(e.quarantined),
                        f"model.{name}.degraded": int(e.degraded)})
            plane = e.executor.plane
            if plane is None:
                continue
            sync_struct(reg, f"model.{name}.shard", plane.totals,
                        ("ops", "dispatches", "checks", "failures",
                         "retries", "hedges", "enclave_shards", "probes",
                         "crashes", "timeouts", "backoffs",
                         "breaker_probes"))
            psnap = plane.snapshot()
            wd = psnap.get("watchdog", {})
            for k, v in wd.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    reg.gauge(f"model.{name}.shard.watchdog.{k}", v)
            # per-device breaker/quarantine/EWMA gauges (satellite: chaos
            # drills and hedging decisions must be explainable post-hoc)
            for idx, slot in enumerate(psnap["pool"]["slots"]):
                pre = f"device.{name}.{idx}"
                for k, v in slot.items():
                    if isinstance(v, bool):
                        reg.gauge(f"{pre}.{k}", int(v))
                    elif isinstance(v, (int, float)):
                        reg.gauge(f"{pre}.{k}", v)
                    elif k == "breaker" and isinstance(v, str):
                        # encode breaker state as an ordinal gauge
                        # (closed=0, half_open=1, open=2) + keep the
                        # string in the legacy snapshot
                        order = {"closed": 0, "half_open": 1, "open": 2}
                        reg.gauge(f"{pre}.breaker_state",
                                  order.get(v, -1))
        return reg

    # -- lifecycle ---------------------------------------------------------
    def drain(self, timeout_s: float = 60.0) -> bool:
        """Block until the queue is empty (True) or timeout (False)."""
        end = time.monotonic() + timeout_s
        while time.monotonic() < end:
            if self.queue_depth() == 0:
                return True
            time.sleep(0.002)
        return self.queue_depth() == 0

    def close(self, drain_s: float = 30.0) -> None:
        """Graceful shutdown (DESIGN.md §12): stop admitting, let the
        batcher flush everything already queued (the plane's liveness
        timeouts bound how long a wedged device can stall that), drain the
        device stage behind it, then force-resolve anything still pending
        with an explicit ``shutdown`` error — **every submitted future
        resolves** — and only then stop the session pools and drain the
        device queues."""
        from repro.runtime.serving import Response
        with self._cv:
            self._closed = True
            # the tail bucket must not idle out its max_wait timer while
            # the batcher is the only thing left running
            self._flush_t = time.monotonic()
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=drain_s)
        # the batcher has stopped enqueueing: sentinel the device stage so
        # it finishes everything already handed off, then exits
        if (self._device_thread is not None
                and self._device_thread.is_alive()):
            self._pipe.put(None)
            self._device_thread.join(timeout=drain_s)
        # forced resolution: anything the batcher or device stage left
        # behind (a thread died, or the drain timed out) resolves NOW — a
        # shutdown may abandon work, never a caller
        leftovers: List[_Pending] = []
        while True:
            try:
                work = self._pipe.get_nowait()
            except queue_mod.Empty:
                break
            if work is not None:
                leftovers.extend(work.batch)
                with self._lock:
                    self._pipe_inflight -= len(work.batch)
        with self._cv:
            for bucket in self._buckets.values():
                leftovers.extend(bucket)
            self._buckets.clear()
            self._in_flight = 0
        for p in leftovers:
            self.stats.inc("shutdown_drops")
            self._finish(p, Response(p.req.rid, None, False,
                                     time.monotonic() - p.submit_t,
                                     error="shutdown"))
        for entry in self.models.values():
            entry.pool.close()
            if entry.executor.plane is not None:
                entry.executor.plane.pool.close(drain=True)
