"""Async blinded-serving engine: continuous micro-batching over enclaves.

The paper's deployment (Fig. 3a) is request/response; the seed server was a
synchronous list loop — fixed-stride chunking, blinding factors generated
between batches, one model per process. ``ServingEngine`` is the serving
layer Privado-style systems put in front of enclave inference:

- **request queue with admission control**: ``submit`` returns a future
  immediately; past ``max_queue`` in-flight requests the engine sheds load
  (``Response.ok=False``) instead of growing the queue without bound, and
  per-request deadlines drop work that can no longer be served in time
  *before* it costs an unseal or an inference slot.
- **continuous micro-batcher**: requests bucket by (model, input shape).
  A bucket dispatches the moment it holds ``max_batch`` requests **or**
  its oldest request has waited ``max_wait_ms`` — no more fixed strides,
  so a full bucket never waits on an unrelated straggler.
- **out-of-order completion**: responses resolve per-request futures keyed
  by ``rid``; a later-submitted model's full bucket can (and does)
  complete before an earlier partial bucket flushes on its timer.
- **per-model executor registry**: one engine serves vgg16 and vgg19 (and
  a smoke LM) concurrently, each with its own OrigamiExecutor, attestation
  quote, blinding ``SessionPool`` (runtime/sessions.py) and partition plan
  from ``core/planner.py``.
- **graceful degradation** (DESIGN.md §12): a model whose DevicePool has
  zero serving-eligible slots (every device quarantined or breaker-open)
  falls back to verified enclave-only dispatch (``trusted=True``) with a
  ``degraded`` flag in EngineStats/snapshot — the service keeps answering,
  bit-exact, at enclave speed. Degraded batches still age the pool's
  bench cooldowns; the moment a breaker half-opens (or a quarantined slot
  reaches probation) the engine routes a blinded dispatch again so the
  plane's probe can re-admit the device, and a successful probe clears
  the flag automatically.
- **draining shutdown**: ``close()`` stops admission, lets the batcher
  flush everything already queued (bounded by the plane's liveness
  timeouts), force-resolves anything left with an explicit ``shutdown``
  error, and only then stops session pools and device queues — no future
  is ever left pending and no dispatched work is orphaned.

Batches execute on the single batcher thread (the enclave executes one
batch at a time; JAX async dispatch still overlaps the session pool's
factor matmuls), so per-executor state needs no further locking.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Deque, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.attestation import Quote, measure_enclave
from repro.core.origami import OrigamiExecutor
from repro.core.plan import PlacementPlan
from repro.core.planner import PartitionPlan, PartitionPlanner
from repro.runtime.sessions import SessionPool
from repro.runtime.straggler import StepWatchdog


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_wait_ms: float = 5.0            # bucket age that forces a flush
    max_queue: int = 256                # admission-control bound (in-flight)
    default_deadline_s: Optional[float] = None
    session_pool_depth: int = 4
    # integrity (DESIGN.md §9): grant one fresh-session device retry after
    # a failed Freivalds check before the enclave recomputes, and after
    # ``quarantine_after`` consecutive failing batches stop offloading to
    # that model's backend at all (every dispatch runs trusted). After
    # ``probation_after`` trusted batches the backend earns one probation
    # probe: a verified offload dispatch — a clean probe restores offload
    # (a transient fault heals), a dirty one re-benches it (the seed
    # quarantined forever: one bad patch window cost a model its
    # accelerator for the life of the process). Models registered with a
    # DevicePool skip this path entirely — their quarantine/probation is
    # per-DEVICE (runtime/devices.py), so one bad part never benches the
    # whole model.
    integrity_retry: bool = True
    quarantine_after: int = 3
    probation_after: int = 8


@dataclasses.dataclass
class _Pending:
    model: str
    req: "Request"
    future: Future
    submit_t: float
    deadline_s: Optional[float]


@dataclasses.dataclass
class _ModelEntry:
    name: str
    cfg: ModelConfig
    executor: OrigamiExecutor
    quote: Quote
    pool: SessionPool
    plan: PartitionPlan                  # prefix-decision provenance
    placement: PlacementPlan = None      # the per-layer IR actually executed
    input_key: str = "images"
    input_dtype: Optional[str] = None    # cast unsealed floats (LM tokens)
    # integrity bookkeeping (batcher thread only — no locking needed)
    integrity_failures: int = 0          # total failed-check batches
    consec_failures: int = 0             # consecutive (resets on clean)
    quarantined: bool = False            # offload disabled, enclave serves
    trusted_streak: int = 0              # trusted batches since quarantine
    probations: int = 0                  # probe dispatches attempted
    restores: int = 0                    # probes that re-admitted offload
    # liveness / degradation bookkeeping (batcher thread only, §12)
    batches: int = 0                     # dispatches (the chaos clock)
    degraded: bool = False               # pool empty: enclave-only serving
    degradations: int = 0                # healthy -> degraded transitions
    recoveries: int = 0                  # degraded -> healthy transitions
    degraded_batches: int = 0            # batches served enclave-only
    chaos: Optional[object] = None       # runtime/chaos.ChaosController


class EngineStats:
    """Aggregate serving telemetry (queried live, not a snapshot)."""

    LAT_WINDOW = 4096

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.rejected = 0                # admission control
        self.expired = 0                 # deadline passed before dispatch
        self.mac_failures = 0
        self.batches = 0
        self.padded_slots = 0
        self.batched_requests = 0
        # integrity counters (DESIGN.md §9)
        self.verify_checks = 0           # Freivalds checks run
        self.verify_failures = 0         # checks that mismatched
        self.device_retries = 0          # fresh-session re-offloads
        self.recomputes = 0              # enclave recomputed a batch
        self.trusted_batches = 0         # dispatched under quarantine
        self.quarantines = 0             # backends quarantined
        self.probations = 0              # quarantine probes dispatched
        self.probation_restores = 0      # probes that restored offload
        # multi-device plane counters (DESIGN.md §11)
        self.shard_checks = 0            # shard-local Freivalds checks
        self.shard_failures = 0          # shard checks that mismatched
        self.shard_retries = 0           # single-shard re-dispatches
        self.shard_hedges = 0            # straggler duplicates launched
        self.shard_enclave = 0           # shards the enclave computed
                                         # (shares-mode recovery, or every
                                         # device exhausted)
        # liveness plane counters (DESIGN.md §12)
        self.shard_crashes = 0           # contained dispatch exceptions
        self.shard_timeouts = 0          # dispatches abandoned past deadline
        self.degradations = 0            # models entering enclave-only mode
        self.recoveries = 0              # models recovering a device
        self.degraded_batches = 0        # batches served enclave-only
        self.shutdown_drops = 0          # futures force-resolved at close
        self.start_t = time.monotonic()
        self.first_batch_t: Optional[float] = None
        self.latencies: Deque[float] = deque(maxlen=self.LAT_WINDOW)

    # -- recording ---------------------------------------------------------
    def record_batch(self, n_valid: int, pad: int) -> None:
        with self.lock:
            if self.first_batch_t is None:
                self.first_batch_t = time.monotonic()
            self.batches += 1
            self.batched_requests += n_valid
            self.padded_slots += pad

    def record_done(self, latency_s: float) -> None:
        with self.lock:
            self.completed += 1
            self.latencies.append(latency_s)

    # -- derived -----------------------------------------------------------
    @property
    def time_to_first_batch_s(self) -> Optional[float]:
        if self.first_batch_t is None:
            return None
        return self.first_batch_t - self.start_t

    def _quantile(self, q: float) -> Optional[float]:
        with self.lock:
            lat = sorted(self.latencies)
        if not lat:
            return None
        return lat[min(len(lat) - 1, int(q * len(lat)))]

    def p50_latency_s(self) -> Optional[float]:
        return self._quantile(0.50)

    def p95_latency_s(self) -> Optional[float]:
        return self._quantile(0.95)

    def snapshot(self, engine: "ServingEngine") -> Dict[str, object]:
        with self.lock:
            out = {
                "submitted": self.submitted, "completed": self.completed,
                "rejected": self.rejected, "expired": self.expired,
                "mac_failures": self.mac_failures, "batches": self.batches,
                "padded_slots": self.padded_slots,
                "batched_requests": self.batched_requests,
            }
        out["queue_depth"] = engine.queue_depth()
        out["time_to_first_batch_s"] = self.time_to_first_batch_s
        out["p50_latency_s"] = self.p50_latency_s()
        out["p95_latency_s"] = self.p95_latency_s()
        with self.lock:
            out["integrity"] = {
                "verify_checks": self.verify_checks,
                "verify_failures": self.verify_failures,
                "device_retries": self.device_retries,
                "recomputes": self.recomputes,
                "trusted_batches": self.trusted_batches,
                "quarantines": self.quarantines,
                "probations": self.probations,
                "probation_restores": self.probation_restores,
                "shard_checks": self.shard_checks,
                "shard_failures": self.shard_failures,
                "shard_retries": self.shard_retries,
                "shard_hedges": self.shard_hedges,
                "shard_enclave": self.shard_enclave,
            }
            out["liveness"] = {
                "shard_crashes": self.shard_crashes,
                "shard_timeouts": self.shard_timeouts,
                "degradations": self.degradations,
                "recoveries": self.recoveries,
                "degraded_batches": self.degraded_batches,
                "shutdown_drops": self.shutdown_drops,
            }
        # per-device health of every model running a sharded offload plane
        # (quarantine is per-DEVICE there, not per-model)
        out["devices"] = {
            name: e.executor.plane.snapshot()
            for name, e in engine.models.items()
            if e.executor.plane is not None}
        out["sessions"] = {name: e.pool.stats()
                           for name, e in engine.models.items()}
        # a persistently failing refill thread silently puts every factor
        # matmul back on the hot path — surface it at the top level too,
        # not just per-model under "sessions"
        out["refill_errors"] = sum(s["refill_errors"]
                                   for s in out["sessions"].values())
        # offload counters read the *blinded*-trace snapshot so a recovery
        # (trusted) trace can never pollute them; trusted_matmuls reads the
        # trusted-trace snapshot for the same reason
        out["matmuls"] = {
            name: {"mode": e.executor.mode,
                   "plan": e.executor.plan.digest[:12],
                   "device": e.executor.telemetry_blinded.device_matmuls,
                   "enclave": e.executor.telemetry_blinded.enclave_matmuls}
            for name, e in engine.models.items()}
        # the effective policy is the executor-wide one OR the plan's
        # per-step policies (a vopen plan verifies with integrity=None —
        # reporting "off" for it would contradict the nonzero
        # verify_checks above)
        out["models"] = {
            name: {"policy": (e.executor.integrity.mode
                              if e.executor.integrity.enabled else
                              "per-step" if e.executor.plan.has_step_policies
                              else "off"),
                   "plan": e.executor.plan.digest[:12],
                   "placements": e.executor.plan.placement_string,
                   "verify_ops": e.executor.telemetry_blinded.verify_ops,
                   "verify_flops": e.executor.telemetry_blinded.verify_flops,
                   "fold_matmuls": e.executor.telemetry_blinded.fold_matmuls,
                   "trusted_matmuls":
                       e.executor.telemetry_trusted.trusted_matmuls,
                   "integrity_failures": e.integrity_failures,
                   "quarantined": e.quarantined,
                   "probations": e.probations, "restores": e.restores,
                   "degraded": e.degraded,
                   "degradations": e.degradations,
                   "recoveries": e.recoveries,
                   "degraded_batches": e.degraded_batches}
            for name, e in engine.models.items()}
        return out


class ServingEngine:
    """Continuous micro-batching engine over a registry of enclaves."""

    def __init__(self, cfg: Optional[EngineConfig] = None, **kw):
        self.cfg = cfg or EngineConfig(**kw)
        self.models: Dict[str, _ModelEntry] = {}
        self.stats = EngineStats()
        self.watchdog = StepWatchdog()
        self._buckets: "OrderedDict[Tuple[str, Tuple[int, ...]], Deque[_Pending]]" = OrderedDict()
        self._futures: Dict[Tuple[str, int], Future] = {}   # (model, rid)
        self._in_flight = 0
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._flush_t = -1.0              # see flush()
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        # (model, rid) completion log, bounded like EngineStats.latencies —
        # an unbounded list would leak one tuple per request forever
        self.completion_order: Deque[Tuple[str, int]] = deque(
            maxlen=EngineStats.LAT_WINDOW)

    # -- registry ----------------------------------------------------------
    def register_model(self, name: str, cfg: ModelConfig, params, *,
                       mode: str = "origami", impl: str = "fused",
                       precompute: bool = True, input_key: str = "images",
                       input_dtype: Optional[str] = None,
                       partition: Optional[int] = None,
                       privacy_floor: Optional[float] = None,
                       planner: Optional[PartitionPlanner] = None,
                       leakage: Optional[Dict[int, float]] = None,
                       integrity=None, fault=None,
                       placement: Optional[PlacementPlan] = None,
                       devices=None, shard: str = "rows",
                       hedging: bool = True, liveness=None,
                       chaos=None) -> _ModelEntry:
        """Build an executor for ``name`` and admit it to the registry.

        ``placement``: an explicit per-layer PlacementPlan (core/plan.py)
        — overrides the mode/partition path entirely. Otherwise the
        partition point comes from, in order: the explicit ``partition``
        argument, the cost-model planner (when ``privacy_floor`` or
        ``planner`` is given), or the config's declared
        ``origami.tier1_layers``, and is compiled to a prefix plan.
        ``integrity``/``fault``: Freivalds verification policy and (for
        tests/chaos drills) a dishonest-device injector, forwarded to the
        executor (core/integrity.py, runtime/faults.py).
        ``devices``: a runtime/devices.DevicePool or a simulated slot
        count — attaches the sharded multi-device offload plane
        (parallel/offload_sharding.py) with default shard geometry
        ``shard`` and straggler ``hedging``; quarantine then becomes
        per-device (the pool's) instead of per-model. ``liveness``: a
        parallel/offload_sharding.LivenessConfig for the plane's
        timeout/backoff/breaker ladder. ``chaos``: a runtime/chaos
        ChaosController — its schedule is advanced once per dispatched
        batch of this model (the drill clock).
        """
        if isinstance(devices, int):
            from repro.runtime.devices import DevicePool
            devices = DevicePool(devices)
        if placement is not None:
            plan = PartitionPlan(cfg.name, placement.mode_label,
                                 placement.boundary, "explicit",
                                 None, {}, {}, ())
            executor = OrigamiExecutor(cfg, params, impl=impl,
                                       precompute=precompute,
                                       integrity=integrity, fault=fault,
                                       plan=placement, devices=devices,
                                       shard=shard, hedging=hedging,
                                       liveness=liveness)
            return self.register_executor(name, executor,
                                          input_key=input_key,
                                          input_dtype=input_dtype, plan=plan,
                                          chaos=chaos)
        if planner is None and privacy_floor is not None:
            planner = PartitionPlanner(privacy_floor=privacy_floor)
        if planner is not None or partition is not None:
            planner = planner or PartitionPlanner()
            plan = planner.plan(cfg, params, mode=mode, partition=partition,
                                leakage=leakage)
        else:
            plan = PartitionPlan(cfg.name, mode, cfg.origami.tier1_layers,
                                 "config", None, {}, {}, ())
        executor = OrigamiExecutor(cfg, params, mode=mode,
                                   partition=plan.partition, impl=impl,
                                   precompute=precompute,
                                   integrity=integrity, fault=fault,
                                   devices=devices, shard=shard,
                                   hedging=hedging, liveness=liveness)
        return self.register_executor(name, executor, input_key=input_key,
                                      input_dtype=input_dtype, plan=plan,
                                      chaos=chaos)

    def register_executor(self, name: str, executor: OrigamiExecutor, *,
                          input_key: str = "images",
                          input_dtype: Optional[str] = None,
                          plan: Optional[PartitionPlan] = None,
                          pool: Optional[SessionPool] = None,
                          chaos=None) -> _ModelEntry:
        """Admit a pre-built executor (the legacy server's compat path)."""
        assert name not in self.models, f"model {name!r} already registered"
        plan = plan or PartitionPlan(executor.cfg.name, executor.mode,
                                     executor.partition, "explicit",
                                     None, {}, {}, ())
        entry = _ModelEntry(
            name=name, cfg=executor.cfg, executor=executor,
            quote=measure_enclave(executor.cfg, executor.params,
                                  executor.partition,
                                  plan_digest=executor.plan.digest),
            pool=pool or SessionPool(executor,
                                     depth=self.cfg.session_pool_depth),
            plan=plan, placement=executor.plan,
            input_key=input_key, input_dtype=input_dtype)
        entry.chaos = chaos
        if chaos is not None:
            chaos.bind(
                pool=(executor.plane.pool if executor.plane is not None
                      else None),
                sessions=entry.pool)
        with self._lock:
            self.models[name] = entry
        return entry

    def attest(self, name: str) -> Quote:
        return self.models[name].quote

    # -- submission --------------------------------------------------------
    def submit(self, model: str, req: "Request",
               deadline_s: Optional[float] = None) -> Future:
        """Queue one sealed request; resolves to a ``Response``.

        Rejected (queue full / unknown model / duplicate in-flight rid)
        requests resolve immediately with ``ok=False`` — admission control
        is part of the response contract, not an exception path.
        """
        from repro.runtime.serving import Response
        fut: Future = Future()
        now = time.monotonic()
        deadline = (deadline_s if deadline_s is not None
                    else self.cfg.default_deadline_s)
        with self._cv:
            self.stats.submitted += 1
            entry = self.models.get(model)
            if entry is None or self._closed:
                self.stats.rejected += 1
                fut.set_result(Response(
                    req.rid, None, False, 0.0,
                    error="shutdown" if self._closed else "rejected"))
                return fut
            if (self._in_flight >= self.cfg.max_queue
                    or (model, req.rid) in self._futures):
                self.stats.rejected += 1
                fut.set_result(Response(req.rid, None, False, 0.0,
                                        error="rejected"))
                return fut
            self._futures[(model, req.rid)] = fut
            bucket_key = (model, tuple(req.shape))
            bucket = self._buckets.setdefault(bucket_key, deque())
            bucket.append(_Pending(model, req, fut, now, deadline))
            self._in_flight += 1
            self._ensure_thread()
            self._cv.notify_all()
        return fut

    def submit_many(self, model: str, reqs: List["Request"],
                    deadline_s: Optional[float] = None) -> List[Future]:
        return [self.submit(model, r, deadline_s) for r in reqs]

    def future_for(self, model: str, rid: int) -> Optional[Future]:
        """The in-flight future for (model, rid), if any."""
        with self._lock:
            return self._futures.get((model, rid))

    def flush(self) -> None:
        """Dispatch everything already queued without waiting for
        max_batch or the max_wait timer — for callers that know their
        request list is complete (e.g. the synchronous serve() wrapper,
        whose tail batch would otherwise idle out the timer). Requests
        submitted after the flush batch up normally."""
        with self._cv:
            self._flush_t = time.monotonic()
            self._cv.notify_all()

    def queue_depth(self) -> int:
        with self._lock:
            return self._in_flight

    # -- batcher -----------------------------------------------------------
    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._batch_loop,
                                            name="serving-engine-batcher",
                                            daemon=True)
            self._thread.start()

    def _ready_bucket(self, now: float):
        """The ready bucket (full or past max_wait) whose head request has
        waited longest — head age, not registry order, breaks ties so a
        persistently full hot bucket cannot starve a timer-expired trickle
        bucket. Also returns the earliest upcoming flush time across
        non-ready buckets (the cv wait timeout when nothing is ready)."""
        max_wait = self.cfg.max_wait_ms / 1e3
        best_key = best_head_t = None
        next_deadline = None
        for key, bucket in self._buckets.items():
            if not bucket:
                continue
            head_t = bucket[0].submit_t
            if (len(bucket) >= self.cfg.max_batch
                    or head_t + max_wait <= now
                    or head_t <= self._flush_t):
                if best_head_t is None or head_t < best_head_t:
                    best_key, best_head_t = key, head_t
            else:
                flush_at = head_t + max_wait
                next_deadline = (flush_at if next_deadline is None
                                 else min(next_deadline, flush_at))
        return best_key, next_deadline

    def _batch_loop(self) -> None:
        from repro.runtime.serving import Response
        while True:
            with self._cv:
                while True:
                    if self._closed and self._in_flight == 0:
                        return
                    now = time.monotonic()
                    key, next_flush = self._ready_bucket(now)
                    if key is not None:
                        break
                    timeout = (None if next_flush is None
                               else max(1e-4, next_flush - now))
                    self._cv.wait(timeout=timeout)
                bucket = self._buckets[key]
                batch: List[_Pending] = []
                expired: List[_Pending] = []
                while bucket and len(batch) < self.cfg.max_batch:
                    p = bucket.popleft()
                    if (p.deadline_s is not None
                            and now - p.submit_t > p.deadline_s):
                        expired.append(p)
                    else:
                        batch.append(p)
                self._in_flight -= len(batch) + len(expired)
                if not bucket:
                    self._buckets.pop(key, None)
            for p in expired:
                with self.stats.lock:
                    self.stats.expired += 1
                self._finish(p, Response(p.req.rid, None, False,
                                         time.monotonic() - p.submit_t,
                                         error="deadline_exceeded"))
            if batch:
                try:
                    self._dispatch(self.models[batch[0].model], batch)
                except Exception as exc:  # noqa: BLE001 — fail the batch,
                    for p in batch:       # not the engine
                        with self._lock:
                            self._futures.pop((p.model, p.req.rid), None)
                        if not p.future.done():
                            p.future.set_exception(exc)

    def _dispatch(self, entry: _ModelEntry, batch: List[_Pending]) -> None:
        """One enclave dispatch through the same sealed-batch primitive as
        the legacy server (runtime/serving.py) — single-sourcing the
        unseal -> MAC-filter -> pad -> infer -> seal pipeline is what keeps
        the engine bit-identical to its legacy oracle."""
        from repro.runtime.serving import Response, execute_sealed_batch
        # deadline re-check at dispatch time (DESIGN.md §12): formation and
        # dispatch are back-to-back on the batcher thread, but a slow
        # previous batch can age this one past its deadline — don't burn
        # device compute on work nobody can use, and tell the caller why
        now = time.monotonic()
        live: List[_Pending] = []
        for p in batch:
            if p.deadline_s is not None and now - p.submit_t > p.deadline_s:
                with self.stats.lock:
                    self.stats.expired += 1
                self._finish(p, Response(p.req.rid, None, False,
                                         now - p.submit_t,
                                         error="deadline_exceeded"))
            else:
                live.append(p)
        batch = live
        if not batch:
            return
        entry.batches += 1
        if entry.chaos is not None:
            # the drill clock: arm/disarm scripted faults for this batch
            # index (device injectors, refill faults, sealed-box corruption)
            entry.chaos.on_batch(entry.batches - 1,
                                 requests=[p.req for p in batch])
        self.watchdog.start_step()
        # probation (poolless models): a quarantined backend that has
        # served ``probation_after`` trusted batches earns ONE verified
        # offload probe — clean restores offload, dirty re-benches it.
        # The probe routes REAL client traffic back to a convicted
        # backend, so it is only safe when every offloaded op is checked
        # (the retry/recompute path then recovers any corruption before
        # sealing): a "sampled" policy would let unchecked ops carry
        # corrupt logits to clients AND could restore the backend off a
        # lucky probe, so such models stay benched (the pre-probation
        # behavior). Models with a DevicePool never take this path:
        # their quarantine/probation is per-device, and shards are
        # always checked.
        per_device = entry.executor.plane is not None
        probe = (entry.quarantined and not per_device
                 and entry.executor.integrity.mode == "full"
                 and entry.trusted_streak >= self.cfg.probation_after)
        if probe:
            entry.probations += 1
            with self.stats.lock:
                self.stats.probations += 1
        # graceful degradation (DESIGN.md §12): zero serving-eligible
        # devices (every slot quarantined or breaker-open) means a blinded
        # dispatch has nowhere to go — serve this batch verified
        # enclave-only instead. The moment the pool has a probe candidate
        # (half-open breaker or probation-ripe quarantine) the blinded
        # path runs again so the plane can route the probe: shards are
        # always verified, so a recovery attempt is safe with real traffic
        # (un-routable shards fall to the enclave inside the op).
        degrade_trusted = False
        if per_device:
            dpool = entry.executor.plane.pool
            can_probe = (dpool.breaker_candidate() is not None
                         or dpool.probe_candidate() is not None)
            if dpool.n_available() == 0 and not can_probe:
                degrade_trusted = True
                entry.degraded_batches += 1
                with self.stats.lock:
                    self.stats.degraded_batches += 1
                # enclave-only batches still age the pool's cooldowns —
                # otherwise a fully-benched pool could never reach its
                # half-open / probation probe state and the degradation
                # would be permanent
                dpool.begin_dispatch()
        boxes, n_valid, pad, integ = execute_sealed_batch(
            entry.executor, [p.req for p in batch],
            input_key=entry.input_key, max_batch=self.cfg.max_batch,
            session_key=entry.pool.acquire,   # lazy: only consumed if a
            input_dtype=entry.input_dtype,    # valid request reaches infer
            trusted=(entry.quarantined and not probe) or degrade_trusted,
            retry_device=self.cfg.integrity_retry)
        if n_valid:
            self.stats.record_batch(n_valid, pad)
        with self.stats.lock:
            self.stats.mac_failures += sum(b is None for b in boxes)
            self.stats.verify_checks += integ.checks
            self.stats.verify_failures += integ.failures
            self.stats.device_retries += integ.retried
            self.stats.recomputes += integ.recomputed
            self.stats.trusted_batches += integ.trusted
            self.stats.shard_checks += integ.shard_checks
            self.stats.shard_failures += integ.shard_failures
            self.stats.shard_retries += integ.shard_retries
            self.stats.shard_hedges += integ.shard_hedges
            self.stats.shard_enclave += integ.shard_enclave
            self.stats.shard_crashes += integ.shard_crashes
            self.stats.shard_timeouts += integ.shard_timeouts
        if n_valid and entry.quarantined and not per_device:
            if probe:
                if integ.checks and not integ.failures:
                    entry.quarantined = False
                    entry.consec_failures = 0
                    entry.restores += 1
                    with self.stats.lock:
                        self.stats.probation_restores += 1
                entry.trusted_streak = 0     # clean: healthy again; dirty:
            else:                            # restart the probation clock
                entry.trusted_streak += 1
        elif n_valid and not entry.quarantined and not per_device:
            # quarantine bookkeeping (batcher thread owns entry state): a
            # backend that keeps failing its Freivalds checks stops being
            # offloaded to until probation re-admits it.
            if integ.flagged:
                entry.integrity_failures += 1
                entry.consec_failures += 1
                if entry.consec_failures >= self.cfg.quarantine_after:
                    entry.quarantined = True
                    entry.trusted_streak = 0
                    with self.stats.lock:
                        self.stats.quarantines += 1
            elif integ.checks:
                entry.consec_failures = 0
        elif n_valid and per_device and integ.flagged:
            entry.integrity_failures += 1    # visibility only: recovery and
                                             # health are per-device (pool)
        if per_device:
            # degraded-mode state machine (§12): the flag tracks the pool's
            # serving-eligible count, transitions counted right after the
            # dispatch that caused them (a breaker opening mid-batch
            # degrades here; a successful half-open probe recovers here)
            available = entry.executor.plane.pool.n_available() > 0
            if entry.degraded and available:
                entry.degraded = False
                entry.recoveries += 1
                with self.stats.lock:
                    self.stats.recoveries += 1
            elif not entry.degraded and not available:
                entry.degraded = True
                entry.degradations += 1
                with self.stats.lock:
                    self.stats.degradations += 1
        self.watchdog.end_step()
        for p, box in zip(batch, boxes):
            self._finish(p, Response(p.req.rid, box, box is not None,
                                     time.monotonic() - p.submit_t,
                                     flagged=integ.flagged
                                     and box is not None,
                                     error=None if box is not None
                                     else "mac_failed"))

    def _finish(self, p: _Pending, resp) -> None:
        if resp.ok:
            self.stats.record_done(resp.latency_s)
        with self._lock:
            self.completion_order.append((p.model, p.req.rid))
            self._futures.pop((p.model, p.req.rid), None)
        # done-guard: the forced shutdown sweep (close) may have resolved
        # this future already — set_result on a done future raises and
        # would kill the batcher thread
        if not p.future.done():
            p.future.set_result(resp)

    def snapshot(self) -> Dict[str, object]:
        """Aggregate serving telemetry (EngineStats.snapshot shorthand)."""
        return self.stats.snapshot(self)

    # -- lifecycle ---------------------------------------------------------
    def drain(self, timeout_s: float = 60.0) -> bool:
        """Block until the queue is empty (True) or timeout (False)."""
        end = time.monotonic() + timeout_s
        while time.monotonic() < end:
            if self.queue_depth() == 0:
                return True
            time.sleep(0.002)
        return self.queue_depth() == 0

    def close(self, drain_s: float = 30.0) -> None:
        """Graceful shutdown (DESIGN.md §12): stop admitting, let the
        batcher flush everything already queued (the plane's liveness
        timeouts bound how long a wedged device can stall that), then
        force-resolve anything still pending with an explicit ``shutdown``
        error — **every submitted future resolves** — and only then stop
        the session pools and drain the device queues."""
        from repro.runtime.serving import Response
        with self._cv:
            self._closed = True
            # the tail bucket must not idle out its max_wait timer while
            # the batcher is the only thing left running
            self._flush_t = time.monotonic()
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=drain_s)
        # forced resolution: anything the batcher left behind (it died, or
        # the drain timed out) resolves NOW — a shutdown may abandon work,
        # never a caller
        leftovers: List[_Pending] = []
        with self._cv:
            for bucket in self._buckets.values():
                leftovers.extend(bucket)
            self._buckets.clear()
            self._in_flight = 0
        for p in leftovers:
            with self.stats.lock:
                self.stats.shutdown_drops += 1
            self._finish(p, Response(p.req.rid, None, False,
                                     time.monotonic() - p.submit_t,
                                     error="shutdown"))
        for entry in self.models.values():
            entry.pool.close()
            if entry.executor.plane is not None:
                entry.executor.plane.pool.close(drain=True)
