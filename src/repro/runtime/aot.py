"""Compile-once serving: AOT executable cache for the plan interpreter.

The ROADMAP throughput item starts from a measured fact (PR 8's phase
profiler): the engine's 7.7 s time-to-first-batch was almost entirely
``jax.jit`` trace+compile paid on the first request's critical path, per
(trace-kind, plan digest, shape bucket) signature. This module moves that
cost off the request path twice over:

- **in-process**: every executable the OrigamiExecutor runs is compiled
  through ``CompileCache.compile_once`` — an explicitly timed
  ``jax.jit(...).lower(...).compile()`` (never an implicit first-call
  compile), memoized per cache key and serialized by a per-key lock so
  concurrent ``register_model`` / mixed-shape submits compile each
  (plan digest, shape bucket) exactly once.
- **across processes**: with a ``cache_dir``, compiled executables are
  persisted via ``jax.experimental.serialize_executable`` and reloaded on
  the next boot — the first request of a *restarted* server never pays
  compile either.

Cache key (DESIGN.md §15): ``sha256(plan digest, trace kind, input-shape
signature, backend, jax version, code version)``. The plan digest pins
*what* the executable computes (placement IR + weights provenance); the
shape signature pins the padded bucket; backend + jax version pin the
lowering; the code version — a content hash over the repro source that
shapes traced programs — invalidates stale entries when the interpreter
itself changes (a stale executable would silently serve an old program:
fail closed to a fresh compile). A corrupted or stale payload is counted
(``aot.disk_errors``) and falls back to a fresh compile, never to a
failed request.

Counters (MetricsRegistry, §13 names): ``aot.compiles`` /
``aot.disk_hits`` / ``aot.memo_hits`` / ``aot.disk_errors`` /
``aot.stores``; gauges ``aot.compile_seconds`` (total) and
``aot.request_compile_seconds`` (the subset paid on the request path —
zero when registration warmed every bucket, which is what makes
``ttfb_warm_s`` visible in EngineStats).
"""
from __future__ import annotations

import hashlib
import os
import pathlib
import pickle
import tempfile
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

try:  # the serializer moved between jax versions; degrade to memo-only
    from jax.experimental import serialize_executable as _sx
except Exception:  # pragma: no cover - depends on jax build
    _sx = None

_PAYLOAD_VERSION = 1

# source roots whose content shapes the traced program — a change in any
# of them must invalidate persisted executables (core: plan interpreter +
# blinding math; kernels: the field matmuls; models: the layer algebra)
_CODE_ROOTS = ("core", "kernels", "models")

_code_version_cache: Optional[str] = None
_code_version_lock = threading.Lock()


def code_version() -> str:
    """Content hash over the source that determines traced programs.

    Hashed once per process (sorted walk — deterministic across runs).
    """
    global _code_version_cache
    with _code_version_lock:
        if _code_version_cache is not None:
            return _code_version_cache
        h = hashlib.sha256()
        pkg_root = pathlib.Path(__file__).resolve().parent.parent
        for root in _CODE_ROOTS:
            base = pkg_root / root
            if not base.is_dir():
                continue
            for path in sorted(base.rglob("*.py")):
                h.update(path.relative_to(pkg_root).as_posix().encode())
                h.update(path.read_bytes())
        _code_version_cache = h.hexdigest()[:16]
        return _code_version_cache


def shape_signature(tree: Any) -> str:
    """Stable string signature of a pytree's avals (shape + dtype)."""
    leaves = jax.tree_util.tree_leaves(tree)
    parts = []
    for leaf in leaves:
        shape = tuple(getattr(leaf, "shape", ()))
        dtype = getattr(leaf, "dtype", type(leaf).__name__)
        parts.append(f"{'x'.join(map(str, shape))}:{dtype}")
    return ";".join(parts)


class CompileCache:
    """Memoized + optionally disk-persistent executable cache.

    One instance per engine (``ServingEngine.aot``), shared by every
    registered executor: the in-process memo deduplicates identical
    (digest, kind, bucket) compiles across executors, the per-key locks
    make concurrent compiles exactly-once, and the counters land in the
    engine's MetricsRegistry.
    """

    def __init__(self, cache_dir: Optional[str] = None,
                 registry=None) -> None:
        self.cache_dir = pathlib.Path(cache_dir) if cache_dir else None
        self.registry = registry
        self._memo: Dict[str, Any] = {}
        self._lock = threading.Lock()
        self._key_locks: Dict[str, threading.Lock] = {}
        # local counters mirror the registry so the cache is usable (and
        # testable) without an engine attached
        self.counters: Dict[str, int] = {
            "compiles": 0, "memo_hits": 0, "disk_hits": 0,
            "disk_errors": 0, "stores": 0, "exec_fallbacks": 0}
        self.compile_seconds = 0.0
        self.request_compile_seconds = 0.0
        # registration-time warmups flip this on so compile seconds are
        # attributed to warmup, not the request path (thread-local: the
        # batcher/device threads must never inherit a warmup flag from a
        # concurrent register_model on the main thread)
        self._tls = threading.local()

    # -- warmup attribution ------------------------------------------------
    class _WarmupScope:
        def __init__(self, cache: "CompileCache") -> None:
            self.cache = cache

        def __enter__(self) -> None:
            self.cache._tls.warmup = getattr(
                self.cache._tls, "warmup", 0) + 1

        def __exit__(self, *exc) -> None:
            self.cache._tls.warmup -= 1

    def warmup_scope(self) -> "CompileCache._WarmupScope":
        """Context manager: compiles inside it count as warmup, not
        request-path, in the ``aot.request_compile_seconds`` split."""
        return CompileCache._WarmupScope(self)

    @property
    def in_warmup(self) -> bool:
        return getattr(self._tls, "warmup", 0) > 0

    # -- keys --------------------------------------------------------------
    def entry_key(self, plan_digest: str, kind: str, args: Any) -> str:
        """The §15 cache key: plan digest + trace kind + shape signature +
        backend + jax version + code version, hashed."""
        raw = "|".join((str(plan_digest), str(kind), shape_signature(args),
                        jax.default_backend(), jax.__version__,
                        code_version()))
        return hashlib.sha256(raw.encode()).hexdigest()

    # -- counters ----------------------------------------------------------
    def _bump(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n
        if self.registry is not None:
            self.registry.inc(f"aot.{name}", n)

    def _add_seconds(self, dt: float) -> None:
        with self._lock:
            self.compile_seconds += dt
            if not self.in_warmup:
                self.request_compile_seconds += dt
        if self.registry is not None:
            self.registry.gauge("aot.compile_seconds",
                                self.compile_seconds)
            self.registry.gauge("aot.request_compile_seconds",
                                self.request_compile_seconds)

    def record_fallback(self) -> None:
        """An AOT executable raised at call time and the executor fell
        back to the implicit-jit path — count it (``aot.exec_fallbacks``)."""
        self._bump("exec_fallbacks")

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            out: Dict[str, Any] = dict(self.counters)
            out["compile_seconds"] = round(self.compile_seconds, 6)
            out["request_compile_seconds"] = round(
                self.request_compile_seconds, 6)
            out["persistent"] = self.cache_dir is not None
        return out

    # -- disk layer --------------------------------------------------------
    def _path(self, key: str) -> Optional[pathlib.Path]:
        if self.cache_dir is None:
            return None
        return self.cache_dir / f"{key}.xc"

    def _disk_load(self, key: str) -> Optional[Any]:
        path = self._path(key)
        if path is None or _sx is None or not path.exists():
            return None
        try:
            with open(path, "rb") as fh:
                doc = pickle.load(fh)
            if (doc.get("v") != _PAYLOAD_VERSION
                    or doc.get("jax") != jax.__version__
                    or doc.get("code") != code_version()):
                raise ValueError("stale compile-cache entry")
            compiled = _sx.deserialize_and_load(
                doc["payload"], doc["in_tree"], doc["out_tree"])
            self._bump("disk_hits")
            return compiled
        except Exception:  # noqa: BLE001 — corrupt/stale/incompatible:
            # fail closed to a fresh compile, never to a failed request
            self._bump("disk_errors")
            return None

    def _disk_store(self, key: str, compiled: Any) -> None:
        path = self._path(key)
        if path is None or _sx is None:
            return
        try:
            payload, in_tree, out_tree = _sx.serialize(compiled)
            doc = {"v": _PAYLOAD_VERSION, "jax": jax.__version__,
                   "code": code_version(), "payload": payload,
                   "in_tree": in_tree, "out_tree": out_tree}
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                                       suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as fh:
                    pickle.dump(doc, fh)
                os.replace(tmp, path)   # atomic: readers never see partials
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
            self._bump("stores")
        except Exception:  # noqa: BLE001 — persistence is an optimization;
            # a full disk or unpicklable tree must not fail serving
            self._bump("disk_errors")

    # -- the one compile path ----------------------------------------------
    def compile_once(self, key: str, build: Callable[[], Any],
                     on_disk_hit: Optional[Callable[[], None]] = None
                     ) -> Tuple[Any, bool]:
        """Return ``(compiled, fresh)`` for ``key`` — memo, then disk,
        then a timed fresh ``build()`` (which must do lower+compile).

        Per-key locking makes concurrent callers exactly-once: the loser
        of the race finds the winner's memo entry. ``on_disk_hit`` runs
        after a successful disk load (the executor uses it to replay
        trace-time telemetry side effects that a deserialized executable
        skips).
        """
        with self._lock:
            compiled = self._memo.get(key)
            if compiled is None:
                klock = self._key_locks.setdefault(key, threading.Lock())
        if compiled is not None:
            self._bump("memo_hits")
            return compiled, False
        with klock:
            with self._lock:
                compiled = self._memo.get(key)
            if compiled is not None:
                self._bump("memo_hits")
                return compiled, False
            compiled = self._disk_load(key)
            if compiled is not None:
                if on_disk_hit is not None:
                    on_disk_hit()
                with self._lock:
                    self._memo[key] = compiled
                return compiled, False
            t0 = time.monotonic()
            compiled = build()
            self._add_seconds(time.monotonic() - t0)
            self._bump("compiles")
            self._disk_store(key, compiled)
            with self._lock:
                self._memo[key] = compiled
            return compiled, True


def bucket_ladder(max_batch: int) -> Tuple[int, ...]:
    """The shape-bucket ladder: powers of two up to (and including)
    ``max_batch`` — 1/2/4/max for the default engine config."""
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


def bucket_for(n: int, max_batch: int) -> int:
    """Smallest ladder bucket holding ``n`` requests (occupancy-driven
    padding: a lone request pads to 1, not to max_batch)."""
    assert 1 <= n <= max_batch, (n, max_batch)
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)
