"""Fault-tolerant checkpointing: atomic, async, reshard-on-load.

Format: one ``.npz`` per checkpoint (flattened key/value arrays) + a JSON
manifest (step, config digest, tree structure, mesh shape). Writes go to a
temp directory that is atomically renamed — a crash mid-write can never
corrupt the latest checkpoint. ``AsyncCheckpointer`` overlaps serialization
with the next training step. ``load(..., shardings=...)`` re-lays arrays
out for a *different* mesh than they were saved from — the elastic-restart
path (runtime/elastic.py, tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str | Path, step: int, tree, *, meta: Optional[dict] = None,
         keep: int = 3) -> Path:
    """Atomic synchronous save. Returns the checkpoint path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:010d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        flat = _flatten(tree)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        # npz can't represent ml_dtypes (bfloat16 etc.): store a samesize
        # integer view and record the true dtype in the manifest
        dtypes = {}
        for k, a in list(arrays.items()):
            if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
                dtypes[k] = a.dtype.name
                arrays[k] = a.view(np.uint8).reshape(a.shape + (-1,)) \
                    if a.dtype.itemsize != 2 else a.view(np.uint16)
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "dtypes": dtypes,
            "treedef": str(jax.tree_util.tree_structure(tree)),
            "time": time.time(),
            "meta": meta or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(ckpt_dir.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def load(ckpt_dir: str | Path, tree_like, *, step: Optional[int] = None,
         shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedSharding — arrays are
    placed directly onto the (possibly different) target mesh, which is the
    reshard-on-load path used for elastic restarts.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints in {ckpt_dir}"
    path = ckpt_dir / f"step_{step:010d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")

    flat_like = _flatten(tree_like)
    assert set(flat_like.keys()) == set(manifest["keys"]), (
        "checkpoint/tree structure mismatch")
    flat_sh = _flatten(shardings) if shardings is not None else None
    dtypes = manifest.get("dtypes", {})

    import ml_dtypes  # jax dependency; bfloat16 et al.

    leaves_by_key = {}
    for key, like in flat_like.items():
        arr = data[key]
        if key in dtypes:
            true_dt = np.dtype(getattr(ml_dtypes, dtypes[key]))
            if arr.dtype == np.uint8:
                arr = arr.view(true_dt).reshape(arr.shape[:-1])
            else:
                arr = arr.view(true_dt)
        if flat_sh is not None:
            leaves_by_key[key] = jax.device_put(arr, flat_sh[key])
        else:
            leaves_by_key[key] = jax.numpy.asarray(arr)

    paths, treedef = zip(*jax.tree_util.tree_flatten_with_path(tree_like)[0]) \
        if flat_like else ((), None)
    treedef = jax.tree_util.tree_structure(tree_like)
    ordered = ["/".join(str(p) for p in path) for path, _ in
               jax.tree_util.tree_flatten_with_path(tree_like)[0]]
    return (jax.tree_util.tree_unflatten(
        treedef, [leaves_by_key[k] for k in ordered]),
        manifest)


class AsyncCheckpointer:
    """Background-thread checkpoint writer (single in-flight save).

    ``save`` transfers arrays to host synchronously (cheap vs. step time)
    and serializes on the worker thread; ``wait`` joins before exit or the
    next save. Failure in the worker is re-raised on the next call.
    """

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, meta: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, meta=meta,
                     keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
