"""Elastic scaling: survive device loss by re-meshing + reshard-on-load.

Single-controller JAX cannot hot-swap devices mid-step; the production
pattern (used by MaxText/Pathways deployments) is checkpoint-restart:

    1. a step deadline or heartbeat miss marks the job degraded
       (runtime/straggler.py),
    2. the launcher re-enumerates healthy hosts and picks the largest
       feasible mesh (``plan_degraded_mesh``),
    3. the job restarts, loading the latest checkpoint **onto the new
       mesh** (checkpoint.load(..., shardings=new_plan)) and rescaling
       the data pipeline.

Everything here is exercised for real in tests/test_elastic.py with fake
CPU devices: save on a (4,) mesh, "lose" two devices, resume bitwise on a
(2,) mesh.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class MeshCandidate:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    devices_needed: int


def plan_degraded_mesh(healthy_devices: int,
                       prefer_model: int = 16) -> MeshCandidate:
    """Largest (data, model) mesh that fits the surviving devices.

    Keeps the model axis at the largest power-of-two divisor ≤ prefer_model
    (TP degree must divide weight dims), spends the rest on data. Batch is
    rescaled by the launcher to keep per-device batch constant.
    """
    assert healthy_devices >= 1
    model = 1
    while model * 2 <= min(prefer_model, healthy_devices):
        model *= 2
    data = healthy_devices // model
    return MeshCandidate(shape=(data, model), axes=("data", "model"),
                         devices_needed=data * model)


def remesh(candidate: MeshCandidate, devices: Optional[Sequence] = None):
    devs = list(devices or jax.devices())[: candidate.devices_needed]
    import numpy as np
    arr = np.array(devs).reshape(candidate.shape)
    return jax.sharding.Mesh(arr, candidate.axes)


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-device batch constant across the re-mesh."""
    per_dev = max(global_batch // old_data, 1)
    return per_dev * new_data
