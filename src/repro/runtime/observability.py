"""Unified metrics registry: every pipeline counter under ONE lock.

Before this module the repo had five ad-hoc stat surfaces — ``Telemetry``
(core/slalom.py), ``EngineStats`` (runtime/engine.py), ``ShardReport``
(parallel/offload_sharding.py), ``IntegrityTotals`` (runtime/serving.py)
and the liveness/breaker counters scattered over ``DeviceSlot`` — each
with its own locking story (or none: ``EngineStats`` counters were bumped
with bare ``+=`` from three threads). ``MetricsRegistry`` replaces the
*accounting* layer: named counters, gauges and bounded histograms behind a
single re-entrant lock, so a multi-field update (``inc_many``) is atomic
and a ``snapshot()`` is a consistent cut. The legacy dataclasses survive
as facades/feeders (tests and call sites keep their spelling) but the
numbers live here, under names shared by ``engine.snapshot()["metrics"]``,
the benches, and the trace plane (DESIGN.md §13 fixes the naming scheme:
``<surface>.<counter>``, dotted, lowercase — e.g. ``engine.submitted``,
``integrity.verify_checks``, ``shard.retries``, ``liveness.degradations``,
``device.<model>.<idx>.ewma_latency_s``).

Metrics carry **aggregates only** — counts, byte totals, flop totals,
latency quantiles. Nothing request-identifying and no payload bytes ever
enter the registry, so exporting a snapshot is redaction-safe by
construction (values are required to be plain numbers).
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Any, Dict, Iterable, Optional

HIST_WINDOW = 4096      # per-histogram sample bound (ring buffer)


def nearest_rank(sorted_vals, q: float) -> float:
    """Nearest-rank quantile over an ascending list: the ceil(q*n)-th
    order statistic, clamped to [1, n] (q=0 -> min, q=1 -> max; n=1 ->
    the only sample for every q). The ONE implementation both
    ``quantile()`` and ``snapshot()`` use — they used to inline the same
    formula separately, which is exactly how rank-math drift starts."""
    n = len(sorted_vals)
    if not n:
        return 0.0
    return sorted_vals[min(n - 1, max(0, math.ceil(q * n) - 1))]


class MetricsRegistry:
    """Counters / gauges / histograms behind one RLock.

    The lock is re-entrant and exposed as ``.lock`` so legacy code that
    did ``with stats.lock: stats.x += 1; stats.y += 1`` keeps its
    multi-field atomicity when ``stats`` became a facade whose property
    setters each take the same lock.
    """

    def __init__(self) -> None:
        self.lock = threading.RLock()
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, deque] = {}

    # -- counters ----------------------------------------------------------
    def inc(self, name: str, n: int = 1) -> int:
        with self.lock:
            v = self._counters.get(name, 0) + n
            self._counters[name] = v
            return v

    def inc_many(self, **deltas: int) -> None:
        """Atomically apply several counter deltas (one lock acquisition)."""
        with self.lock:
            for name, n in deltas.items():
                if n:
                    self._counters[name] = self._counters.get(name, 0) + n

    def set_counter(self, name: str, value: int) -> None:
        with self.lock:
            self._counters[name] = value

    def get(self, name: str, default: int = 0) -> int:
        with self.lock:
            return self._counters.get(name, default)

    # -- gauges ------------------------------------------------------------
    def gauge(self, name: str, value: float) -> None:
        with self.lock:
            self._gauges[name] = value

    def gauges(self, mapping: Dict[str, float]) -> None:
        with self.lock:
            self._gauges.update(mapping)

    def get_gauge(self, name: str, default: float = 0.0) -> float:
        with self.lock:
            return self._gauges.get(name, default)

    # -- histograms --------------------------------------------------------
    def observe(self, name: str, value: float) -> None:
        with self.lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = deque(maxlen=HIST_WINDOW)
            h.append(float(value))

    def hist_values(self, name: str) -> list:
        with self.lock:
            return list(self._hists.get(name, ()))

    def quantile(self, name: str, q: float) -> float:
        return nearest_rank(sorted(self.hist_values(name)), q)

    # -- export ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Consistent cut of every metric: counters and gauges verbatim,
        histograms summarized (count/mean/p50/p95/p99/max)."""
        with self.lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: list(v) for k, v in self._hists.items()}
        out: Dict[str, Any] = {"counters": counters, "gauges": gauges,
                               "histograms": {}}
        for name, vals in hists.items():
            sv = sorted(vals)
            n = len(sv)
            summ = {"count": n}
            if n:
                summ.update(mean=sum(sv) / n,
                            p50=nearest_rank(sv, 0.50),
                            p95=nearest_rank(sv, 0.95),
                            p99=nearest_rank(sv, 0.99),
                            max=sv[-1])
            out["histograms"][name] = summ
        return out

    def reset(self, prefix: Optional[str] = None) -> None:
        """Drop metrics (all, or those under a dotted prefix) — bench use."""
        with self.lock:
            if prefix is None:
                self._counters.clear()
                self._gauges.clear()
                self._hists.clear()
                return
            for store in (self._counters, self._gauges, self._hists):
                for k in [k for k in store if k.startswith(prefix)]:
                    del store[k]


def sync_struct(registry: MetricsRegistry, prefix: str,
                obj: Any, fields: Iterable[str]) -> None:
    """Publish a stats dataclass's numeric fields as gauges under
    ``<prefix>.<field>`` — the bridge that makes ``Telemetry`` /
    ``ShardReport`` / session stats readable from the one registry at
    snapshot time without rewriting their producers."""
    vals = {}
    for f in fields:
        v = getattr(obj, f, None)
        if isinstance(v, bool) or v is None:
            v = int(bool(v))
        if isinstance(v, (int, float)):
            vals[f"{prefix}.{f}"] = v
    registry.gauges(vals)
