"""Performance attribution: critical-path phase profiles + flight recorder.

PR 7's trace plane (core/tracing.py) records *raw* span trees; the
ROADMAP's throughput work needs an *answer*: which phase owns a request's
latency, and which phase owns the 22 s time-to-first-batch. This module
folds completed request trees into a fixed phase taxonomy
(``PHASES``: queue_wait, compile, unseal, blind, dispatch_wait,
device_compute, verify, unblind, seal — plus ``other`` for engine
bookkeeping no phase claims) with two decompositions per tree:

- **critical** (``critical_s``): every instant of the request's wall is
  attributed to exactly ONE span — the deepest child covering it, parents
  keep only their uncovered self-time — so the per-phase criticals sum to
  the request wall exactly (the invariant the acceptance bar keys on).
- **total** (``total_s``): raw span durations summed per phase. Under
  parallel shard dispatch total > critical; the gap IS the measured
  parallelism.

Compile attribution: ``OrigamiExecutor.infer`` stamps its ambient infer
span with ``first_call=True`` the first time a (trace-kind, plan-digest,
shape) signature is seen — the call that pays ``jax.jit`` tracing +
compilation. The profiler prices compile as the first-call infer duration
*minus* the warm median for the same profile key (clamped at >= 0) and
moves it out of ``device_compute``, so cold-start cost has a named owner
instead of inflating steady-state device time.

``FlightRecorder`` is the post-mortem side: an always-on bounded ring of
redaction-enforced events (``core/tracing.redact`` — arrays/bytes raise
before storage, same fail-closed contract as spans). On a trigger
(quarantine, breaker-open, degradation, verify-failure) it dumps a bundle
of the last events + the tracer's span tail + metric counter deltas since
the previous dump — everything an operator needs to reconstruct *why*,
nothing a client sent (the bundle passes the PR 7 secret byte-scan).
"""
from __future__ import annotations

import json
import pathlib
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.tracing import Span, Tracer, redact

# the fixed taxonomy (DESIGN.md §14) — every span name maps to exactly one.
# ``compile_aot`` is ahead-of-time compilation at register_model time
# (runtime/aot.py): it happens *before* any request exists, so it shows up
# in registration-scoped spans and engine counters rather than request
# trees — but it owns a phase so the taxonomy can say where cold-start
# seconds went once requests stop paying them.
PHASES = ("queue_wait", "compile", "compile_aot", "unseal", "blind",
          "dispatch_wait", "device_compute", "verify", "unblind", "seal",
          "other")

# span name -> phase. ``shard.matmul`` keeps only its *self*-time (host
# fan-out/join around the dispatches) -> dispatch_wait; the dispatches
# themselves are device_compute. ``op.blinded`` self-time is the
# unblind + re-encode work around the device call -> unblind.
_NAME_PHASE = {
    "queue": "queue_wait",
    "compile.aot": "compile_aot",
    "unseal": "unseal",
    "seal": "seal",
    "session.acquire": "blind",
    "kernel.blind_encode": "blind",
    "kernel.fused_blind_matmul": "device_compute",
    "kernel.limb_matmul": "device_compute",
    "kernel.unblind": "unblind",
    "kernel.fold": "verify",
    "op.blinded": "unblind",
    "op.trusted": "device_compute",
    "shard.matmul": "dispatch_wait",
    "shard.dispatch": "device_compute",
    "shard.enclave": "device_compute",
    "infer": "device_compute",
    "plan.segment": "device_compute",
    "verify": "verify",
    "batch": "other",
    "request": "other",
}

_PROFILE_WINDOW = 512           # per-profile bounded sample ring


def phase_of(name: str) -> str:
    return _NAME_PHASE.get(name, "other")


def _merge_intervals(iv: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Union of (t0, t1) intervals — overlapping children (parallel shard
    dispatches) must not double-claim the parent's time."""
    if not iv:
        return []
    iv = sorted(iv)
    out = [list(iv[0])]
    for lo, hi in iv[1:]:
        if lo <= out[-1][1]:
            out[-1][1] = max(out[-1][1], hi)
        else:
            out.append([lo, hi])
    return [(lo, hi) for lo, hi in out]


def _intersect(a: List[Tuple[float, float]],
               b: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Intersection of two sorted merged interval lists."""
    out = []
    i = j = 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if lo < hi:
            out.append((lo, hi))
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return out


def _subtract(iv: List[Tuple[float, float]],
              sub: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """``iv`` minus ``sub`` (both sorted merged interval lists)."""
    out = []
    for lo, hi in iv:
        cur = lo
        for slo, shi in sub:
            if shi <= cur:
                continue
            if slo >= hi:
                break
            if slo > cur:
                out.append((cur, min(slo, hi)))
            cur = max(cur, shi)
            if cur >= hi:
                break
        if cur < hi:
            out.append((cur, hi))
    return out


def _measure(iv: List[Tuple[float, float]]) -> float:
    return sum(hi - lo for lo, hi in iv)


@dataclass
class TreeDecomposition:
    """One folded request tree."""
    key: Tuple[str, str, str]            # (model, plan digest, shape bucket)
    wall_s: float
    critical_s: Dict[str, float]         # phase -> path-attributed seconds
    total_s: Dict[str, float]            # phase -> raw span-duration sum
    first_call: bool                     # tree contains a first-call infer
    infer_s: float                       # summed infer-span durations
    quantities: Dict[str, float]         # measured cost-model features


@dataclass
class PhaseProfile:
    """Accumulated decompositions for one (model, digest, shape) key."""
    key: Tuple[str, str, str]
    count: int = 0
    critical_s: Dict[str, float] = field(
        default_factory=lambda: {p: 0.0 for p in PHASES})
    total_s: Dict[str, float] = field(
        default_factory=lambda: {p: 0.0 for p in PHASES})
    wall_s: float = 0.0
    walls: deque = field(default_factory=lambda: deque(maxlen=_PROFILE_WINDOW))
    # infer-span durations split cold/warm: compile = first-call excess
    # over the warm median (the cost model and the snapshot both need
    # compile OUT of device_compute)
    first_infer_s: List[float] = field(default_factory=list)
    warm_infer_s: deque = field(
        default_factory=lambda: deque(maxlen=_PROFILE_WINDOW))

    @property
    def compile_s(self) -> float:
        """Estimated compile seconds inside this profile's first calls.

        First-call duration minus the warm median (same executable, warm
        caches); with no warm sample yet the whole first call is cold and
        indistinguishable, so compile is conservatively 0 — it shows up
        the moment a second request lands in the bucket."""
        if not self.first_infer_s or not self.warm_infer_s:
            return 0.0
        warm = sorted(self.warm_infer_s)
        med = warm[len(warm) // 2]
        return sum(max(0.0, d - med) for d in self.first_infer_s)

    def summary(self) -> Dict[str, Any]:
        compile_s = self.compile_s
        crit = dict(self.critical_s)
        # compile time was measured inside infer spans -> carve it out of
        # device_compute so both decompositions still sum to wall
        crit["compile"] = crit.get("compile", 0.0) + compile_s
        crit["device_compute"] = max(
            0.0, crit["device_compute"] - compile_s)
        tot = dict(self.total_s)
        tot["compile"] = tot.get("compile", 0.0) + compile_s
        tot["device_compute"] = max(0.0, tot["device_compute"] - compile_s)
        walls = sorted(self.walls)
        return {
            "count": self.count,
            "wall_s": round(self.wall_s, 6),
            "wall_p50_s": round(walls[len(walls) // 2], 6) if walls else 0.0,
            "critical_s": {p: round(v, 6) for p, v in crit.items()},
            "total_s": {p: round(v, 6) for p, v in tot.items()},
            "compile_s": round(compile_s, 6),
            "critical_sum_s": round(sum(crit.values()), 6),
        }


class CriticalPathProfiler:
    """Folds completed tracer span trees into ``PhaseProfile``s.

    ``ingest`` is incremental (folded roots are remembered by span id) and
    thread-safe; ``report`` is what ``engine.snapshot()["phases"]``
    exports. ``cost_observations`` pairs each tree's measured phase
    seconds with the cost-model feature quantities its infer spans carry
    (core/trust.CalibratedCostModel consumes these).
    """

    def __init__(self) -> None:
        self.profiles: Dict[Tuple[str, str, str], PhaseProfile] = {}
        self._folded: set = set()
        self._observations: List[TreeDecomposition] = []
        self._lock = threading.Lock()

    # -- folding -----------------------------------------------------------
    def ingest(self, tracer: Optional[Tracer]) -> int:
        """Fold every *completed, not yet folded* request root. Returns the
        number of trees folded this call."""
        if tracer is None:
            return 0
        spans = tracer.spans()
        children: Dict[Optional[int], List[Span]] = {}
        for s in spans:
            children.setdefault(s.parent_id, []).append(s)
        folded = 0
        with self._lock:
            for root in children.get(None, ()):
                if (root.name != "request" or root.t1 is None
                        or root.span_id in self._folded):
                    continue
                self._folded.add(root.span_id)
                dec = self._fold_tree(root, children)
                prof = self.profiles.get(dec.key)
                if prof is None:
                    prof = self.profiles[dec.key] = PhaseProfile(dec.key)
                prof.count += 1
                prof.wall_s += dec.wall_s
                prof.walls.append(dec.wall_s)
                for p in PHASES:
                    prof.critical_s[p] += dec.critical_s.get(p, 0.0)
                    prof.total_s[p] += dec.total_s.get(p, 0.0)
                if dec.first_call:
                    prof.first_infer_s.append(dec.infer_s)
                elif dec.infer_s:
                    prof.warm_infer_s.append(dec.infer_s)
                self._observations.append(dec)
                folded += 1
        return folded

    def _fold_tree(self, root: Span,
                   children: Dict[Optional[int], List[Span]]
                   ) -> TreeDecomposition:
        critical = {p: 0.0 for p in PHASES}
        total = {p: 0.0 for p in PHASES}
        first_call = False
        infer_s = 0.0
        quantities: Dict[str, float] = {}
        # every instant of the wall goes to exactly ONE span: each child is
        # *allotted* its extent ∩ the parent's allotment, minus whatever an
        # earlier sibling already claimed (first-claim on overlap — parallel
        # shard dispatches cannot double-count), and the parent keeps the
        # unallotted remainder as self-time. Criticals therefore sum to the
        # request wall exactly, by construction, even under parallelism.
        stack: List[Tuple[Span, List[Tuple[float, float]]]] = [
            (root, [(root.t0, root.t1)])]
        while stack:
            s, allot = stack.pop()
            t1 = s.t1 if s.t1 is not None else root.t1
            dur = max(0.0, t1 - s.t0)
            kids = sorted((c for c in children.get(s.span_id, ())
                           if c.t0 < t1),      # clamp runaways to the parent
                          key=lambda c: c.t0)
            granted: List[Tuple[float, float]] = []
            for c in kids:
                c_t1 = c.t1 if c.t1 is not None else t1
                c_iv = (max(c.t0, s.t0), min(c_t1, t1))
                c_allot = (_subtract(_intersect(allot, [c_iv]), granted)
                           if c_iv[0] < c_iv[1] else [])
                granted = _merge_intervals(granted + c_allot)
                stack.append((c, c_allot))
            self_s = _measure(allot) - _measure(granted)
            phase = phase_of(s.name)
            critical[phase] += max(0.0, self_s)
            total[phase] += dur
            if s.name == "infer":
                infer_s += dur
                if s.attrs.get("first_call"):
                    first_call = True
                for attr in ("device_flops", "enclave_flops", "blind_bytes",
                             "unblind_bytes", "device_matmuls"):
                    v = s.attrs.get(attr)
                    if isinstance(v, (int, float)):
                        quantities[attr] = quantities.get(attr, 0.0) + v
            if s.name == "shard.dispatch":
                quantities["dispatches"] = quantities.get(
                    "dispatches", 0.0) + 1
        shape = root.attrs.get("shape")
        bucket = ("x".join(str(d) for d in shape)
                  if isinstance(shape, (list, tuple)) else "?")
        digest = str(root.attrs.get("plan", ""))
        if not digest:
            for c in children.get(root.span_id, ()):
                if c.name == "batch":
                    digest = str(c.attrs.get("plan", ""))
                    break
        key = (str(root.attrs.get("model", "?")), digest, bucket)
        return TreeDecomposition(key=key,
                                 wall_s=max(0.0, root.t1 - root.t0),
                                 critical_s=critical, total_s=total,
                                 first_call=first_call, infer_s=infer_s,
                                 quantities=quantities)

    # -- export ------------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        """The ``engine.snapshot()["phases"]`` payload: one summary per
        (model, plan-digest, shape-bucket) profile plus a fleet rollup."""
        with self._lock:
            profiles = dict(self.profiles)
        out: Dict[str, Any] = {"profiles": {}, "taxonomy": list(PHASES)}
        rollup = {p: 0.0 for p in PHASES}
        n = 0
        wall = 0.0
        for key, prof in profiles.items():
            summ = prof.summary()
            out["profiles"]["|".join(key)] = summ
            for p in PHASES:
                rollup[p] += summ["critical_s"].get(p, 0.0)
            n += prof.count
            wall += prof.wall_s
        out["requests"] = n
        out["wall_s"] = round(wall, 6)
        out["critical_s"] = {p: round(v, 6) for p, v in rollup.items()}
        return out

    def cost_observations(self) -> List[Tuple[Dict[str, float],
                                              Dict[str, float]]]:
        """(quantities, phase seconds) pairs for CalibratedCostModel.fit —
        warm trees only (a first-call tree's device_compute is poisoned by
        compile, which has its own phase, not a unit cost)."""
        with self._lock:
            obs = list(self._observations)
        out = []
        for dec in obs:
            if dec.first_call or not dec.quantities:
                continue
            out.append((dict(dec.quantities), dict(dec.critical_s)))
        return out

    def export_gauges(self, registry) -> None:
        """Fleet-rollup phase criticals as ``phase.<phase>_s`` gauges."""
        rep = self.report()
        registry.gauges({f"phase.{p}_s": v
                         for p, v in rep["critical_s"].items()})
        registry.gauge("phase.requests", rep["requests"])


# -- flight recorder --------------------------------------------------------

_TRIGGERS = ("quarantine", "breaker_open", "degradation", "verify_failure",
             "manual")


class FlightRecorder:
    """Always-on bounded post-mortem ring (redaction-enforced).

    ``event`` appends one redacted event to the ring (cheap: one lock +
    one deque append). ``dump`` assembles a bundle — recent events, the
    tracer's last ``span_tail`` spans, metric counter deltas since the
    previous dump — and, when ``out_dir`` is set, writes it as
    ``postmortem_<n>_<trigger>.json``. Dumps are rate-limited per trigger
    kind (``min_interval_s``) so a persistently dishonest device cannot
    turn every batch into a file write; the in-memory ``last_bundle`` is
    always refreshed.
    """

    def __init__(self, capacity: int = 512, span_tail: int = 200,
                 out_dir: Optional[str] = None,
                 min_interval_s: float = 1.0, max_dumps: int = 64) -> None:
        self.capacity = capacity
        self.span_tail = span_tail
        self.out_dir = pathlib.Path(out_dir) if out_dir else None
        self.min_interval_s = min_interval_s
        self.max_dumps = max_dumps
        self.events: deque = deque(maxlen=capacity)
        self.dumps = 0
        self.suppressed = 0
        self.last_bundle: Optional[Dict[str, Any]] = None
        self._last_dump_t: Dict[str, float] = {}
        self._last_counters: Dict[str, int] = {}
        self._lock = threading.Lock()

    def event(self, kind: str, **attrs: Any) -> None:
        """Record one engine/plane event. Attributes pass through the PR 7
        ``redact`` allowlist — arrays/bytes raise before storage."""
        ev = {"t": time.time(), "kind": str(kind),
              "attrs": {k: redact(v) for k, v in attrs.items()}}
        with self._lock:
            self.events.append(ev)

    def dump(self, trigger: str, tracer: Optional[Tracer] = None,
             registry=None, **attrs: Any) -> Optional[Dict[str, Any]]:
        """Assemble (and maybe write) a post-mortem bundle. Returns the
        bundle, or None when rate-limited for this trigger kind."""
        now = time.monotonic()
        with self._lock:
            last = self._last_dump_t.get(trigger)
            if last is not None and now - last < self.min_interval_s:
                self.suppressed += 1
                return None
            self._last_dump_t[trigger] = now
            events = list(self.events)
            seq = self.dumps
            self.dumps += 1
        spans: List[Dict[str, Any]] = []
        dropped = 0
        if tracer is not None:
            tail = tracer.spans()[-self.span_tail:]
            spans = [s.as_dict() for s in tail]
            dropped = tracer.dropped
        metrics: Dict[str, Any] = {}
        if registry is not None:
            snap = registry.snapshot()
            counters = snap["counters"]
            with self._lock:
                delta = {k: v - self._last_counters.get(k, 0)
                         for k, v in counters.items()
                         if v != self._last_counters.get(k, 0)}
                self._last_counters = dict(counters)
            metrics = {"counter_delta": delta, "gauges": snap["gauges"]}
        bundle = {
            "trigger": str(trigger),
            "seq": seq,
            "ts_unix": time.time(),
            "attrs": {k: redact(v) for k, v in attrs.items()},
            "events": events,
            "spans": spans,
            "dropped_spans": dropped,
            "metrics": metrics,
        }
        with self._lock:
            self.last_bundle = bundle
        if self.out_dir is not None and seq < self.max_dumps:
            self.out_dir.mkdir(parents=True, exist_ok=True)
            path = self.out_dir / f"postmortem_{seq:03d}_{trigger}.json"
            path.write_text(json.dumps(bundle, indent=1) + "\n")
        return bundle

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"events": len(self.events), "dumps": self.dumps,
                    "suppressed": self.suppressed,
                    "last_trigger": (self.last_bundle or {}).get("trigger")}
