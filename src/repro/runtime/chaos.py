"""Deterministic chaos harness: scripted faults across serving layers.

The integrity and liveness ladders (DESIGN.md §9/§11/§12) are recovery
machinery; this module is the *drill sergeant* that proves they work as a
system. A ``ChaosSchedule`` arms and disarms faults at scripted batch
indices across three layers:

- **device** (``dev{i}.{kind}@a-b``): installs a runtime/faults
  ``UnresponsiveDevice`` of the given liveness kind (``crash`` / ``hang``
  / ``flaky`` / ``brownout``) on DevicePool slot ``i`` for batches a..b
  inclusive, then removes it — the breaker/timeout/backoff ladder must
  absorb the window and re-admit the device afterwards;
- **session-refill** (``refill@a-b``): the SessionPool's prefetch raises
  for those batches (``refill_fault`` hook) — factor generation falls
  back to the request path, ``refill_errors`` must count it, serving must
  not stop;
- **sealing** (``seal@a-b``): every request dispatched in those batches
  gets its MAC flipped in flight — the enclave must reject exactly those
  requests (``mac_failed``) without disturbing the rest of the batch.

Everything is deterministic: the schedule is a pure function of batch
index, and the device injectors draw per-(seed, op, attempt) decisions —
the same schedule replays the same run (runtime/faults.py). The engine
advances the clock (``ChaosController.on_batch``) once per dispatched
batch of the chaotic model; ``launch/serve.py --chaos`` drives the tier-1
drill and ``benchmarks/chaos_bench.py`` measures detection-to-recovery
latency and goodput per fault class.

The chaos invariant the drills assert (ISSUE 6): under ANY schedule,
every submitted future resolves (ok, flagged-recovered, or an explicit
error), the engine never stops serving, and recovered outputs are
bit-exact against a healthy oracle.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.runtime.faults import (LIVENESS_KINDS, LivenessSpec,
                                  UnresponsiveDevice)

LAYERS = ("device", "refill", "seal")


class RefillChaos(RuntimeError):
    """Injected session-refill failure (scripted, not a real fault)."""


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One armed window: ``layer`` fault active for batches
    [start, stop], both inclusive (batch indices are per-model dispatch
    counts, the engine's drill clock)."""
    layer: str
    start: int
    stop: int
    device: Optional[int] = None    # device layer only
    kind: Optional[str] = None      # liveness kind (device layer only)
    prob: float = 1.0
    delay_s: float = 0.05           # brownout inflation

    def __post_init__(self):
        assert self.layer in LAYERS, self.layer
        assert 0 <= self.start <= self.stop, (self.start, self.stop)
        if self.layer == "device":
            assert self.device is not None and self.device >= 0
            assert self.kind in LIVENESS_KINDS, self.kind

    def active(self, batch: int) -> bool:
        return self.start <= batch <= self.stop

    @property
    def label(self) -> str:
        span = (f"@{self.start}" if self.start == self.stop
                else f"@{self.start}-{self.stop}")
        if self.layer == "device":
            return f"dev{self.device}.{self.kind}{span}"
        return f"{self.layer}{span}"


_EVENT_RE = re.compile(
    r"^(?:dev(?P<dev>\d+)\.(?P<kind>[a-z_]+)|(?P<layer>refill|seal))"
    r"@(?P<start>\d+)(?:-(?P<stop>\d+))?$")


@dataclasses.dataclass
class ChaosSchedule:
    """An ordered list of ChaosEvents (order is cosmetic — activation is
    purely by batch index, so overlapping windows compose)."""
    events: List[ChaosEvent]

    @classmethod
    def parse(cls, text: str) -> "ChaosSchedule":
        """Mini-language: comma-separated ``dev{i}.{kind}@a[-b]``,
        ``refill@a[-b]``, ``seal@a[-b]`` — e.g. the tier-1 drill's
        ``dev0.crash@1-2,dev1.hang@1-2,refill@4-5,seal@6``."""
        events: List[ChaosEvent] = []
        for part in filter(None, (p.strip() for p in text.split(","))):
            m = _EVENT_RE.match(part)
            if m is None:
                raise ValueError(
                    f"bad chaos event {part!r} (want dev<i>.<kind>@a[-b], "
                    f"refill@a[-b] or seal@a[-b])")
            start = int(m["start"])
            stop = int(m["stop"]) if m["stop"] is not None else start
            if m["dev"] is not None:
                if m["kind"] not in LIVENESS_KINDS:
                    raise ValueError(
                        f"bad liveness kind {m['kind']!r} in {part!r} "
                        f"(want one of {LIVENESS_KINDS})")
                events.append(ChaosEvent("device", start, stop,
                                         device=int(m["dev"]),
                                         kind=m["kind"]))
            else:
                events.append(ChaosEvent(m["layer"], start, stop))
        if not events:
            raise ValueError(f"empty chaos schedule {text!r}")
        return cls(events)

    @property
    def horizon(self) -> int:
        """First batch index past every window (all faults disarmed)."""
        return max(ev.stop for ev in self.events) + 1

    def __str__(self) -> str:
        return ",".join(ev.label for ev in self.events)


class ChaosController:
    """Binds a schedule to a live engine's fault surfaces and advances it.

    ``on_batch(idx)`` is called by the engine once per dispatched batch
    (runtime/engine.py ``_dispatch``): events entering their window arm
    (device injector installed / refill hook set / request MACs flipped),
    events leaving it disarm. The arm/disarm ``log`` plus the per-layer
    counters are what the drills and the bench assert against.
    """

    def __init__(self, schedule: ChaosSchedule, *, pool=None,
                 sessions=None, seed: int = 0):
        self.schedule = schedule
        self.pool = pool                # runtime/devices.DevicePool
        self.sessions = sessions        # runtime/sessions.SessionPool
        self.seed = seed
        self.batch = -1                 # last batch index seen
        self.log: List[Tuple[int, str, str]] = []   # (batch, label, action)
        self.seal_corruptions = 0
        self.refill_faults = 0          # injected refill raises
        self._armed: Dict[int, object] = {}         # event idx -> injector

    def bind(self, *, pool=None, sessions=None) -> None:
        """Late-bind the fault surfaces (the engine owns their lifetimes:
        register_executor calls this once the pools exist)."""
        if pool is not None:
            self.pool = pool
        if sessions is not None:
            self.sessions = sessions

    # -- arming ------------------------------------------------------------
    def _arm(self, i: int, ev: ChaosEvent, batch: int) -> None:
        if ev.layer == "device":
            assert self.pool is not None, "device chaos needs a DevicePool"
            spec = LivenessSpec(kind=ev.kind, prob=ev.prob,
                                delay_s=ev.delay_s)
            injector = UnresponsiveDevice(spec, seed=(self.seed, i))
            self.pool.slots[ev.device].liveness = injector
            self._armed[i] = injector
        elif ev.layer == "refill":
            assert self.sessions is not None, "refill chaos needs a pool"

            def fail(counter: int, _ev=ev) -> None:
                self.refill_faults += 1
                raise RefillChaos(f"scripted refill fault ({_ev.label})")

            self.sessions.refill_fault = fail
            self._armed[i] = fail
        else:                           # seal: applied per batch in on_batch
            self._armed[i] = True
        self.log.append((batch, ev.label, "arm"))

    def _disarm(self, i: int, ev: ChaosEvent, batch: int) -> None:
        injector = self._armed.pop(i)
        if ev.layer == "device":
            slot = self.pool.slots[ev.device]
            if slot.liveness is injector:   # overlapping windows: last wins
                slot.liveness = None
        elif ev.layer == "refill":
            if self.sessions.refill_fault is injector:
                self.sessions.refill_fault = None
        self.log.append((batch, ev.label, "disarm"))

    # -- the drill clock ----------------------------------------------------
    def on_batch(self, batch: int, requests=None) -> None:
        """Advance to batch ``batch``: arm/disarm every event whose window
        boundary was crossed, then corrupt this batch's request MACs if a
        seal window is active. Idempotent per index and tolerant of
        skipped indices (activation is absolute, not incremental)."""
        self.batch = batch
        seal_active = False
        for i, ev in enumerate(self.schedule.events):
            armed = i in self._armed
            if ev.active(batch) and not armed:
                self._arm(i, ev, batch)
            elif not ev.active(batch) and armed:
                self._disarm(i, ev, batch)
            if ev.layer == "seal" and ev.active(batch):
                seal_active = True
        if seal_active and requests:
            for r in requests:
                # flip one MAC bit in flight: the enclave's unseal must
                # reject exactly this request (mac_failed), nothing else
                r.box = r.box._replace(mac=r.box.mac ^ jnp.uint32(1))
                self.seal_corruptions += 1

    def quiesce(self, batch: Optional[int] = None) -> None:
        """Force-disarm everything (end of drill / engine close)."""
        b = batch if batch is not None else self.batch
        for i, ev in enumerate(self.schedule.events):
            if i in self._armed:
                self._disarm(i, ev, b)

    def snapshot(self) -> Dict[str, object]:
        return {"schedule": str(self.schedule), "batch": self.batch,
                "armed": sorted(self.schedule.events[i].label
                                for i in self._armed),
                "seal_corruptions": self.seal_corruptions,
                "refill_faults": self.refill_faults,
                "log": list(self.log)}
