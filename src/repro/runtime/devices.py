"""Multi-device offload pool: per-device health for the blinded plane.

The engine (PR 2/3) offloaded every blinded field matmul to one implicit
device, so a single bad accelerator quarantined a whole *model* forever and
throughput was capped by one part. DarKnight's construction (PAPERS.md)
scales the same blinding across multiple untrusted GPUs; ``DevicePool`` is
the health-tracking side of that plane (parallel/offload_sharding.py is the
dispatch side):

- **slots**: one per untrusted accelerator — real ``jax.devices()`` entries
  when the host has them, or N *simulated* slots (CPU tests/benchmarks: all
  compute lands on the default backend, but each slot keeps its own fault
  injector, latency model and health state, which is what the dishonest-
  device drills exercise).
- **per-device telemetry**: a latency EWMA per slot (shard placement
  prefers fast devices) and Freivalds-failure counters fed by the
  shard-local checks.
- **per-device quarantine/probation**: ``quarantine_after`` consecutive
  failed shard checks quarantine *that slot only* — the rest of the pool
  keeps serving blinded offload (the all-or-nothing per-model quarantine
  of runtime/engine.py remains only for poolless models). After
  ``probation_after`` further pool dispatches the slot becomes
  probe-eligible: the plane routes it ONE verified shard; a clean check
  restores it, a failed one re-quarantines it — a transient fault heals, a
  persistent adversary stays benched.

Each slot owns a single-worker thread (its dispatch queue): shards to
distinct devices run concurrently (JAX ops drop the GIL; real devices
overlap fully, simulated ones at least overlap their latency models),
while work for one device serializes like a real command queue.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import jax


@dataclasses.dataclass
class DeviceHealthConfig:
    quarantine_after: int = 2       # consecutive failed shard checks
    probation_after: int = 4        # pool dispatches before a re-probe
    ewma_alpha: float = 0.25        # latency EWMA smoothing


class DeviceSlot:
    """One untrusted accelerator: identity, health, queue, telemetry."""

    def __init__(self, index: int, *, jax_device=None, fault=None,
                 sim_gflops: Optional[float] = None,
                 sim_delay_s: float = 0.0):
        self.index = index
        self.jax_device = jax_device            # real device or None (sim)
        self.fault = fault                      # runtime/faults injector
        self.sim_gflops = sim_gflops            # modeled throughput (sleep)
        self.sim_delay_s = sim_delay_s          # fixed per-dispatch latency
        self.name = (str(jax_device) if jax_device is not None
                     else f"sim:{index}")
        # health state (guarded by the pool lock)
        self.quarantined = False
        self.probation = False                  # probe-eligible
        self._cooldown = 0                      # dispatches until probation
        self.consec_failures = 0
        # telemetry
        self.dispatches = 0
        self.verify_failures = 0
        self.quarantines = 0
        self.probes = 0
        self.restores = 0
        self.ewma_latency_s: Optional[float] = None
        self._queue = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"offload-dev{index}")

    def submit(self, fn: Callable, *args) -> Future:
        """Enqueue ``fn(self, *args)`` on this device's serial queue."""
        return self._queue.submit(fn, self, *args)

    def snapshot(self) -> Dict[str, object]:
        return {"name": self.name, "quarantined": self.quarantined,
                "probation": self.probation,
                "dispatches": self.dispatches,
                "verify_failures": self.verify_failures,
                "consec_failures": self.consec_failures,
                "quarantines": self.quarantines, "probes": self.probes,
                "restores": self.restores,
                "ewma_latency_s": self.ewma_latency_s}

    def close(self) -> None:
        self._queue.shutdown(wait=False)


class DevicePool:
    """Health-tracked device set the sharded offload plane dispatches to.

    ``n``: simulated slot count; ``devices``: explicit ``jax.Device``s
    (``DevicePool.from_jax()`` wraps the host's real accelerators).
    ``faults``: {slot index: DishonestDevice} — per-device injectors, the
    "one dishonest device in the fleet" drill the tier-1 smoke runs.
    """

    def __init__(self, n: Optional[int] = None, *,
                 devices: Optional[Sequence] = None,
                 faults: Optional[Dict[int, object]] = None,
                 sim_gflops: Optional[float] = None,
                 sim_delay_s: Optional[Dict[int, float]] = None,
                 health: Optional[DeviceHealthConfig] = None):
        assert (n is None) != (devices is None), "pass n= XOR devices="
        faults = faults or {}
        delays = sim_delay_s or {}
        self.health = health or DeviceHealthConfig()
        self._lock = threading.Lock()
        if devices is not None:
            self.slots = [DeviceSlot(i, jax_device=d, fault=faults.get(i),
                                     sim_delay_s=delays.get(i, 0.0))
                          for i, d in enumerate(devices)]
        else:
            assert n >= 1, n
            self.slots = [DeviceSlot(i, fault=faults.get(i),
                                     sim_gflops=sim_gflops,
                                     sim_delay_s=delays.get(i, 0.0))
                          for i in range(n)]
        self.dispatches = 0                 # plane-level matmul dispatches

    @classmethod
    def from_jax(cls, **kw) -> "DevicePool":
        return cls(devices=jax.devices(), **kw)

    @property
    def size(self) -> int:
        return len(self.slots)

    # -- health ------------------------------------------------------------
    def n_healthy(self) -> int:
        with self._lock:
            return sum(not s.quarantined for s in self.slots)

    def healthy(self, group: Optional[Sequence[int]] = None
                ) -> List[DeviceSlot]:
        """Non-quarantined slots (optionally restricted to a device
        group), fastest EWMA first — placement prefers proven-fast parts;
        never-measured slots sort first so every device gets warmed."""
        with self._lock:
            out = [s for s in self.slots if not s.quarantined
                   and (group is None or s.index in group)]
        return sorted(out, key=lambda s: (s.ewma_latency_s is not None,
                                          s.ewma_latency_s or 0.0, s.index))

    def probe_candidate(self, group: Optional[Sequence[int]] = None
                        ) -> Optional[DeviceSlot]:
        """One probe-eligible quarantined slot (probation reached), if any."""
        with self._lock:
            for s in self.slots:
                if (s.quarantined and s.probation
                        and (group is None or s.index in group)):
                    return s
        return None

    def begin_dispatch(self) -> None:
        """One plane-level matmul dispatch: age quarantine cooldowns so
        benched devices eventually reach probation."""
        with self._lock:
            self.dispatches += 1
            for s in self.slots:
                if s.quarantined and not s.probation:
                    s._cooldown -= 1
                    if s._cooldown <= 0:
                        s.probation = True

    def record_success(self, slot: DeviceSlot, latency_s: float) -> None:
        """A shard this slot computed passed its Freivalds check."""
        a = self.health.ewma_alpha
        with self._lock:
            slot.dispatches += 1
            slot.ewma_latency_s = (
                latency_s if slot.ewma_latency_s is None
                else (1 - a) * slot.ewma_latency_s + a * latency_s)
            slot.consec_failures = 0
            if slot.quarantined and slot.probation:
                # restored ONLY via the probation probe — a clean result
                # reaching a quarantined slot any other way (a spares list
                # captured before a mid-op quarantine) must not shortcut
                # the probation wait, or a probabilistic corruptor could
                # un-bench itself immediately
                slot.quarantined = False
                slot.probation = False
                slot.restores += 1

    def record_latency(self, slot: DeviceSlot, latency_s: float) -> None:
        """EWMA-only update — a hedge loser's wall time teaches placement
        to avoid a chronic straggler without touching its health state
        (its Freivalds check never ran)."""
        a = self.health.ewma_alpha
        with self._lock:
            slot.ewma_latency_s = (
                latency_s if slot.ewma_latency_s is None
                else (1 - a) * slot.ewma_latency_s + a * latency_s)

    def record_probe(self, slot: DeviceSlot) -> None:
        """The plane routed a probe shard to a quarantined slot."""
        with self._lock:
            slot.probes += 1

    def record_failure(self, slot: DeviceSlot) -> None:
        """A shard this slot computed FAILED its Freivalds check."""
        with self._lock:
            slot.dispatches += 1
            slot.verify_failures += 1
            slot.consec_failures += 1
            if slot.quarantined:                # failed probe: re-bench
                slot.probation = False
                slot._cooldown = self.health.probation_after
            elif slot.consec_failures >= self.health.quarantine_after:
                slot.quarantined = True
                slot.probation = False
                slot._cooldown = self.health.probation_after
                slot.quarantines += 1

    def snapshot(self) -> Dict[str, object]:
        return {"size": self.size, "healthy": self.n_healthy(),
                "dispatches": self.dispatches,
                "slots": [s.snapshot() for s in self.slots]}

    def close(self) -> None:
        for s in self.slots:
            s.close()
