"""Multi-device offload pool: per-device health for the blinded plane.

The engine (PR 2/3) offloaded every blinded field matmul to one implicit
device, so a single bad accelerator quarantined a whole *model* forever and
throughput was capped by one part. DarKnight's construction (PAPERS.md)
scales the same blinding across multiple untrusted GPUs; ``DevicePool`` is
the health-tracking side of that plane (parallel/offload_sharding.py is the
dispatch side):

- **slots**: one per untrusted accelerator — real ``jax.devices()`` entries
  when the host has them, or N *simulated* slots (CPU tests/benchmarks: all
  compute lands on the default backend, but each slot keeps its own fault
  injector, latency model and health state, which is what the dishonest-
  device drills exercise).
- **per-device telemetry**: a latency EWMA per slot (shard placement
  prefers fast devices) and Freivalds-failure counters fed by the
  shard-local checks.
- **per-device quarantine/probation** (integrity indictment): ``quarantine_after``
  consecutive failed shard checks quarantine *that slot only* — the rest
  of the pool keeps serving blinded offload (the all-or-nothing per-model
  quarantine of runtime/engine.py remains only for poolless models).
  After ``probation_after`` further pool dispatches the slot becomes
  probe-eligible: the plane routes it ONE verified shard; a clean check
  restores it, a failed one re-quarantines it — a transient fault heals, a
  persistent adversary stays benched.
- **per-device circuit breaker** (liveness indictment, DESIGN.md §12):
  ``breaker_after`` consecutive liveness failures (crash / hard dispatch
  timeout) OPEN the breaker — no traffic; after ``breaker_cooldown``
  further pool dispatches it goes HALF-OPEN and the plane routes ONE
  probe shard: a verified success closes the breaker, any failure
  re-opens it with a doubled cooldown (capped). The two indictment
  states are independent and compose: a slot serves only when it is
  neither quarantined (returns wrong results) nor open (returns none).

Each slot owns a single-worker thread (its dispatch queue): shards to
distinct devices run concurrently (JAX ops drop the GIL; real devices
overlap fully, simulated ones at least overlap their latency models),
while work for one device serializes like a real command queue. A queue
wedged by a hung dispatch is ``abandon()``-ed: the stuck worker is cut
loose (its cancel event released, its pending work cancelled) and a
fresh queue takes its place, so one hang never blocks later probes.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import jax

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"


@dataclasses.dataclass
class DeviceHealthConfig:
    quarantine_after: int = 2       # consecutive failed shard checks
    probation_after: int = 4        # pool dispatches before a re-probe
    ewma_alpha: float = 0.25        # latency EWMA smoothing
    # liveness circuit breaker (independent of the integrity quarantine)
    breaker_after: int = 2          # consecutive liveness failures -> open
    breaker_cooldown: int = 4       # pool dispatches until half-open
    breaker_backoff: float = 2.0    # cooldown multiplier per failed probe
    breaker_max_cooldown: int = 64  # cooldown growth cap


class DeviceSlot:
    """One untrusted accelerator: identity, health, queue, telemetry."""

    def __init__(self, index: int, *, jax_device=None, fault=None,
                 liveness=None, sim_gflops: Optional[float] = None,
                 sim_delay_s: float = 0.0):
        self.index = index
        self.jax_device = jax_device            # real device or None (sim)
        self.fault = fault                      # integrity injector
        self.liveness = liveness                # liveness injector
        self.sim_gflops = sim_gflops            # modeled throughput (sleep)
        self.sim_delay_s = sim_delay_s          # fixed per-dispatch latency
        self.name = (str(jax_device) if jax_device is not None
                     else f"sim:{index}")
        # health state (guarded by the pool lock)
        self.quarantined = False
        self.probation = False                  # probe-eligible
        self._cooldown = 0                      # dispatches until probation
        self.consec_failures = 0
        # liveness circuit breaker (guarded by the pool lock)
        self.breaker = BREAKER_CLOSED
        self.consec_liveness = 0
        self._breaker_cooldown = 0              # dispatches until half-open
        self._breaker_wait = 0                  # current cooldown length
        # telemetry
        self.dispatches = 0
        self.verify_failures = 0
        self.quarantines = 0
        self.probes = 0
        self.restores = 0
        self.liveness_failures = 0
        self.breaker_opens = 0
        self.breaker_probes = 0
        self.breaker_closes = 0
        self.abandons = 0
        self.ewma_latency_s: Optional[float] = None
        # the cancel event is handed to in-flight dispatches: an injected
        # hang parks on it, and abandon()/close() set it so the parked
        # worker is always reclaimable
        self.cancel = threading.Event()
        self._queue = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"offload-dev{index}")

    @property
    def available(self) -> bool:
        """Serving-eligible: neither indicted for integrity (quarantine)
        nor for liveness (open/half-open breaker — half-open only takes
        the explicit probe the plane routes it)."""
        return not self.quarantined and self.breaker == BREAKER_CLOSED

    def submit(self, fn: Callable, *args) -> Future:
        """Enqueue ``fn(self, *args)`` on this device's serial queue."""
        return self._queue.submit(fn, self, *args)

    def abandon(self) -> None:
        """Cut a wedged queue loose after a hard dispatch timeout: release
        anything parked on the cancel event, cancel queued-but-unstarted
        work, and swap in a fresh queue + event so subsequent probes do
        not line up behind the hung dispatch."""
        old_queue, old_cancel = self._queue, self.cancel
        self.cancel = threading.Event()
        self._queue = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"offload-dev{self.index}")
        self.abandons += 1
        old_cancel.set()
        old_queue.shutdown(wait=False, cancel_futures=True)

    def snapshot(self) -> Dict[str, object]:
        return {"name": self.name, "quarantined": self.quarantined,
                "probation": self.probation,
                "breaker": self.breaker, "available": self.available,
                "dispatches": self.dispatches,
                "verify_failures": self.verify_failures,
                "consec_failures": self.consec_failures,
                "liveness_failures": self.liveness_failures,
                "breaker_opens": self.breaker_opens,
                "breaker_probes": self.breaker_probes,
                "breaker_closes": self.breaker_closes,
                "abandons": self.abandons,
                "quarantines": self.quarantines, "probes": self.probes,
                "restores": self.restores,
                "ewma_latency_s": self.ewma_latency_s}

    def close(self, drain: bool = True) -> None:
        """Stop the dispatch queue. ``drain=True`` (the default) lets
        already-submitted work finish instead of orphaning it; the cancel
        event is set first so an injected hang cannot stall the drain."""
        self.cancel.set()
        self._queue.shutdown(wait=drain, cancel_futures=not drain)


class DevicePool:
    """Health-tracked device set the sharded offload plane dispatches to.

    ``n``: simulated slot count; ``devices``: explicit ``jax.Device``s
    (``DevicePool.from_jax()`` wraps the host's real accelerators).
    ``faults``: {slot index: DishonestDevice} — per-device injectors, the
    "one dishonest device in the fleet" drill the tier-1 smoke runs.
    """

    def __init__(self, n: Optional[int] = None, *,
                 devices: Optional[Sequence] = None,
                 faults: Optional[Dict[int, object]] = None,
                 liveness: Optional[Dict[int, object]] = None,
                 sim_gflops: Optional[float] = None,
                 sim_delay_s: Optional[Dict[int, float]] = None,
                 health: Optional[DeviceHealthConfig] = None):
        assert (n is None) != (devices is None), "pass n= XOR devices="
        faults = faults or {}
        livefaults = liveness or {}
        delays = sim_delay_s or {}
        self.health = health or DeviceHealthConfig()
        self._lock = threading.Lock()
        if devices is not None:
            self.slots = [DeviceSlot(i, jax_device=d, fault=faults.get(i),
                                     liveness=livefaults.get(i),
                                     sim_delay_s=delays.get(i, 0.0))
                          for i, d in enumerate(devices)]
        else:
            assert n >= 1, n
            self.slots = [DeviceSlot(i, fault=faults.get(i),
                                     liveness=livefaults.get(i),
                                     sim_gflops=sim_gflops,
                                     sim_delay_s=delays.get(i, 0.0))
                          for i in range(n)]
        self.dispatches = 0                 # plane-level matmul dispatches

    @classmethod
    def from_jax(cls, **kw) -> "DevicePool":
        return cls(devices=jax.devices(), **kw)

    @property
    def size(self) -> int:
        return len(self.slots)

    # -- health ------------------------------------------------------------
    def n_healthy(self) -> int:
        """Integrity-healthy (non-quarantined) slots — liveness aside."""
        with self._lock:
            return sum(not s.quarantined for s in self.slots)

    def n_available(self) -> int:
        """Serving-eligible slots: neither quarantined nor breaker-open.
        Zero is the engine's graceful-degradation trigger (§12)."""
        with self._lock:
            return sum(s.available for s in self.slots)

    def healthy(self, group: Optional[Sequence[int]] = None
                ) -> List[DeviceSlot]:
        """Serving-eligible slots (not quarantined, breaker closed;
        optionally restricted to a device group), fastest EWMA first —
        placement prefers proven-fast parts; never-measured slots sort
        first so every device gets warmed."""
        with self._lock:
            out = [s for s in self.slots if s.available
                   and (group is None or s.index in group)]
        return sorted(out, key=lambda s: (s.ewma_latency_s is not None,
                                          s.ewma_latency_s or 0.0, s.index))

    def probe_candidate(self, group: Optional[Sequence[int]] = None
                        ) -> Optional[DeviceSlot]:
        """One probe-eligible quarantined slot (probation reached), if any.
        A slot whose breaker is also non-closed is skipped — liveness must
        be re-proven first (the breaker probe path)."""
        with self._lock:
            for s in self.slots:
                if (s.quarantined and s.probation
                        and s.breaker == BREAKER_CLOSED
                        and (group is None or s.index in group)):
                    return s
        return None

    def breaker_candidate(self, group: Optional[Sequence[int]] = None
                          ) -> Optional[DeviceSlot]:
        """One half-open slot awaiting its liveness probe, if any."""
        with self._lock:
            for s in self.slots:
                if (s.breaker == BREAKER_HALF_OPEN and not s.quarantined
                        and (group is None or s.index in group)):
                    return s
        return None

    def begin_dispatch(self) -> None:
        """One plane-level matmul dispatch: age quarantine and breaker
        cooldowns so benched devices eventually reach their probe state.
        The engine also calls this while serving degraded (enclave-only)
        batches — otherwise a fully-benched pool could never half-open."""
        with self._lock:
            self.dispatches += 1
            for s in self.slots:
                if s.quarantined and not s.probation:
                    s._cooldown -= 1
                    if s._cooldown <= 0:
                        s.probation = True
                if s.breaker == BREAKER_OPEN:
                    s._breaker_cooldown -= 1
                    if s._breaker_cooldown <= 0:
                        s.breaker = BREAKER_HALF_OPEN

    def record_success(self, slot: DeviceSlot, latency_s: float) -> None:
        """A shard this slot computed passed its Freivalds check."""
        a = self.health.ewma_alpha
        with self._lock:
            slot.dispatches += 1
            slot.ewma_latency_s = (
                latency_s if slot.ewma_latency_s is None
                else (1 - a) * slot.ewma_latency_s + a * latency_s)
            slot.consec_failures = 0
            slot.consec_liveness = 0
            if slot.breaker == BREAKER_HALF_OPEN:
                # the liveness probe came back verified: close the breaker
                # and reset the cooldown backoff (DESIGN.md §12)
                slot.breaker = BREAKER_CLOSED
                slot._breaker_wait = 0
                slot.breaker_closes += 1
            if slot.quarantined and slot.probation:
                # restored ONLY via the probation probe — a clean result
                # reaching a quarantined slot any other way (a spares list
                # captured before a mid-op quarantine) must not shortcut
                # the probation wait, or a probabilistic corruptor could
                # un-bench itself immediately
                slot.quarantined = False
                slot.probation = False
                slot.restores += 1

    def record_latency(self, slot: DeviceSlot, latency_s: float) -> None:
        """EWMA-only update — a hedge loser's wall time teaches placement
        to avoid a chronic straggler without touching its health state
        (its Freivalds check never ran)."""
        a = self.health.ewma_alpha
        with self._lock:
            slot.ewma_latency_s = (
                latency_s if slot.ewma_latency_s is None
                else (1 - a) * slot.ewma_latency_s + a * latency_s)

    def record_probe(self, slot: DeviceSlot) -> None:
        """The plane routed a probe shard to a quarantined slot."""
        with self._lock:
            slot.probes += 1

    def record_breaker_probe(self, slot: DeviceSlot) -> None:
        """The plane routed a liveness probe to a half-open slot."""
        with self._lock:
            slot.breaker_probes += 1

    def record_liveness_failure(self, slot: DeviceSlot) -> None:
        """A dispatch to this slot crashed or exceeded the hard timeout.

        Liveness failures feed the circuit breaker, NOT the integrity
        quarantine — a crashing device never returned a wrong result, so
        conflating the two would let an attacker convert cheap crashes
        into integrity convictions (and vice versa would let a corruptor
        hide behind breaker half-open resets)."""
        with self._lock:
            slot.dispatches += 1
            slot.liveness_failures += 1
            slot.consec_liveness += 1
            if slot.breaker == BREAKER_HALF_OPEN:
                # failed probe: re-open with a longer cooldown (capped)
                slot.breaker = BREAKER_OPEN
                slot._breaker_wait = min(
                    max(int(slot._breaker_wait
                            * self.health.breaker_backoff), 1),
                    self.health.breaker_max_cooldown)
                slot._breaker_cooldown = slot._breaker_wait
            elif (slot.breaker == BREAKER_CLOSED
                  and slot.consec_liveness >= self.health.breaker_after):
                slot.breaker = BREAKER_OPEN
                slot._breaker_wait = self.health.breaker_cooldown
                slot._breaker_cooldown = slot._breaker_wait
                slot.breaker_opens += 1

    def record_failure(self, slot: DeviceSlot) -> None:
        """A shard this slot computed FAILED its Freivalds check."""
        with self._lock:
            slot.dispatches += 1
            slot.verify_failures += 1
            slot.consec_failures += 1
            if slot.quarantined:                # failed probe: re-bench
                slot.probation = False
                slot._cooldown = self.health.probation_after
            elif slot.consec_failures >= self.health.quarantine_after:
                slot.quarantined = True
                slot.probation = False
                slot._cooldown = self.health.probation_after
                slot.quarantines += 1

    def snapshot(self) -> Dict[str, object]:
        return {"size": self.size, "healthy": self.n_healthy(),
                "available": self.n_available(),
                "dispatches": self.dispatches,
                "slots": [s.snapshot() for s in self.slots]}

    def close(self, drain: bool = True) -> None:
        for s in self.slots:
            s.close(drain=drain)
