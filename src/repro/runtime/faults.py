"""Dishonest-device simulator: fault injection under the device matmul.

The threat model (DESIGN.md §2, §9) makes the accelerator untrusted for
*integrity* as well as privacy: a malicious or faulty device can return any
``y_b`` it likes for the offloaded field matmul. ``DishonestDevice`` sits
exactly at that boundary — core/slalom.py hands it the device's field-domain
result and it returns a (possibly) corrupted one, all inside the jit trace,
so the enclave-side Freivalds layer (core/integrity.py) sees precisely what
a byzantine backend would feed it.

``UnresponsiveDevice`` is the *availability* half of the same threat model
(DESIGN.md §12): a device that returns NO result instead of a wrong one.
It lives host-side (a crash or a hang cannot be expressed inside a jit
trace) and perturbs the DeviceSlot dispatch path
(parallel/offload_sharding.py ``_device_run``) before any compute runs.

Liveness fault classes (``LivenessSpec.kind``):

- ``crash``        the dispatch raises ``DeviceCrash`` (driver reset, OOM
                   kill, XID error) — the minimal liveness fault the
                   containment/retry ladder must absorb;
- ``hang``         the dispatch never returns: the worker parks on the
                   slot's cancel event, which only the plane's hard
                   dispatch timeout (abandon) or shutdown releases;
- ``flaky``        transient failure: attempt k on an op crashes with
                   probability ``prob * decay**k`` — retries with backoff
                   eventually get through, which is what distinguishes it
                   from ``crash`` for the circuit breaker;
- ``brownout``     latency inflation: the dispatch sleeps ``delay_s`` on
                   top of real compute — no error is ever raised, only the
                   straggler/hedging machinery sees it.

All liveness decisions are pure functions of (seed, op, attempt), so a
scripted chaos run (runtime/chaos.py) replays identically.

Integrity fault classes (``FaultSpec.kind``):

- ``bit_flip``     one bit of one field element flips (SEU / marginal
                   hardware) — the minimal corruption Freivalds must catch;
- ``row_swap``     two result rows exchanged (batch-order bug or targeted
                   misattribution between users in a batch);
- ``stale``        the device replays a stale result; after unblinding with
                   the current factors a replay differs by a uniform-looking
                   field offset ``(r_old − r_now) @ W_q``, which is how it
                   is emulated here (dense corruption, every element);
- ``adaptive``     a rational adversary that knows the sampling schedule
                   (worst case: timing side channels) and corrupts — with a
                   bit flip — only ops that will NOT be verified. Defeats
                   ``sampled(rate)`` completely, is completely neutralized
                   by ``full`` — the policy/threat table DESIGN.md §9
                   tabulates and BENCH_integrity.json measures.

All decisions are pure functions of the fault key the protocol layer
derives per (session, op, step), so a given session replays identically —
which is what lets the engine's device-retry distinguish transient faults
(fresh session → fresh key → possibly clean) from persistent ones.
"""
from __future__ import annotations

import random
import threading
import zlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import blinding as B
from repro.kernels.limb_matmul.ref import P

KINDS = ("bit_flip", "row_swap", "stale", "adaptive")
LIVENESS_KINDS = ("crash", "hang", "flaky", "brownout")


class DeviceCrash(RuntimeError):
    """The untrusted device raised (or was abandoned) mid-dispatch."""


def stable_seed(*parts) -> int:
    """Process-independent integer seed from reprable parts. (Seeding
    random.Random with a tuple is deprecated AND goes through hash(),
    which PYTHONHASHSEED randomizes — a chaos schedule must replay
    identically across processes, e.g. the subprocess-isolated tests.)"""
    return zlib.crc32(repr(parts).encode())


@dataclass(frozen=True)
class LivenessSpec:
    """Static liveness-corruption plan for one device.

    ``prob``: per-attempt trigger probability (1.0 = deterministic);
    ``decay``: ``flaky`` multiplies the probability by this per *attempt*
    on the same op, so a bounded number of retries always gets through;
    ``delay_s``: ``brownout`` latency inflation; ``ops``: blinded-op
    indices to target (None = every op).
    """
    kind: str
    prob: float = 1.0
    decay: float = 0.5
    delay_s: float = 0.05
    ops: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        assert self.kind in LIVENESS_KINDS, self.kind
        assert 0.0 < self.prob <= 1.0, self.prob
        assert 0.0 <= self.decay <= 1.0, self.decay
        assert self.delay_s >= 0.0, self.delay_s


class UnresponsiveDevice:
    """Host-side liveness injector: perturbs the slot dispatch path.

    ``perturb`` runs ON the device's worker thread before its compute —
    exactly where a real crash/hang would bite. Decisions are
    deterministic in (seed, op, attempt): a chaos schedule replays
    identically, and the per-op attempt counter is what lets ``flaky``
    decay across the plane's retries.
    """

    def __init__(self, spec: LivenessSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed
        self.fired = 0                     # perturbations that triggered
        self._attempts: Dict[int, int] = {}
        self._lock = threading.Lock()

    def _attempt(self, op_index: int) -> int:
        with self._lock:
            k = self._attempts.get(op_index, 0)
            self._attempts[op_index] = k + 1
        return k

    def _gate(self, op_index: int, attempt: int, prob: float) -> bool:
        if prob >= 1.0:
            return True
        u = random.Random(stable_seed(self.seed, self.spec.kind, op_index,
                                      attempt)).random()
        return u < prob

    def perturb(self, *, op_index: int, cancel: threading.Event) -> None:
        """Crash, park, delay — or pass through. ``cancel`` is the slot's
        abandon/shutdown event: an injected hang parks on it instead of
        sleeping unconditionally, so a timed-out dispatch (slot.abandon)
        or a draining close always reclaims the worker thread."""
        spec = self.spec
        if spec.ops is not None and op_index not in spec.ops:
            return
        attempt = self._attempt(op_index)
        if spec.kind == "brownout":
            if self._gate(op_index, attempt, spec.prob):
                self.fired += 1
                cancel.wait(timeout=spec.delay_s)
            return
        prob = spec.prob
        if spec.kind == "flaky":
            prob = spec.prob * (spec.decay ** attempt)
        if not self._gate(op_index, attempt, prob):
            return
        self.fired += 1
        if spec.kind == "hang":
            cancel.wait()                  # parked until abandon/close
        raise DeviceCrash(f"{spec.kind} (op {op_index}, "
                          f"attempt {attempt})")

# fold_in sub-domains of the per-op fault key
_SUB_GATE = 0
_SUB_PICK = 1
_SUB_STALE = 2


@dataclass(frozen=True)
class FaultSpec:
    """Static corruption plan (part of the executor's jit trace).

    ``ops``: blinded-op indices to target (None = every op); ``prob``:
    per-(op, session) corruption probability — 1.0 models a persistent
    adversary, < 1 a flaky part.
    """
    kind: str
    prob: float = 1.0
    ops: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        assert self.kind in KINDS, self.kind
        assert 0.0 < self.prob <= 1.0, self.prob


class DishonestDevice:
    """Corrupts field-domain matmul results inside the trace."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.targeted_ops = 0          # static per-trace accounting

    def _bit_flip(self, y: jax.Array, key: jax.Array) -> jax.Array:
        t, d = y.shape
        ki, kj, kb = jax.random.split(jax.random.fold_in(key, _SUB_PICK), 3)
        i = jax.random.randint(ki, (), 0, t)
        j = jax.random.randint(kj, (), 0, d)
        b = jax.random.randint(kb, (), 0, 23)      # p < 2^23
        flipped = jnp.mod(y[i, j] ^ jnp.left_shift(jnp.int32(1), b), P)
        return y.at[i, j].set(flipped)

    def _row_swap(self, y: jax.Array, key: jax.Array) -> jax.Array:
        t = y.shape[0]
        if t < 2:
            return y
        ka, ko = jax.random.split(jax.random.fold_in(key, _SUB_PICK))
        a = jax.random.randint(ka, (), 0, t)
        bb = jnp.mod(a + jax.random.randint(ko, (), 1, t), t)
        idx = jnp.arange(t).at[a].set(bb).at[bb].set(a)
        return jnp.take(y, idx, axis=0)

    def _stale(self, y: jax.Array, key: jax.Array) -> jax.Array:
        off = B.blinding_stream(jax.random.fold_in(key, _SUB_STALE), y.shape)
        return jnp.mod(y + off, P)

    def corrupt(self, y_field: jax.Array, *, op_index: int, key: jax.Array,
                will_verify: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(possibly) corrupt one device result.

        y_field: (t, d_out) int32 in [0, p); key: per-(session, op, step)
        fault key; will_verify: the integrity layer's (traced) check/skip
        decision for this op — only the ``adaptive`` class reads it.
        Returns (y', changed) with ``changed`` the ground-truth scalar bool
        the IntegrityReport exposes for detection-rate accounting.
        """
        spec = self.spec
        if spec.ops is not None and op_index not in spec.ops:
            return y_field, jnp.bool_(False)
        self.targeted_ops += 1
        if spec.kind in ("bit_flip", "adaptive"):
            y_new = self._bit_flip(y_field, key)
        elif spec.kind == "row_swap":
            y_new = self._row_swap(y_field, key)
        else:
            y_new = self._stale(y_field, key)
        gate = jnp.bool_(True)
        if spec.prob < 1.0:
            gate = (jax.random.uniform(jax.random.fold_in(key, _SUB_GATE))
                    < spec.prob)
        if spec.kind == "adaptive":
            gate = gate & ~will_verify
        y_out = jnp.where(gate, y_new, y_field)
        return y_out, jnp.any(y_out != y_field)
