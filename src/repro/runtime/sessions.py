"""Blinding-session pool: pre-generated (r, u) factor sets, N deep.

PR 1's serving loop double-buffered blinding sessions: after dispatching
batch k it enqueued factor generation for batch k+1 — exactly one session
of slack. Under bursty load that is not enough: two back-to-back batches
drain the buffer and the second one pays the ``r @ W_q`` field matmuls on
the request path again.

``SessionPool`` generalizes the double-buffer into an N-deep ring:

- **keys**: session keys are ``fold_in(root, counter)`` with a fresh
  64-bit entropy root per pool (same construction and rationale as the
  legacy server — a colliding root would reuse one-time pads across
  replicas).
- **refill**: a daemon thread keeps ``depth`` sessions prefetched into the
  executor's ``BlindedLayerCache`` (whose ``max_prefetched`` is raised to
  match). JAX dispatch is async, so the refill thread mostly *enqueues*
  device work that overlaps the batcher thread's inference. A prefetched
  factor set carries everything the session's offload needs: (r, u), the
  Freivalds fold vectors under a verification policy, and — when the
  executor runs a multi-device plane — the PER-SHARD fold vectors
  (core/precompute.py ``shards``), so shard-local verification material
  is off the request path too.
- **reuse guard**: every key handed out is remembered (as bytes) and
  re-issue raises — the one-time-pad argument (DESIGN.md §3) dies the
  moment a session is used twice. ``stats()`` exposes
  consumed/refilled/misses/reuse-checked counters for EngineStats.
- **fault containment**: a prefetch that raises increments
  ``refill_errors`` and the loop keeps going — ``acquire`` falls back to
  synchronous factors for that session. ``refill_fault`` is the chaos
  harness's injection point (runtime/chaos.py): a callable run before
  each prefetch, so a drill can script exactly this failure mode.

The pool is executor-agnostic: before the first batch builds the layer
cache, ``prepare`` is a no-op and ``acquire`` simply hands out fresh keys
(factors are then computed on the request path once, as in the seed).
"""
from __future__ import annotations

import os
import threading
from typing import Callable, Dict, Optional, Set

import jax
import numpy as np


class SessionReuseError(RuntimeError):
    """A blinding session key was issued twice — one-time pad violation."""


class SlotReuseError(RuntimeError):
    """A (session, token) factor slot was issued twice — each decode step's
    pads are one-time material exactly like a forward session's
    (DESIGN.md §16): re-issuing token t would offload two different
    activation vectors under the same r."""


def fresh_root(seed: Optional[int] = None) -> jax.Array:
    """64 entropy bits via two 32-bit words (PRNGKey seeds are C-long)."""
    if seed is not None:
        return jax.random.fold_in(jax.random.PRNGKey(seed & 0xFFFFFFFF),
                                  (seed >> 32) & 0xFFFFFFFF)
    w0, w1 = np.frombuffer(os.urandom(8), np.uint32)
    return jax.random.fold_in(jax.random.PRNGKey(int(w0)), int(w1))


class SessionPool:
    """N-deep pre-generated blinding-session ring for one executor."""

    def __init__(self, executor=None, *, depth: int = 4,
                 root: Optional[jax.Array] = None,
                 background: bool = True,
                 refill_fault: Optional[Callable[[int], None]] = None):
        assert depth >= 1, depth
        self.executor = executor
        self.depth = depth
        # chaos hook: called with the session counter before each prefetch;
        # raising makes that refill fail exactly like a real one would
        self.refill_fault = refill_fault
        self._root = root if root is not None else fresh_root()
        self._next = 0                     # next counter to prefetch
        self._head = 0                     # next counter to hand out
        self._issued: Set[bytes] = set()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._closed = False
        # telemetry
        self.consumed = 0
        self.refilled = 0
        self.misses = 0                    # acquired with factors not ready
        self.refill_errors = 0
        self.reuse_checked = 0
        cache = self._cache()
        if cache is not None:
            cache.max_prefetched = max(depth, cache.max_prefetched)
        self._thread: Optional[threading.Thread] = None
        if background:
            self._thread = threading.Thread(
                target=self._refill_loop, name="session-pool-refill",
                daemon=True)
            self._thread.start()

    # -- internals ---------------------------------------------------------
    def _cache(self):
        return getattr(self.executor, "cache", None) if self.executor else None

    def _caches(self):
        """Every layer cache the executor has built — keyed by
        (plan digest, batch shape) since PR 4, so a re-planned executor
        never aliases another plan's prefetch ring.

        The executor swaps ``cache`` per input shape ((model, shape)
        buckets each get their own), so prefetching only into the current
        one would thrash under mixed-shape traffic: every shape switch
        would miss and pay the factor matmuls on the hot path. Prefetching
        each session into all known shape caches costs depth x n_shapes
        factor sets (FIFO-evicted, bounded by max_prefetched) and keeps
        every bucket hitting."""
        if self.executor is None:
            return []
        # snapshot the attribute once: the executor rebinds _caches
        # copy-on-write (origami.py), so the dict we iterate never mutates
        by_shape = getattr(self.executor, "_caches", {})
        caches = {id(c): c for c in by_shape.values() if c is not None}
        cur = self._cache()
        if cur is not None:
            caches.setdefault(id(cur), cur)
        return list(caches.values())

    def _key_for(self, counter: int) -> jax.Array:
        return jax.random.fold_in(self._root, counter)

    def _prefetch(self, counter: int) -> bool:
        """Generate factors for one future session. False if no cache yet."""
        if self.refill_fault is not None:
            self.refill_fault(counter)
        caches = self._caches()
        for cache in caches:
            cache.max_prefetched = max(self.depth + 1, cache.max_prefetched)
            cache.prefetch(self._key_for(counter))
        return bool(caches)

    def _refill_loop(self) -> None:
        while True:
            with self._cv:
                while not self._closed and (
                        self._next - self._head >= self.depth
                        or self._cache() is None):
                    # before the first batch builds the layer cache there is
                    # nothing to prefetch — poll instead of burning counters
                    self._cv.wait(
                        timeout=0.05 if self._cache() is None else None)
                if self._closed:
                    return
                counter = self._next
                self._next += 1
            try:
                ok = self._prefetch(counter)
            except Exception:  # noqa: BLE001 — a dead refill thread would
                # silently put every factor matmul back on the hot path;
                # count the failure and keep the loop alive (acquire()
                # falls back to synchronous factors for this session)
                with self._lock:
                    self.refill_errors += 1
                continue
            if ok:
                with self._lock:
                    self.refilled += 1

    # -- public API --------------------------------------------------------
    def acquire(self) -> jax.Array:
        """Pop the next never-before-issued session key.

        The key's factors are prefetched whenever the executor's layer
        cache exists; a miss (factors not ready) is counted, not fatal —
        the executor computes them synchronously on first use.
        """
        with self._cv:
            counter = self._head
            self._head += 1
            if self._head > self._next:     # outran the refill thread
                self._next = self._head
            key = self._key_for(counter)
            kb = np.asarray(key).tobytes()
            self.reuse_checked += 1
            if kb in self._issued:
                raise SessionReuseError(
                    f"blinding session {counter} issued twice")
            self._issued.add(kb)
            self.consumed += 1
            cache = self._cache()
            if cache is None or not cache.prefetched(key):
                self.misses += 1
            self._cv.notify_all()           # wake refill to top the pool up
        return key

    def prime(self) -> None:
        """Synchronously top the pool up (e.g. right after the first batch
        built the layer cache, or when running without the thread)."""
        with self._lock:
            start, self._next = self._next, max(self._next,
                                                self._head + self.depth)
            stop = self._next
        for c in range(start, stop):
            try:
                ok = self._prefetch(c)
            except Exception:  # noqa: BLE001 — same containment as the loop
                with self._lock:
                    self.refill_errors += 1
                continue
            if ok:
                with self._lock:
                    self.refilled += 1

    def ready(self) -> int:
        """How many handed-out-next sessions have factors prefetched."""
        cache = self._cache()
        if cache is None:
            return 0
        with self._lock:
            head, nxt = self._head, self._next
        return sum(cache.prefetched(self._key_for(c))
                   for c in range(head, nxt))

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"consumed": self.consumed, "refilled": self.refilled,
                    "misses": self.misses, "reuse_checked": self.reuse_checked,
                    "refill_errors": self.refill_errors,
                    "depth": self.depth, "pending": self._next - self._head}

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    def acquire_stream(self, cache, *, lo: int = 0, depth: int = 8,
                       background: bool = True):
        """Pop the next never-reused session key AND bind a per-token
        factor stream to it: ``(key, TokenSlotRing | None)``.

        ``cache`` is the executor's decode-walk BlindedLayerCache
        (core/origami.py ``decode_cache``) — per-session material, so it
        is NOT part of the pool's forward prefetch ring; each generate
        stream gets its own ring whose slots are keyed by token index.
        ``None`` ring when the decode plan has nothing to blind."""
        key = self.acquire()
        if cache is None:
            return key, None
        return key, TokenSlotRing(cache, key, lo=lo, depth=depth,
                                  background=background,
                                  refill_fault=self.refill_fault)


class TokenSlotRing:
    """Streaming per-token blinding/fold slots for ONE decode session.

    The SessionPool's ring is N sessions deep — a fixed set of
    (session, layer, step=0) factor sets for single-shot traces. Decode
    needs an UNBOUNDED stream instead: every generated token consumes the
    (session, layer, token) factor set of every offloaded op in the scan
    segment. The ring keeps ``depth`` future token slots prefetched
    through ``BlindedLayerCache.session_factors(key, step=token)`` — the
    token index rides the factor keying's existing ``step`` slot, which is
    exactly the stream the jitted token step derives live (ctx.step = the
    traced position), so ring-fed and live traces are bit-identical.

    - **reuse guard**: ``take(token)`` remembers every token index issued
      and raises SlotReuseError on re-issue — one-time pads per
      (session, token, layer).
    - **refill**: a daemon thread tops the ring up ahead of the consumer;
      outrunning it is a counted miss (``take`` falls back to synchronous
      factor generation), never an error.
    - **fault containment**: a failing refill increments
      ``refill_errors`` and the thread keeps going; ``refill_fault`` is
      the chaos hook (called with the token index), as in SessionPool.
    """

    def __init__(self, cache, session_key, *, lo: int = 0, depth: int = 8,
                 background: bool = True,
                 refill_fault: Optional[Callable[[int], None]] = None):
        assert depth >= 1, depth
        self.cache = cache
        self.session_key = session_key
        self.depth = depth
        self.refill_fault = refill_fault
        self._issued: Set[int] = set()
        self._head = lo                    # lowest token not yet taken
        self._next = lo                    # next token to prefetch
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._closed = False
        self.consumed = 0
        self.refilled = 0
        self.misses = 0
        self.refill_errors = 0
        # the ring's slots must not FIFO-evict each other before they are
        # taken; leave slack for a take that jumps the head forward
        cache.max_prefetched = max(depth + 2, cache.max_prefetched)
        self._thread: Optional[threading.Thread] = None
        if background:
            self._thread = threading.Thread(
                target=self._refill_loop, name="token-slot-refill",
                daemon=True)
            self._thread.start()

    def _refill_loop(self) -> None:
        while True:
            with self._cv:
                while not self._closed and (
                        self._next - self._head >= self.depth):
                    self._cv.wait()
                if self._closed:
                    return
                token = self._next
                self._next += 1
            try:
                if self.refill_fault is not None:
                    self.refill_fault(token)
                self.cache.prefetch(self.session_key, step=token)
            except Exception:  # noqa: BLE001 — keep the stream alive; the
                # consumer falls back to synchronous factors for this token
                with self._lock:
                    self.refill_errors += 1
                continue
            with self._lock:
                self.refilled += 1

    # -- public API --------------------------------------------------------
    def take(self, token: int):
        """Factor set for decode step ``token`` — prefetched if the ring
        kept up, synchronously generated otherwise (counted miss). Raises
        SlotReuseError if this (session, token) was ever issued before."""
        token = int(token)
        with self._cv:
            if self._closed:
                raise RuntimeError("token-slot ring closed")
            if token in self._issued:
                raise SlotReuseError(
                    f"token slot {token} issued twice for this session")
            self._issued.add(token)
            self.consumed += 1
            if token >= self._head:
                self._head = token + 1
            if self._head > self._next:    # consumer outran the refill
                self._next = self._head
            if not self.cache.prefetched(self.session_key, step=token):
                self.misses += 1
            self._cv.notify_all()          # wake refill to top the ring up
        return self.cache.take(self.session_key, step=token)

    def ready(self) -> int:
        """How many not-yet-taken upcoming slots are prefetched."""
        with self._lock:
            head, nxt = self._head, self._next
        return sum(self.cache.prefetched(self.session_key, step=t)
                   for t in range(head, nxt))

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"consumed": self.consumed, "refilled": self.refilled,
                    "misses": self.misses,
                    "refill_errors": self.refill_errors,
                    "depth": self.depth,
                    "pending": self._next - self._head}

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
