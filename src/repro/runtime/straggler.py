"""Straggler mitigation: step deadlines, heartbeats, backup-step policy.

At pod scale the dominant failure modes are (a) a host that dies (handled
by elastic restart) and (b) a host that *slows down* (thermal, ECC,
network) and drags every synchronous step with it. The watchdog tracks a
robust moving estimate of step time and flags steps exceeding
``deadline_factor``× the P50; after ``tolerance`` consecutive flags the
policy escalates to the launcher (checkpoint + re-mesh without the slow
host — the same path as a failure, but proactive).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from collections import deque
from typing import Deque, Optional


@dataclasses.dataclass
class WatchdogConfig:
    deadline_factor: float = 3.0
    warmup_steps: int = 10
    window: int = 50
    tolerance: int = 3


def _median(values) -> float:
    """Proper median (mean of the two middles on even-length windows).

    The seed used ``sorted(h)[len(h) // 2]`` — the UPPER median — which
    systematically inflated the deadline baseline on even-length windows
    (a 3× deadline silently became up to 3× the worst-half boundary), so
    genuinely slow steps could pass. The offload plane's straggler hedging
    (parallel/offload_sharding.py) keys its duplicate-dispatch deadline off
    this estimate, so the bias became load-bearing."""
    return float(statistics.median(values))


class StepWatchdog:
    def __init__(self, cfg: Optional[WatchdogConfig] = None):
        self.cfg = cfg or WatchdogConfig()
        self.history: Deque[float] = deque(maxlen=self.cfg.window)
        self.consecutive_slow = 0
        self.flagged_steps = 0
        self._t0: Optional[float] = None

    def start_step(self, now: Optional[float] = None):
        self._t0 = now if now is not None else time.monotonic()

    def end_step(self, now: Optional[float] = None) -> bool:
        """Returns True if the step breached its deadline."""
        assert self._t0 is not None, "end_step without start_step"
        dt = (now if now is not None else time.monotonic()) - self._t0
        self._t0 = None
        slow = False
        if len(self.history) >= self.cfg.warmup_steps:
            slow = dt > self.cfg.deadline_factor * _median(self.history)
        self.history.append(dt)
        if slow:
            self.flagged_steps += 1
            self.consecutive_slow += 1
        else:
            self.consecutive_slow = 0
        return slow

    @property
    def should_escalate(self) -> bool:
        """Launcher should checkpoint + re-mesh without the slow host."""
        return self.consecutive_slow >= self.cfg.tolerance

    @property
    def p50(self) -> Optional[float]:
        if not self.history:
            return None
        return _median(self.history)

    def deadline(self, factor: Optional[float] = None,
                 floor: float = 0.0,
                 cold: Optional[float] = None) -> Optional[float]:
        """``factor × P50`` once warm, else ``cold``.

        The one deadline baseline both consumers share: the offload
        plane's straggler-hedge trigger and its hard per-dispatch
        liveness timeout (parallel/offload_sharding.py) key off the same
        robust estimate, just with different factors. ``floor`` guards
        against sub-millisecond P50s turning scheduler jitter into
        timeouts; ``cold`` is the pre-warmup fallback (None = no
        deadline until the window warms)."""
        if len(self.history) < self.cfg.warmup_steps:
            return cold
        p50 = _median(self.history)
        f = self.cfg.deadline_factor if factor is None else factor
        return max(f * p50, floor)
