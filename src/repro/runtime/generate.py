"""Autoregressive generation: prefill + decode loop, optionally under the
Origami two-tier protocol (tier-1 blocks run the Slalom blinded-dense
context *per decode step*; tier-2 and the LM head run open).

This is the LM-serving realization of the paper's partitioned inference:
the per-token hidden prefix stays blinded/in-enclave while the bulk of the
network runs on the untrusted accelerator — the KV cache for tier-1 layers
conceptually lives in the trusted domain (cache rows for layers < p),
which `tier1_cache_bytes` accounts for against the EPC budget.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import integrity as IG
from repro.core import origami as OG
from repro.core import slalom as SL
from repro.core.blinding import BlindingSpec
from repro.models import layers as L
from repro.models import model as M
from repro.runtime.sessions import TokenSlotRing


@dataclass
class GenerationResult:
    tokens: jax.Array              # (B, prompt+new)
    telemetry: Optional[SL.Telemetry]


def _sample(logits, key, temperature: float, vocab_size: int):
    logits = logits[..., :vocab_size].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


# jit caches keyed on the (hashable, frozen) config: recreating the jitted
# callable per generate() call would retrace/recompile on every sequence
@functools.lru_cache(maxsize=None)
def _jit_decode_step(cfg: ModelConfig):
    return jax.jit(functools.partial(M.decode_step, cfg=cfg))


@functools.lru_cache(maxsize=None)
def _jit_prefill(cfg: ModelConfig, max_seq: int, vlm: bool):
    fn = M.prefill_vlm if vlm else M.prefill
    return jax.jit(functools.partial(fn, cfg=cfg, max_seq=max_seq))


@functools.lru_cache(maxsize=None)
def _jit_prefill_recurrent(cfg: ModelConfig, S0: int):
    @jax.jit
    def prefill_recurrent(params, prompt, caches):
        logits, caches = M.decode_step(params, prompt[:, 0:1], caches,
                                       jnp.int32(0), cfg)

        def body(t, carry):
            _, c = carry
            tok = jax.lax.dynamic_slice_in_dim(prompt, t, 1, axis=1)
            return M.decode_step(params, tok, c, t, cfg)

        return jax.lax.fori_loop(1, S0, body, (logits, caches))

    return prefill_recurrent


def generate(params, prompt, cfg: ModelConfig, *, max_new_tokens: int,
             temperature: float = 0.0, key=None) -> GenerationResult:
    """Open (non-private) generation for any family with a decode path."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B, S0 = prompt.shape
    total = S0 + max_new_tokens

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        batch = {"tokens": prompt}
        logits, caches = _jit_prefill(cfg, total, cfg.family == "vlm")(
            params, batch)
    else:
        # recurrent families: build state by stepping through the prompt —
        # ONE jitted fori_loop over the token index instead of S0 eager
        # decode_step dispatches (each step is the same computation up to
        # the token slice, so the loop compiles once and prompt
        # processing pays no per-token Python/dispatch overhead)
        caches = M.init_caches(cfg, B, total)
        logits, caches = _jit_prefill_recurrent(cfg, S0)(params, prompt,
                                                         caches)

    decode = _jit_decode_step(cfg)
    tokens = prompt
    key, k = jax.random.split(key)
    nxt = _sample(logits[:, -1], k, temperature, cfg.vocab_size)[:, None]
    tokens = jnp.concatenate([tokens, nxt], axis=1)
    for t in range(S0, total - 1):
        logits, caches = decode(params, tokens[:, -1:], caches,
                                jnp.int32(t))
        key, k = jax.random.split(key)
        nxt = _sample(logits[:, 0], k, temperature, cfg.vocab_size)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
    return GenerationResult(tokens=tokens, telemetry=None)


def generate_origami(params, prompt, cfg: ModelConfig, *,
                     max_new_tokens: int, partition: Optional[int] = None,
                     temperature: float = 0.0, session_key=None,
                     key=None) -> GenerationResult:
    """Two-tier private generation (dense/moe families).

    Every decode step runs blocks [0, p) under the blinded-dense context
    and [p, L) open — the per-step analogue of the paper's Fig. 3a flow.
    """
    assert cfg.family in ("dense", "moe"), cfg.family
    p = partition if partition is not None else cfg.origami.tier1_layers
    key = key if key is not None else jax.random.PRNGKey(0)
    session_key = (session_key if session_key is not None
                   else jax.random.PRNGKey(7))
    ctx = SL.SlalomContext(session_key, BlindingSpec())
    B, S0 = prompt.shape
    total = S0 + max_new_tokens
    caches = M.init_caches(cfg, B, total)

    def tiered_step(params, token, caches, pos, step_key):
        x = M.embed_tokens_at(params, token, pos, cfg)        # enclave
        with L.dense_impl(functools.partial(SL.blinded_dense, ctx)):
            x, caches = M.decode_range(params, x, caches, pos, cfg, 0, p)
        x, caches = M.decode_range(params, x, caches, pos, cfg, p,
                                   cfg.num_layers)             # open
        logits = M.head(params, x, cfg)
        nxt = _sample(logits[:, 0], step_key, temperature, cfg.vocab_size)
        return nxt[:, None], caches

    tokens = prompt
    for t in range(total - 1):
        feed = tokens[:, t:t + 1] if t < S0 else tokens[:, -1:]
        key, k = jax.random.split(key)
        nxt, caches = tiered_step(params, feed, caches, jnp.int32(t), k)
        if t >= S0 - 1:
            tokens = jnp.concatenate([tokens, nxt], axis=1)
    return GenerationResult(tokens=tokens, telemetry=ctx.telemetry)


@dataclass
class PrivateGenerationResult:
    """Outcome of one ``private_generate`` stream.

    ``logits``: (B, max_new_tokens, vocab) — the logits each sampled token
    was drawn from (position S0-1 .. total-2), the bit-exactness surface
    the ``trusted=True`` recompute oracle is compared against.
    ``telemetry`` is the last per-step trace snapshot (static per step —
    multiply by ``decode_steps`` for whole-stream totals); ``integrity``
    concatenates the per-op fold outcomes of the prefill pass and every
    decode step, in execution order."""
    tokens: jax.Array                    # (B, prompt+new)
    logits: jax.Array                    # (B, new, vocab)
    telemetry: Optional[SL.Telemetry]
    integrity: IG.IntegrityReport
    ring: Optional[Dict[str, int]]       # TokenSlotRing.stats(), None if
    trusted: bool                        # nothing was blinded / trusted
    plan_digest: str                     # DecodePlan digest (attestation)
    decode_steps: int


def _concat_reports(reps) -> IG.IntegrityReport:
    cat = lambda xs: (jnp.concatenate(xs) if xs
                      else jnp.zeros((0,), jnp.bool_))
    return IG.IntegrityReport(
        checked=cat([r.checked for r in reps if r.n_ops]),
        failed=cat([r.failed for r in reps if r.n_ops]),
        corrupted=cat([r.corrupted for r in reps if r.n_ops]))


def private_generate(params, prompt, cfg: ModelConfig, *,
                     max_new_tokens: int, partition: Optional[int] = None,
                     integrity: Optional[IG.IntegrityPolicy] = None,
                     temperature: float = 0.0, session_key=None, key=None,
                     trusted: bool = False, ring_depth: int = 8,
                     executor: Optional["OG.OrigamiExecutor"] = None,
                     jit: bool = True) -> PrivateGenerationResult:
    """Private autoregressive generation under a DecodePlan (DESIGN.md §16).

    Prefill runs the prompt through the BASE plan's segments (blinded
    prefix offloaded per-op, open suffix in the clear); each decode step
    walks the plan's scan segments, consuming one per-token slot from a
    streaming TokenSlotRing for its blinded KV-cache-facing matmuls and
    folding a per-step Freivalds check over every offloaded op.

    ``trusted=True`` is the recovery oracle: the same quantized math runs
    entirely inside the enclave (no device, no blinding, no ring) and the
    logits — hence the sampled tokens — are bit-identical to the honest
    offloaded path. ``executor``: reuse a prepared OrigamiExecutor (its
    decode plan is attached on first use); otherwise one is built from
    ``partition``/``integrity``."""
    key = key if key is not None else jax.random.PRNGKey(0)
    session_key = (session_key if session_key is not None
                   else jax.random.PRNGKey(7))
    B, S0 = prompt.shape
    total = S0 + max_new_tokens
    if executor is None:
        executor = OG.OrigamiExecutor(cfg, params, "origami", partition,
                                      integrity=integrity)
    if executor.dplan is None:
        executor.attach_decode_plan(max_steps=max_new_tokens)
    ring = None
    if not trusted:
        cache = executor.decode_cache(B)
        if cache is not None:
            # decode positions start at S0 >= 1; prefill ops use step 0 —
            # the ring's slot domain never collides with the prompt's
            ring = TokenSlotRing(cache, session_key, lo=S0,
                                 depth=ring_depth)
    logits, caches, rep = executor.prefill_session(
        prompt, session_key, max_seq=total, trusted=trusted, jit=jit)
    reps = [rep]
    key, k = jax.random.split(key)
    nxt = _sample(logits[:, -1], k, temperature, cfg.vocab_size)[:, None]
    tokens = jnp.concatenate([prompt, nxt], axis=1)
    step_logits = [logits[:, -1]]
    for t in range(S0, total - 1):
        factors = ring.take(t) if ring is not None else None
        logits, caches, rep = executor.decode_once(
            tokens[:, -1:], caches, t, session_key, factors,
            trusted=trusted, jit=jit)
        reps.append(rep)
        key, k = jax.random.split(key)
        nxt = _sample(logits[:, 0], k, temperature, cfg.vocab_size)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        step_logits.append(logits[:, 0])
    ring_stats = None
    if ring is not None:
        ring_stats = ring.stats()
        ring.close()
    return PrivateGenerationResult(
        tokens=tokens, logits=jnp.stack(step_logits, axis=1),
        telemetry=executor.telemetry, integrity=_concat_reports(reps),
        ring=ring_stats, trusted=trusted,
        plan_digest=executor.dplan.digest,
        decode_steps=max(0, max_new_tokens - 1))


class GenerateExecutor(OG.OrigamiExecutor):
    """Engine adapter: serve private token STREAMS through the sealed
    single-shot batcher (runtime/engine.py).

    A request's payload is the prompt — ``prompt_len`` int32 token ids
    riding the float32 sealing channel — and the response is the full
    generated sequence, returned tokens-as-logits (float32 is exact for
    every vocab < 2^24, and the engine's seal path already ships float32
    rows). ``infer`` runs the whole prefill + decode loop per batch:
    greedy/fixed-key sampling, so the §9 recovery ladder's trusted
    recompute reproduces the stream bit-for-bit. The attested digest is
    the DECODE plan's (covers the scan structure, not just the base
    plan). Decode-aware bucket selection comes for free: the engine pads
    to the §15 shape-bucket ladder, and ``warm_aot`` compiles the
    per-bucket prefill + token-step executables (plus trusted twins) and
    builds each bucket's decode factor cache."""

    def __init__(self, cfg: ModelConfig, params, *, prompt_len: int,
                 max_new_tokens: int, mode: str = "origami",
                 partition: Optional[int] = None,
                 integrity: Optional[IG.IntegrityPolicy] = None,
                 ring_depth: int = 8, temperature: float = 0.0, **kw):
        super().__init__(cfg, params, mode, partition,
                         integrity=integrity, **kw)
        self.prompt_len = int(prompt_len)
        self.max_new_tokens = int(max_new_tokens)
        self.ring_depth = int(ring_depth)
        self.temperature = float(temperature)
        self.attach_decode_plan(max_steps=self.max_new_tokens)
        # engine.warm consults these instead of the CNN image shape
        self.request_shape: Tuple[int, ...] = (self.prompt_len,)
        self.response_elems: int = self.prompt_len + self.max_new_tokens

    @property
    def attested_digest(self) -> str:
        return self.dplan.digest

    def infer(self, batch, session_key=None, jit: bool = True,
              trusted: bool = False) -> OG.OrigamiResult:
        (prompt,) = batch.values()
        prompt = jnp.asarray(prompt, jnp.int32)
        assert prompt.shape[1] == self.prompt_len, prompt.shape
        key = (session_key if session_key is not None
               else jax.random.PRNGKey(0))
        res = private_generate(
            self.params, prompt, self.cfg,
            max_new_tokens=self.max_new_tokens,
            temperature=self.temperature, session_key=key,
            key=jax.random.PRNGKey(0),   # fixed sampling stream: recovery
            trusted=trusted,             # recompute must replay the tokens
            ring_depth=self.ring_depth, executor=self, jit=jit)
        self._tele_last = (self._tele_trusted if trusted
                           else self._tele_blinded)
        return OG.OrigamiResult(
            logits=res.tokens.astype(jnp.float32), boundary=None,
            telemetry=self.telemetry, integrity=res.integrity,
            trusted=trusted, sharding=None)

    def warm_aot(self, input_key: str, request_shape, buckets,
                 dtype=None, trusted_too: bool = True) -> int:
        n = 0
        for b in buckets:
            n += self.warm_decode_aot(
                int(b), self.prompt_len,
                self.prompt_len + self.max_new_tokens,
                trusted_too=trusted_too)
        return n


def tier1_cache_bytes(cfg: ModelConfig, batch: int, max_seq: int,
                      partition: Optional[int] = None) -> int:
    """KV-cache bytes that must stay in the trusted domain (layers < p)."""
    p = partition if partition is not None else cfg.origami.tier1_layers
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        width = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return p * batch * max_seq * width * 2
    return p * batch * max_seq * cfg.num_kv_heads * hd * 2 * 2
