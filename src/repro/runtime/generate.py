"""Autoregressive generation: prefill + decode loop, optionally under the
Origami two-tier protocol (tier-1 blocks run the Slalom blinded-dense
context *per decode step*; tier-2 and the LM head run open).

This is the LM-serving realization of the paper's partitioned inference:
the per-token hidden prefix stays blinded/in-enclave while the bulk of the
network runs on the untrusted accelerator — the KV cache for tier-1 layers
conceptually lives in the trusted domain (cache rows for layers < p),
which `tier1_cache_bytes` accounts for against the EPC budget.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import slalom as SL
from repro.core.blinding import BlindingSpec
from repro.models import layers as L
from repro.models import model as M


@dataclass
class GenerationResult:
    tokens: jax.Array              # (B, prompt+new)
    telemetry: Optional[SL.Telemetry]


def _sample(logits, key, temperature: float, vocab_size: int):
    logits = logits[..., :vocab_size].astype(jnp.float32)
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def generate(params, prompt, cfg: ModelConfig, *, max_new_tokens: int,
             temperature: float = 0.0, key=None) -> GenerationResult:
    """Open (non-private) generation for any family with a decode path."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B, S0 = prompt.shape
    total = S0 + max_new_tokens

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        batch = {"tokens": prompt}
        logits, caches = (M.prefill_vlm if cfg.family == "vlm" else M.prefill)(
            params, batch, cfg, max_seq=total)
    else:
        # recurrent families: build state by stepping through the prompt
        caches = M.init_caches(cfg, B, total)
        logits = None
        for t in range(S0):
            logits, caches = M.decode_step(params, prompt[:, t:t + 1],
                                           caches, jnp.int32(t), cfg)

    decode = jax.jit(functools.partial(M.decode_step, cfg=cfg))
    tokens = prompt
    key, k = jax.random.split(key)
    nxt = _sample(logits[:, -1], k, temperature, cfg.vocab_size)[:, None]
    tokens = jnp.concatenate([tokens, nxt], axis=1)
    for t in range(S0, total - 1):
        logits, caches = decode(params, tokens[:, -1:], caches,
                                jnp.int32(t))
        key, k = jax.random.split(key)
        nxt = _sample(logits[:, 0], k, temperature, cfg.vocab_size)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
    return GenerationResult(tokens=tokens, telemetry=None)


def generate_origami(params, prompt, cfg: ModelConfig, *,
                     max_new_tokens: int, partition: Optional[int] = None,
                     temperature: float = 0.0, session_key=None,
                     key=None) -> GenerationResult:
    """Two-tier private generation (dense/moe families).

    Every decode step runs blocks [0, p) under the blinded-dense context
    and [p, L) open — the per-step analogue of the paper's Fig. 3a flow.
    """
    assert cfg.family in ("dense", "moe"), cfg.family
    p = partition if partition is not None else cfg.origami.tier1_layers
    key = key if key is not None else jax.random.PRNGKey(0)
    session_key = (session_key if session_key is not None
                   else jax.random.PRNGKey(7))
    ctx = SL.SlalomContext(session_key, BlindingSpec())
    B, S0 = prompt.shape
    total = S0 + max_new_tokens
    caches = M.init_caches(cfg, B, total)

    def tiered_step(params, token, caches, pos, step_key):
        x = M.embed_tokens_at(params, token, pos, cfg)        # enclave
        with L.dense_impl(functools.partial(SL.blinded_dense, ctx)):
            x, caches = M.decode_range(params, x, caches, pos, cfg, 0, p)
        x, caches = M.decode_range(params, x, caches, pos, cfg, p,
                                   cfg.num_layers)             # open
        logits = M.head(params, x, cfg)
        nxt = _sample(logits[:, 0], step_key, temperature, cfg.vocab_size)
        return nxt[:, None], caches

    tokens = prompt
    for t in range(total - 1):
        feed = tokens[:, t:t + 1] if t < S0 else tokens[:, -1:]
        key, k = jax.random.split(key)
        nxt, caches = tiered_step(params, feed, caches, jnp.int32(t), k)
        if t >= S0 - 1:
            tokens = jnp.concatenate([tokens, nxt], axis=1)
    return GenerationResult(tokens=tokens, telemetry=ctx.telemetry)


def tier1_cache_bytes(cfg: ModelConfig, batch: int, max_seq: int,
                      partition: Optional[int] = None) -> int:
    """KV-cache bytes that must stay in the trusted domain (layers < p)."""
    p = partition if partition is not None else cfg.origami.tier1_layers
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        width = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return p * batch * max_seq * width * 2
    return p * batch * max_seq * cfg.num_kv_heads * hd * 2 * 2
