import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_5_14b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Each cell writes a JSON artifact with memory_analysis / cost_analysis /
loop-aware HLO collective bytes + dot FLOPs (parallel/hlo_analysis.py) —
the §Roofline inputs. The XLA_FLAGS line above MUST precede any jax import
(jax locks the device count at first init); smoke tests and benches never
import this module, so they see 1 device.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import (ARCHS, SHAPES, SKIPPED_CELLS, applicable_shapes,
                           get_config)
from repro.configs.base import MeshConfig, TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import (default_microbatches, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.parallel.hlo_analysis import analyze_hlo
from repro.parallel.sharding import make_plan, sanitize_shardings


def cell_name(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path, overrides: dict = None,
             microbatches: int = 0) -> dict:
    t_start = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh_cfg = MeshConfig(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mode = "train" if shape.kind == "train" else "serve"
    plan = make_plan(cfg, shape, mesh, mesh_cfg, mode)
    tcfg = TrainConfig(microbatches=microbatches
                       or default_microbatches(cfg, shape))

    kind, args = input_specs(cfg, shape, tcfg)
    if kind == "train":
        step = make_train_step(cfg, tcfg)
        in_shardings = (plan.param_shardings(cfg), plan.opt_shardings(cfg),
                        plan.batch_shardings(cfg, kind))
        donate = (0, 1)
    elif kind == "prefill":
        step = make_prefill_step(cfg, shape)
        in_shardings = (plan.param_shardings(cfg),
                        plan.batch_shardings(cfg, kind))
        donate = ()
    else:
        step = make_decode_step(cfg, shape)
        in_shardings = (plan.param_shardings(cfg), plan.token_sharding(),
                        plan.cache_shardings(cfg), plan.named())
        donate = (2,)
    in_shardings = sanitize_shardings(in_shardings, args, plan.axis_sizes)

    record = {
        "cell": cell_name(arch, shape_name, multi_pod),
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh_cfg.shape), "axes": list(mesh_cfg.axes),
        "kind": kind, "microbatches": tcfg.microbatches,
        "status": "running",
    }
    from repro.parallel.act_sharding import activation_rules
    try:
        with mesh, activation_rules(plan.act_rules, plan.axis_sizes):
            t0 = time.time()
            lowered = jax.jit(step, in_shardings=in_shardings,
                              donate_argnums=donate).lower(*args)
            record["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t0, 2)

        ma = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: getattr(ma, k) for k in dir(ma)
            if k.endswith("bytes") and not k.startswith("_")}
        ca = compiled.cost_analysis() or {}
        record["cost_analysis"] = {
            k: v for k, v in ca.items()
            if k in ("flops", "bytes accessed", "transcendentals")}
        t0 = time.time()
        hlo = compiled.as_text()
        st = analyze_hlo(hlo)
        record["hlo_analysis"] = {
            "dot_flops_per_device": st.dot_flops,
            "hbm_bytes_per_device": st.hbm_bytes,
            "flash_bytes_per_device": st.flash_bytes,
            "collective_bytes_per_device": st.bytes_by_kind,
            "collective_counts": st.count_by_kind,
            "trip_counts": st.trip_counts,
            "analyze_s": round(time.time() - t0, 2),
        }
        record["status"] = "ok"
        print(f"[dryrun] {record['cell']}: OK "
              f"(lower {record['lower_s']}s, compile {record['compile_s']}s)")
        print(f"  memory_analysis: {record['memory_analysis']}")
        print(f"  cost_analysis: {record['cost_analysis']}")
    except Exception as e:  # noqa: BLE001 — record failures as artifacts
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {record['cell']}: FAILED {record['error'][:200]}")
    record["total_s"] = round(time.time() - t_start, 2)

    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{record['cell']}.json"
    path.write_text(json.dumps(record, indent=1, default=str))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out = Path(args.out)

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in applicable_shapes(arch):
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    ok = failed = skipped = 0
    for arch, shape, mp in cells:
        path = out / f"{cell_name(arch, shape, mp)}.json"
        if args.skip_existing and path.exists():
            prev = json.loads(path.read_text())
            if prev.get("status") == "ok":
                skipped += 1
                continue
        rec = run_cell(arch, shape, mp, out,
                       microbatches=args.microbatches)
        ok += rec["status"] == "ok"
        failed += rec["status"] != "ok"
    print(f"[dryrun] done: {ok} ok, {failed} failed, {skipped} skipped; "
          f"{len(SKIPPED_CELLS)} cells skipped by design (DESIGN.md §5)")


if __name__ == "__main__":
    main()
