"""jit-able train / prefill / decode steps (the units the dry-run lowers).

train_step supports microbatched gradient accumulation (scan): cuts stored
activation boundaries by the microbatch factor and lets each microbatch's
reduce-scatter overlap the next microbatch's backward — the compute/comm
overlap lever recorded in EXPERIMENTS §Perf.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import model as M
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns ``step(params, opt, batch)``; with
    ``tcfg.grad_compression`` the signature becomes
    ``step(params, opt, batch, residual) -> (..., residual)`` — int8
    error-feedback compression of the gradient before the (cross-pod)
    reduction (parallel/compression.py)."""
    m = tcfg.microbatches

    def loss(params, batch):
        total, ce = M.loss_fn(params, batch, cfg)
        return total, ce

    def _grads_and_ce(params, batch):
        if m > 1:
            B = batch["tokens"].shape[0]
            assert B % m == 0, (B, m)
            micro = {k: v.reshape((m, B // m) + v.shape[1:])
                     for k, v in batch.items()}

            def body(acc, mb):
                (_, ce), g = jax.value_and_grad(loss, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / m, acc, g)
                return acc, ce

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            grads, ces = jax.lax.scan(body, g0, micro)
            return grads, jnp.mean(ces)
        (_, ce), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch)
        return grads, ce

    def train_step(params, opt_state, batch):
        lr = adamw.lr_schedule(tcfg, opt_state.step)
        grads, ce = _grads_and_ce(params, batch)
        new_params, new_opt, om = adamw.update(grads, opt_state, params,
                                               tcfg, lr)
        return new_params, new_opt, {"loss": ce, "lr": lr, **om}

    def train_step_compressed(params, opt_state, batch, residual):
        from repro.parallel import compression as GC
        lr = adamw.lr_schedule(tcfg, opt_state.step)
        grads, ce = _grads_and_ce(params, batch)
        grads, residual = GC.apply_error_feedback(grads, residual)
        new_params, new_opt, om = adamw.update(grads, opt_state, params,
                                               tcfg, lr)
        return new_params, new_opt, {"loss": ce, "lr": lr, **om}, residual

    return train_step_compressed if tcfg.grad_compression else train_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig):
    def prefill_step(params, batch):
        if cfg.family == "vlm":
            return M.prefill_vlm(params, batch, cfg)
        if cfg.family in ("hybrid", "ssm"):
            # recurrent families: prefill == full forward (state capture is
            # the decode path's job; compute profile identical)
            return M.forward(params, batch, cfg).logits
        return M.prefill(params, batch, cfg)

    return prefill_step


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig):
    def decode_step(params, token, caches, pos):
        return M.decode_step(params, token, caches, pos, cfg)

    return decode_step


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if shape.kind != "train":
        return 1
    big = M.count_params_analytic(cfg) > 1e9
    return 8 if big else 2
