"""End-to-end training driver: data pipeline -> pjit train loop with
checkpoint/resume, async saves, straggler watchdog, and optional gradient
compression. CPU-runnable on smoke configs (examples/train_smollm.py);
the same code path lowers on the production meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm_135m --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.configs.base import MeshConfig, ShapeConfig, TrainConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw
from repro.parallel.act_sharding import activation_rules
from repro.parallel.sharding import make_plan
from repro.runtime.checkpoint import AsyncCheckpointer, latest_step, load
from repro.runtime.straggler import StepWatchdog


def train(cfg, tcfg: TrainConfig, *, batch: int, seq: int, steps: int,
          ckpt_dir: str = None, ckpt_every: int = 50, mesh=None,
          log_every: int = 10, resume: bool = True):
    mesh = mesh or make_host_mesh(data=1, model=1)
    mesh_cfg = MeshConfig()
    shape = ShapeConfig("custom", "train", seq, batch)
    plan = make_plan(cfg, shape, mesh, mesh_cfg, "train")

    key = jax.random.PRNGKey(tcfg.seed)
    params = M.init_params(cfg, key)
    opt_state = adamw.init(params, tcfg)
    start_step = 0

    ckpt = None
    if ckpt_dir:
        ckpt = AsyncCheckpointer(ckpt_dir)
        last = latest_step(ckpt_dir)
        if resume and last is not None:
            (params, opt_state), manifest = load(
                ckpt_dir, (params, opt_state))
            start_step = manifest["step"]
            print(f"[train] resumed from step {start_step}")

    n_data = mesh.shape.get("data", 1)
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, seq, batch,
                                    seed=tcfg.seed),
                         shard=0, num_shards=1)
    step_fn = make_train_step(cfg, tcfg)
    with mesh, activation_rules(plan.act_rules):
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        watchdog = StepWatchdog()
        losses = []
        for step in range(start_step, steps):
            batch_np = pipe.batch(step)
            watchdog.start_step()
            params, opt_state, metrics = jitted(
                params, opt_state, {k: jnp.asarray(v)
                                    for k, v in batch_np.items()})
            slow = watchdog.end_step()
            loss = float(metrics["loss"])
            losses.append(loss)
            if log_every and (step + 1) % log_every == 0:
                print(f"[train] step {step+1}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"p50={watchdog.p50 and round(watchdog.p50, 3)}s"
                      + (" SLOW" if slow else ""))
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          meta={"loss": loss})
            if watchdog.should_escalate:
                print("[train] straggler escalation -> checkpoint + exit "
                      "for re-mesh (runtime/elastic.py)")
                break
        if ckpt:
            ckpt.save(steps, (params, opt_state),
                      meta={"loss": losses[-1] if losses else None})
            ckpt.wait()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps,
                       microbatches=args.microbatches)
    t0 = time.time()
    _, _, losses = train(cfg, tcfg, batch=args.batch, seq=args.seq,
                         steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)
    if losses:
        print(f"[train] done in {time.time()-t0:.1f}s  "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
