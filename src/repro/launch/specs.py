"""Abstract input specs (ShapeDtypeStruct) per (arch × shape) cell.

Nothing here allocates: params/opt/caches come from jax.eval_shape, inputs
are ShapeDtypeStructs. The dry-run lowers against these; the frontend
stubs for [audio]/[vlm] archs provide precomputed frame/patch embeddings
per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import model as M
from repro.optim import adamw


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig,
                   seq_len: int = None) -> Dict[str, jax.ShapeDtypeStruct]:
    B = shape.global_batch
    S = seq_len if seq_len is not None else shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_seq_len, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def abstract_caches(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                tcfg: TrainConfig = None) -> Tuple[str, Tuple[Any, ...]]:
    """Returns (step_kind, abstract argument tuple) for the cell."""
    params = M.abstract_params(cfg)
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        opt = jax.eval_shape(lambda p: adamw.init(p, tcfg), params)
        return "train", (params, opt, abstract_batch(cfg, shape))
    if shape.kind == "prefill":
        return "prefill", (params, abstract_batch(cfg, shape))
    # decode: one new token against a seq_len-sized cache
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    caches = abstract_caches(cfg, shape)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return "decode", (params, token, caches, pos)
