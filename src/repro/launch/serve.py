"""Private-inference serving driver (paper deployment, Fig. 3a).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --model vgg16 --smoke \
        --requests 16 --mode origami

    # async engine over a mixed vgg16/vgg19 fleet, partition from the
    # cost-model planner, logits cross-checked against the legacy server:
    PYTHONPATH=src python -m repro.launch.serve --smoke --engine

    # integrity drill: Freivalds-verify every offloaded op while a
    # dishonest device flips bits — every corruption must be detected,
    # recovered (still bit-exact vs the honest legacy server) and the
    # backend quarantined:
    PYTHONPATH=src python -m repro.launch.serve --smoke --engine \
        --models vgg16 --verify full --inject bit_flip

    # sharded multi-device drill: blinded matmuls row-shard across 2
    # simulated devices with device 1 dishonest — shard-local Freivalds
    # must detect every corruption, retry ONLY the bad shard on the
    # healthy device, and quarantine device 1 (per-device, the model
    # keeps offloading on device 0):
    PYTHONPATH=src python -m repro.launch.serve --smoke --engine \
        --models vgg16 --devices 2 --shard rows --inject bit_flip

    # liveness chaos drill (DESIGN.md §12): a scripted schedule crashes
    # device 0 and hangs device 1 (the engine must degrade to verified
    # enclave-only serving, then recover automatically via breaker
    # probes), fails session refills, and corrupts sealed requests in
    # flight — every future must resolve, the engine must never stop
    # serving, and every served response must stay bit-exact:
    PYTHONPATH=src python -m repro.launch.serve --smoke --engine \
        --models vgg16 --devices 2 --chaos
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core import plan as PL
from repro.core.integrity import IntegrityPolicy
from repro.models import model as M
from repro.privacy.data import make_batch
from repro.runtime.faults import DishonestDevice, FaultSpec
from repro.runtime.serving import PrivateInferenceServer, Request


def _integrity_args(args):
    """(policy, fault_factory) from the --verify / --inject flags."""
    policy = None
    if args.verify != "off":
        policy = (IntegrityPolicy.full(args.verify_k)
                  if args.verify == "full"
                  else IntegrityPolicy.sampled(args.verify_rate,
                                               args.verify_k))
    def fault():
        if args.inject == "none":
            return None
        return DishonestDevice(FaultSpec(args.inject))
    return policy, fault


def _device_pool(args):
    """A fresh DevicePool per model from --devices/--inject flags.

    With a pool, --inject targets ONE device (--inject-device, default the
    last slot) instead of the executor-wide injector — the "one dishonest
    accelerator in the fleet" drill the tier-1 smoke runs."""
    from repro.runtime.devices import DevicePool
    if not args.devices:
        return None
    faults = {}
    if args.inject != "none":
        bad = (args.inject_device if args.inject_device is not None
               else args.devices - 1)
        assert 0 <= bad < args.devices, (bad, args.devices)
        faults[bad] = DishonestDevice(FaultSpec(args.inject))
    return DevicePool(args.devices, faults=faults)


def _placement_for(cfg, args):
    """Resolve --plan to a PlacementPlan (None = legacy --mode path).

    Accepted specs: a legacy mode name ("origami", "slalom", ...); "mixed"
    (blind the first half of tier-1, enclave-reside the rest — a plan no
    mode string can express); "vopen" (origami prefix + verified-open
    tier-2 linear layers under the --verify policy); or an explicit
    per-layer string over the ``oebv`` alphabet (core/plan.py).
    """
    spec = args.plan
    if spec is None:
        return None
    policy, _ = _integrity_args(args)
    verify = policy or IntegrityPolicy.full(1)
    if spec in PL.LEGACY_MODES:
        return PL.compile_mode(cfg, spec)
    if spec == "mixed":
        return PL.make_mixed(cfg)
    if spec == "vopen":
        return PL.make_vopen(cfg, verify=verify)
    return PL.from_string(cfg, spec, verify=verify)


def _print_plans(names, get) -> None:
    """--plan print: the compiled legacy plans + digests per model."""
    for name in names:
        cfg = get(name)
        print(f"[plan] {name} ({cfg.family}, "
              f"{PL.num_blocks(cfg)} blocks, tier1="
              f"{cfg.origami.tier1_layers}):")
        for mode in PL.LEGACY_MODES:
            print(f"  {mode:8s} {PL.compile_mode(cfg, mode).summary()}")


def _flight_recorder(args):
    """A FlightRecorder for --postmortem-dir (None keeps the engine's
    default in-memory ring)."""
    if not args.postmortem_dir:
        return None
    from repro.runtime.profiling import FlightRecorder
    return FlightRecorder(out_dir=args.postmortem_dir)


def _dump_observability(args, engine, tag) -> None:
    """--metrics-out / --postmortem-dir exit dump: one JSON file with the
    unified registry snapshot, the §14 phase decomposition and the flight
    recorder's ring summary."""
    rec = engine.recorder.snapshot()
    if args.postmortem_dir:
        print(f"[{tag}] flight recorder: {rec['dumps']} post-mortem "
              f"bundle(s), {rec['suppressed']} suppressed "
              f"-> {args.postmortem_dir}")
    if not args.metrics_out:
        return
    snap = engine.snapshot()
    with open(args.metrics_out, "w") as f:
        json.dump({"metrics": snap["metrics"], "phases": snap["phases"],
                   "aot": snap["aot"], "buckets": snap["buckets"],
                   "ttfb_cold_s": snap["ttfb_cold_s"],
                   "ttfb_warm_s": snap["ttfb_warm_s"],
                   "flight_recorder": rec}, f, indent=2, sort_keys=True,
                  default=str)
    print(f"[{tag}] metrics snapshot "
          f"({len(snap['metrics']['counters'])} counters, "
          f"{len(snap['metrics']['gauges'])} gauges) -> {args.metrics_out}")


def _sealed_requests(cfg, n, rid0=0, rng=None):
    rng = rng or np.random.default_rng(rid0)
    keys, reqs = [], []
    for i in range(n):
        rid = rid0 + i
        img = make_batch(rid, 1, cfg.image_size)[0]
        key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
        box = PrivateInferenceServer.client_seal(key, img, rid)
        keys.append(key)
        reqs.append(Request(rid=rid, box=box, shape=img.shape,
                            session_key=key))
    return reqs, keys


def run_engine(args) -> None:
    """Mixed-model continuous-batching smoke: vgg16 + vgg19 through one
    ServingEngine, each request's logits cross-checked bit-exactly against
    a legacy synchronous server of the same model."""
    from repro.runtime.engine import EngineConfig, ServingEngine

    get = get_smoke if args.smoke else get_config
    names = [m.strip() for m in args.models.split(",") if m.strip()]
    policy, fault = _integrity_args(args)
    tracer = None
    if args.trace_out:
        from repro.core.tracing import Tracer
        tracer = Tracer(kernel_spans=args.trace_kernels)
    engine = ServingEngine(
        EngineConfig(max_batch=args.batch, max_wait_ms=args.max_wait_ms,
                     compile_cache_dir=args.compile_cache_dir,
                     aot_warm=args.aot_warm),
        tracer=tracer, recorder=_flight_recorder(args))
    legacy, per_model = {}, {}
    for i, name in enumerate(names):
        cfg = get(name)
        params = M.init_params(cfg, jax.random.PRNGKey(i))
        pool = _device_pool(args)
        entry = engine.register_model(name, cfg, params, mode=args.mode,
                                      privacy_floor=args.privacy_floor,
                                      integrity=policy,
                                      # with a pool the injector is
                                      # per-DEVICE (pool slots), not
                                      # executor-wide
                                      fault=None if pool else fault(),
                                      placement=_placement_for(cfg, args),
                                      devices=pool, shard=args.shard)
        print(f"[engine] registered {entry.plan.summary()} "
              f"plan={entry.placement.summary()} "
              f"quote={entry.quote.measurement[:12]}…"
              + (f" devices={pool.size} shard={args.shard}" if pool else ""))
        legacy[name] = PrivateInferenceServer(cfg, params, mode=args.mode,
                                              max_batch=args.batch,
                                              plan=_placement_for(cfg, args))
        if pool is None:
            # same weights, same cache — but NEVER for pooled runs: the
            # cross-check oracle must stay a genuinely single-device
            # executor, or a sharding bug would corrupt both sides alike
            legacy[name].executor = entry.executor
        per_model[name] = cfg

    # interleave the models' request streams (worst case for a
    # fixed-stride batcher, the normal case for the bucket batcher);
    # disjoint rid spaces per model, keys looked up by rid
    n_each = args.requests // len(names)
    streams, key_by_rid = {}, {}
    for i, m in enumerate(per_model):
        reqs, keys = _sealed_requests(per_model[m], n_each,
                                      rid0=n_each * i)
        streams[m] = (reqs, keys)
        key_by_rid.update({r.rid: k for r, k in zip(reqs, keys)})
    t0 = time.time()
    futures = []
    for j in range(n_each):
        for m in names:
            futures.append((m, j, engine.submit(m, streams[m][0][j])))
    responses = [(m, j, f.result(timeout=300)) for m, j, f in futures]
    dt = time.time() - t0
    ok = sum(r.ok for _, _, r in responses)

    # cross-check: every engine response must be bit-identical to the
    # legacy synchronous server run over the same per-model stream
    mismatches = 0
    for m in names:
        reqs, _ = streams[m]
        want = []
        for i in range(0, n_each, args.batch):
            want += legacy[m].serve_batch(reqs[i:i + args.batch])
        want_logits = {r.rid: PrivateInferenceServer.client_open(
            key_by_rid[r.rid], r.box, (per_model[m].num_classes,))
            for r in want if r.ok}
        for _, j, resp in [t for t in responses if t[0] == m]:
            got = PrivateInferenceServer.client_open(
                key_by_rid[resp.rid], resp.box,
                (per_model[m].num_classes,))
            if not np.array_equal(got, want_logits[resp.rid]):
                mismatches += 1
    order = list(engine.completion_order)
    ooo = any(order[k][0] != order[k + 1][0] for k in range(len(order) - 1))
    stats = engine.stats.snapshot(engine)
    print(f"[engine] {ok}/{len(responses)} ok in {dt:.2f}s "
          f"({dt / max(len(responses), 1) * 1e3:.0f} ms/req) "
          f"batches={stats['batches']} padded={stats['padded_slots']} "
          f"out_of_order={ooo}")
    print(f"[engine] p50={stats['p50_latency_s']:.3f}s "
          f"p95={stats['p95_latency_s']:.3f}s "
          f"ttfb={stats['time_to_first_batch_s']:.3f}s "
          f"(cold={stats['ttfb_cold_s']:.3f}s "
          f"warm={stats['ttfb_warm_s']:.3f}s) "
          f"sessions={stats['sessions']}")
    aot = stats["aot"]
    print(f"[engine] aot: compiles={aot['compiles']} "
          f"memo_hits={aot['memo_hits']} disk_hits={aot['disk_hits']} "
          f"compile_s={aot['compile_seconds']:.2f} "
          f"request_compile_s={aot['request_compile_seconds']:.2f} "
          f"buckets={stats['buckets']}")
    print(f"[engine] bit-identical vs legacy: "
          f"{'OK' if mismatches == 0 else f'{mismatches} MISMATCHES'}")
    integ = stats["integrity"]
    if args.verify != "off":
        print(f"[engine] integrity: checks={integ['verify_checks']} "
              f"failures={integ['verify_failures']} "
              f"retries={integ['device_retries']} "
              f"recomputes={integ['recomputes']} "
              f"quarantines={integ['quarantines']} "
              f"flagged={sum(r.flagged for _, _, r in responses)}")
    if args.devices:
        print(f"[engine] offload plane: shard_checks={integ['shard_checks']} "
              f"shard_failures={integ['shard_failures']} "
              f"shard_retries={integ['shard_retries']} "
              f"shard_hedges={integ['shard_hedges']}")
        for name, snap in stats["devices"].items():
            for s in snap["pool"]["slots"]:
                print(f"[engine]   {name} {s['name']}: "
                      f"dispatches={s['dispatches']} "
                      f"failures={s['verify_failures']} "
                      f"quarantined={s['quarantined']} "
                      f"restores={s['restores']}")
    engine.close()
    if tracer is not None:
        n_events = tracer.dump_chrome(args.trace_out)
        print(f"[engine] trace: {len(tracer.spans())} spans "
              f"({n_events} chrome events, dropped={tracer.dropped}) "
              f"-> {args.trace_out}")
        phases = engine.profile_phases()
        roll = phases.get("critical_s", {})
        top = sorted(roll.items(), key=lambda kv: -kv[1])[:4]
        print(f"[engine] phases ({phases['requests']} requests): "
              + " ".join(f"{k}={v * 1e3:.1f}ms" for k, v in top))
    _dump_observability(args, engine, "engine")
    if mismatches or ok != len(responses):
        raise SystemExit(1)
    if args.devices:
        # the sharded plane always verifies shard-locally
        if integ["shard_checks"] == 0:
            print("[engine] FAIL: sharded plane ran no shard checks")
            raise SystemExit(1)
        if args.inject not in ("none", "adaptive"):
            # drill contract: the dishonest DEVICE was caught shard-locally
            # and ONLY its shards were recovered — re-dispatched to a
            # healthy device in rows mode, enclave-recomputed in shares
            # mode (a share may never visit a second device) — it alone
            # was quarantined, and the model kept offloading on the
            # healthy devices (the bit-exact cross-check above already
            # proved recovery)
            bad = (args.inject_device if args.inject_device is not None
                   else args.devices - 1)
            recovered = (integ["shard_retries"] if args.shard == "rows"
                         else integ["shard_enclave"])
            if integ["shard_failures"] == 0 or recovered == 0:
                print("[engine] FAIL: dishonest device not detected "
                      "shard-locally")
                raise SystemExit(1)
            for name, snap in stats["devices"].items():
                slots = snap["pool"]["slots"]
                if not slots[bad]["quarantined"]:
                    print(f"[engine] FAIL: {name} device {bad} not "
                          "quarantined")
                    raise SystemExit(1)
                healthy = [s for j, s in enumerate(slots) if j != bad]
                if any(s["quarantined"] for s in healthy) or not any(
                        s["dispatches"] > 0 and s["verify_failures"] == 0
                        for s in healthy):
                    print(f"[engine] FAIL: {name} healthy devices not "
                          "serving blinded offload")
                    raise SystemExit(1)
                if stats["models"][name]["quarantined"]:
                    print(f"[engine] FAIL: {name} quarantined per-model — "
                          "expected per-device only")
                    raise SystemExit(1)
    if args.verify != "off" and integ["verify_checks"] == 0:
        print("[engine] FAIL: verification enabled but no checks ran")
        raise SystemExit(1)
    if args.inject == "adaptive" and args.verify != "off":
        # the adaptive adversary corrupts only unchecked ops: under full
        # (or sampled at rate 1.0) it is neutralized — zero corruptions,
        # zero failures IS the success condition (the bit-exact cross-check
        # above already proved no corruption slipped through); under a
        # sparser sampled policy it evades by design, so detection cannot
        # be asserted either way.
        print("[engine] adaptive drill: evasion bounded by policy "
              f"(failures={integ['verify_failures']}), responses bit-exact")
    elif args.inject != "none" and args.verify != "off" and not args.devices:
        # the drill contract: the injected faults were caught (nonzero
        # failed checks) AND every response above was still bit-exact.
        # (With --devices the injector is per-device and recovery is
        # shard-local — no op-level failure or recompute ever happens;
        # that drill's contract is asserted in the sharded block above.)
        if integ["verify_failures"] == 0 or integ["recomputes"] == 0:
            print("[engine] FAIL: injected faults were not detected")
            raise SystemExit(1)


def run_chaos(args) -> None:
    """Liveness chaos drill (DESIGN.md §12): serial request stream through
    the engine while a scripted ChaosSchedule crashes/hangs devices, fails
    session refills and corrupts sealed requests in flight.

    The chaos invariant asserted here: every submitted future resolves,
    the engine never stops serving (degrading to verified enclave-only
    when every device is benched, recovering via breaker half-open
    probes), every non-seal-window response is bit-exact against a
    healthy single-device oracle, and seal-window requests fail with
    ``mac_failed`` and nothing else."""
    from repro.parallel.offload_sharding import LivenessConfig
    from repro.runtime.chaos import ChaosController, ChaosSchedule
    from repro.runtime.devices import DeviceHealthConfig, DevicePool
    from repro.runtime.engine import EngineConfig, ServingEngine

    get = get_smoke if args.smoke else get_config
    name = [m.strip() for m in args.models.split(",") if m.strip()][0]
    cfg = get(name)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    schedule = ChaosSchedule.parse(args.chaos)
    dev_events = [ev for ev in schedule.events if ev.layer == "device"]
    for ev in dev_events:
        if ev.device >= args.devices:
            raise SystemExit(f"[chaos] schedule targets dev{ev.device} but "
                             f"--devices {args.devices}")
    kinds = {ev.kind for ev in dev_events}
    refill_scheduled = any(ev.layer == "refill" for ev in schedule.events)
    seal_batches = {b for ev in schedule.events if ev.layer == "seal"
                    for b in range(ev.start, ev.stop + 1)}
    # a batch where EVERY device is under an armed fault must degrade the
    # engine to enclave-only serving (the assertion below keys off this)
    blackout = any(
        {ev.device for ev in dev_events if ev.active(b)}
        == set(range(args.devices))
        for b in range(schedule.horizon))

    # 2 requests per chaos batch: the plane's host-side dispatch runs the
    # trace eagerly, and eager/jitted logits are only bit-identical for
    # t >= 2 on this backend (XLA picks a different t=1 conv algorithm) —
    # the same regime the sharded integrity drill above relies on
    per = 2
    n_batches = schedule.horizon + args.chaos_margin
    reqs, keys = _sealed_requests(cfg, per * n_batches)
    key_by_rid = {r.rid: k for r, k in zip(reqs, keys)}

    # healthy oracle FIRST (chaos mutates seal-window request MACs in
    # flight, so the oracle must see the pristine boxes), on a genuinely
    # single-device executor so a plane bug can't corrupt both sides
    # alike; grouped in the engine's exact batches
    oracle = PrivateInferenceServer(cfg, params, mode=args.mode,
                                    max_batch=per)
    want = {}
    for j in range(n_batches):
        for r in oracle.serve_batch(reqs[per * j:per * (j + 1)]):
            assert r.ok, f"oracle failed on rid={r.rid}"
            want[r.rid] = PrivateInferenceServer.client_open(
                key_by_rid[r.rid], r.box, (cfg.num_classes,))

    pool = DevicePool(args.devices,
                      health=DeviceHealthConfig(breaker_after=2,
                                                breaker_cooldown=2))
    chaos = ChaosController(schedule)
    tracer = None
    if args.trace_out:
        from repro.core.tracing import Tracer
        tracer = Tracer(kernel_spans=args.trace_kernels)
    engine = ServingEngine(EngineConfig(max_batch=per, max_wait_ms=50.0),
                           tracer=tracer, recorder=_flight_recorder(args))
    engine.register_model(name, cfg, params, mode=args.mode,
                          devices=pool, shard=args.shard,
                          liveness=LivenessConfig(cold_timeout_s=2.0),
                          chaos=chaos)
    print(f"[chaos] schedule={schedule} horizon={schedule.horizon} "
          f"batches={n_batches}x{per} devices={args.devices}")

    t0 = time.time()
    timeline, ok_served = [], 0
    for j in range(n_batches):
        futs = [engine.submit(name, r) for r in reqs[per * j:per * (j + 1)]]
        resps = [f.result(timeout=120) for f in futs]
        snap = engine.snapshot()
        degraded = snap["models"][name]["degraded"]
        timeline.append((j, resps, degraded))
        ok_served += sum(r.ok for r in resps)
        if refill_scheduled and any(
                ev.layer == "refill" and ev.active(j)
                for ev in schedule.events):
            # the refill thread is async: give it a beat to hit the armed
            # window (bounded — the drill stays deterministic in outcome)
            for _ in range(40):
                if chaos.refill_faults > 0:
                    break
                time.sleep(0.05)
        time.sleep(args.chaos_pace)
    dt = time.time() - t0

    snap = engine.snapshot()
    liv = snap["liveness"]
    slots = next(iter(snap["devices"].values()))["pool"]["slots"]
    marks = "".join("D" if d else ("X" if not all(r.ok for r in rs)
                                   else ".")
                    for _, rs, d in timeline)
    print(f"[chaos] timeline [{marks}]  (.=ok D=degraded X=rejected)")
    for b, label, action in chaos.log:
        print(f"[chaos]   batch {b}: {action} {label}")
    print(f"[chaos] {ok_served}/{per * n_batches} ok in {dt:.1f}s "
          f"(goodput {ok_served / dt:.1f} req/s) liveness={liv} "
          f"refill_errors={snap['refill_errors']} "
          f"seal_corruptions={chaos.seal_corruptions}")
    for s in slots:
        print(f"[chaos]   {s['name']}: breaker={s['breaker']} "
              f"opens={s['breaker_opens']} probes={s['breaker_probes']} "
              f"closes={s['breaker_closes']} abandons={s['abandons']} "
              f"available={s['available']}")
    engine.close()
    if tracer is not None:
        n_events = tracer.dump_chrome(args.trace_out)
        print(f"[chaos] trace: {len(tracer.spans())} spans "
              f"({n_events} chrome events) -> {args.trace_out}")
    _dump_observability(args, engine, "chaos")

    # the chaos invariant, clause by clause
    fails = []
    if chaos.batch != n_batches - 1:
        fails.append(f"chaos clock drift: controller saw batch "
                     f"{chaos.batch}, drill drove {n_batches} "
                     f"(partial flush?) — scripted windows shifted")
    for j, resps, _ in timeline:
        for resp in resps:
            if j in seal_batches:
                if resp.ok or resp.error != "mac_failed":
                    fails.append(f"batch {j} rid={resp.rid}: seal-window "
                                 f"request not rejected with mac_failed "
                                 f"(ok={resp.ok}, error={resp.error})")
            elif not resp.ok:
                fails.append(f"batch {j} rid={resp.rid}: rejected outside "
                             f"any seal window (error={resp.error})")
            elif not np.array_equal(
                    PrivateInferenceServer.client_open(
                        key_by_rid[resp.rid], resp.box,
                        (cfg.num_classes,)),
                    want[resp.rid]):
                fails.append(f"batch {j} rid={resp.rid}: logits not "
                             f"bit-exact vs oracle")
    if blackout:
        if liv["degradations"] == 0:
            fails.append("total device blackout never degraded the engine "
                         "to enclave-only serving")
        if liv["recoveries"] == 0 or snap["models"][name]["degraded"]:
            fails.append("engine did not recover from degraded mode")
    if "crash" in kinds and liv["shard_crashes"] == 0:
        fails.append("crash scheduled but no shard crash contained")
    if "hang" in kinds and liv["shard_timeouts"] == 0:
        fails.append("hang scheduled but no dispatch timeout fired")
    if dev_events:
        if not any(s["breaker_opens"] > 0 for s in slots):
            fails.append("device faults scheduled but no breaker opened")
        bad = [s["name"] for s in slots if not s["available"]]
        if bad:
            fails.append(f"devices still benched after recovery margin: "
                         f"{bad}")
    if refill_scheduled and (chaos.refill_faults == 0
                             or snap["refill_errors"] == 0):
        fails.append("refill faults scheduled but none contained")
    if seal_batches and chaos.seal_corruptions == 0:
        fails.append("seal corruption scheduled but never applied")
    if chaos.snapshot()["armed"]:
        fails.append(f"events still armed: {chaos.snapshot()['armed']}")
    for f in fails:
        print(f"[chaos] FAIL: {f}")
    if fails:
        raise SystemExit(1)
    print("[chaos] OK: every future resolved, degradation/recovery as "
          "scheduled, all served logits bit-exact")


DEFAULT_CHAOS = "dev0.crash@1-2,dev1.hang@1-2,refill@7-8,seal@10"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="vgg16")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="origami",
                    choices=("open", "enclave", "split", "slalom", "origami"))
    ap.add_argument("--requests", type=int, default=None,
                    help="default: 16 (legacy loop) / 32 (--engine, the "
                         "mixed-smoke acceptance floor)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--engine", action="store_true",
                    help="drive the async ServingEngine over --models")
    ap.add_argument("--models", default="vgg16,vgg19",
                    help="comma list for --engine (mixed traffic)")
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--compile-cache-dir", default=None, metavar="DIR",
                    help="persistent AOT compilation cache (DESIGN.md §15): "
                         "compiled executables are serialized here keyed by "
                         "(plan digest, shape bucket, backend, code version) "
                         "and reloaded on the next boot, so a restarted "
                         "server never pays first-request compile. Requires "
                         "--engine.")
    ap.add_argument("--aot-warm", action="store_true",
                    help="with --engine, AOT-compile every (model, shape "
                         "bucket) executable at register_model time "
                         "(lower+compile off the request path) — the first "
                         "request then never traces or compiles")
    ap.add_argument("--plan", default=None,
                    help="per-layer PlacementPlan (core/plan.py): 'print' "
                         "lists compiled plans; a legacy mode name; "
                         "'mixed' (enclave/blinded tier-1); 'vopen' "
                         "(verified-open tier-2); or an explicit oebv "
                         "per-layer string. Overrides --mode.")
    ap.add_argument("--privacy-floor", type=float, default=None,
                    help="SSIM leakage floor for the partition planner "
                         "(default: use the config's declared partition)")
    ap.add_argument("--verify", default="off",
                    choices=("off", "sampled", "full"),
                    help="Freivalds verification policy over offloaded "
                         "field matmuls (DESIGN.md §9)")
    ap.add_argument("--verify-rate", type=float, default=0.25,
                    help="per-op check probability under --verify sampled")
    ap.add_argument("--verify-k", type=int, default=1,
                    help="Freivalds repetitions (soundness 1-p^-k)")
    ap.add_argument("--inject", default="none",
                    choices=("none", "bit_flip", "row_swap", "stale",
                             "adaptive"),
                    help="dishonest-device drill: corrupt every offloaded "
                         "op with this fault class (runtime/faults.py)")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard blinded offload across N simulated devices "
                         "(runtime/devices.py DevicePool + "
                         "parallel/offload_sharding.py); 0 = single-device "
                         "path. Requires --engine.")
    ap.add_argument("--shard", default="rows", choices=("rows", "shares"),
                    help="shard geometry: row-shard the blinded operand, "
                         "or additive secret shares (no single device sees "
                         "the full blinded tensor)")
    ap.add_argument("--inject-device", type=int, default=None,
                    help="with --devices, the slot --inject corrupts "
                         "(default: the last device)")
    ap.add_argument("--chaos", nargs="?", const=DEFAULT_CHAOS, default=None,
                    help="liveness chaos drill (runtime/chaos.py): a "
                         "scripted schedule like "
                         "'dev0.crash@1-2,dev1.hang@1-2,refill@7-8,seal@10' "
                         f"(no value = '{DEFAULT_CHAOS}'). Requires "
                         "--engine and --devices.")
    ap.add_argument("--chaos-margin", type=int, default=10,
                    help="recovery batches served past the schedule "
                         "horizon (breaker half-open probes need a few)")
    ap.add_argument("--chaos-pace", type=float, default=0.02,
                    help="inter-batch sleep in the chaos drill")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON span tree of "
                         "the run (core/tracing.py): request admission -> "
                         "micro-batch -> plan steps -> shard dispatches -> "
                         "verify -> unseal, redacted to shapes/timings. "
                         "Requires --engine.")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the unified metrics-registry snapshot "
                         "(DESIGN.md §13) plus the §14 phase decomposition "
                         "as JSON at exit. Requires --engine.")
    ap.add_argument("--postmortem-dir", default=None, metavar="DIR",
                    help="write redaction-safe flight-recorder post-mortem "
                         "bundles (last spans + metric deltas + engine "
                         "events) on quarantine/breaker-open/degradation/"
                         "verify-failure. Requires --engine.")
    ap.add_argument("--trace-kernels", action="store_true",
                    help="with --trace-out, also record fenced wall-time "
                         "kernel spans (blind_encode/limb_matmul/fold) — "
                         "adds block_until_ready fences, so only for "
                         "profiling runs")
    args = ap.parse_args()
    if args.devices and not args.engine:
        ap.error("--devices requires --engine")
    if args.trace_out and not args.engine:
        ap.error("--trace-out requires --engine")
    if args.chaos is not None and (not args.engine or args.devices < 1):
        ap.error("--chaos requires --engine and --devices >= 1")
    if (args.metrics_out or args.postmortem_dir) and not args.engine:
        ap.error("--metrics-out/--postmortem-dir require --engine")
    if (args.compile_cache_dir or args.aot_warm) and not args.engine:
        ap.error("--compile-cache-dir/--aot-warm require --engine")

    if args.requests is None:
        args.requests = 32 if args.engine else 16
    if args.plan == "print":
        get = get_smoke if args.smoke else get_config
        names = ([m.strip() for m in args.models.split(",") if m.strip()]
                 if args.engine else [args.model])
        _print_plans(names, get)
        return
    if args.chaos is not None:
        run_chaos(args)
        return
    if args.engine:
        run_engine(args)
        return

    cfg = get_smoke(args.model) if args.smoke else get_config(args.model)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    policy, fault = _integrity_args(args)
    server = PrivateInferenceServer(cfg, params, mode=args.mode,
                                    max_batch=args.batch,
                                    integrity=policy, fault=fault(),
                                    plan=_placement_for(cfg, args))

    # client: attest, then send sealed requests
    quote = server.attest()
    print(f"[serve] attested enclave measurement={quote.measurement[:16]}… "
          f"partition={quote.partition} mode={args.mode}")
    reqs, keys = _sealed_requests(cfg, args.requests)
    t0 = time.time()
    responses = server.serve(reqs)
    dt = time.time() - t0
    ok = sum(r.ok for r in responses)
    # client decrypts a response to verify the loop
    r0 = next(r for r in responses if r.ok)
    logits = PrivateInferenceServer.client_open(
        keys[r0.rid], r0.box, (cfg.num_classes,))
    print(f"[serve] {ok}/{len(responses)} ok in {dt:.2f}s "
          f"({dt/max(len(responses),1)*1e3:.0f} ms/req); "
          f"logits[:3]={np.round(logits[:3], 3)}")
    tele = server.executor.telemetry
    print(f"[serve] telemetry: blinded={tele.blinded_bytes/1e6:.2f}MB "
          f"offloaded={tele.offloaded_flops/1e9:.2f}GFLOP "
          f"calls={tele.calls}")
    if args.verify != "off":
        it = server.integrity_totals
        print(f"[serve] integrity: checks={it.checks} "
              f"failures={it.failures} retries={it.retries} "
              f"recomputes={it.recomputes}")


if __name__ == "__main__":
    main()
