"""Private-inference serving driver (paper deployment, Fig. 3a).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --model vgg16 --smoke \
        --requests 16 --mode origami
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import model as M
from repro.privacy.data import make_batch
from repro.runtime.serving import PrivateInferenceServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="vgg16")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="origami",
                    choices=("open", "enclave", "split", "slalom", "origami"))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke(args.model) if args.smoke else get_config(args.model)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = PrivateInferenceServer(cfg, params, mode=args.mode,
                                    max_batch=args.batch)

    # client: attest, then send sealed requests
    quote = server.attest()
    print(f"[serve] attested enclave measurement={quote.measurement[:16]}… "
          f"partition={quote.partition} mode={args.mode}")
    rng = np.random.default_rng(0)
    keys, reqs, images = [], [], []
    for rid in range(args.requests):
        img = make_batch(rid, 1, cfg.image_size)[0]
        key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
        box = PrivateInferenceServer.client_seal(key, img, rid)
        keys.append(key)
        images.append(img)
        reqs.append(Request(rid=rid, box=box, shape=img.shape,
                            session_key=key))

    t0 = time.time()
    responses = server.serve(reqs)
    dt = time.time() - t0
    ok = sum(r.ok for r in responses)
    # client decrypts a response to verify the loop
    r0 = next(r for r in responses if r.ok)
    logits = PrivateInferenceServer.client_open(
        keys[r0.rid], r0.box, (cfg.num_classes,))
    print(f"[serve] {ok}/{len(responses)} ok in {dt:.2f}s "
          f"({dt/max(len(responses),1)*1e3:.0f} ms/req); "
          f"logits[:3]={np.round(logits[:3], 3)}")
    tele = server.executor.telemetry
    print(f"[serve] telemetry: blinded={tele.blinded_bytes/1e6:.2f}MB "
          f"offloaded={tele.offloaded_flops/1e9:.2f}GFLOP "
          f"calls={tele.calls}")


if __name__ == "__main__":
    main()
