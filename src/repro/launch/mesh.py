"""Production mesh construction (spec'd in the assignment).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def _axis_types_kwargs(n: int) -> dict:
    """jax.sharding.AxisType only exists on newer jax; older versions get
    Auto semantics by default, so omitting the kwarg is equivalent."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_mesh_from_config(mesh_cfg: MeshConfig):
    return make_production_mesh(multi_pod=mesh_cfg.multi_pod)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) devices exist — used by
    smoke-scale distributed tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_types_kwargs(2))
