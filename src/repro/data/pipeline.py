"""Deterministic sharded synthetic data pipeline.

Token streams are generated from a counter-based PRNG keyed by
(seed, shard, step) — each data-parallel host materializes exactly its
slice with no coordination, resumption at any step is exact (no state to
checkpoint beyond the step counter), and elastic re-sharding just changes
the (shard, num_shards) split. The "language" is a mixture of Zipfian
unigrams and repeated motifs so a small LM shows a real learning curve
(examples/train_smollm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.5


class TokenPipeline:
    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def _rng(self, step: int, row: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 0x9E3779B1 + step) * 65536
            + self.shard * self.local_batch + row)

    def _sample_row(self, rng: np.random.Generator) -> np.ndarray:
        c = self.cfg
        # Zipfian unigrams clipped to vocab
        row = rng.zipf(c.zipf_a, size=c.seq_len).astype(np.int64)
        row = (row - 1) % c.vocab_size
        # overlay repeated motifs (learnable structure)
        pos = 0
        while pos + 2 * c.motif_len < c.seq_len:
            if rng.random() < c.motif_prob:
                motif = row[pos: pos + c.motif_len]
                row[pos + c.motif_len: pos + 2 * c.motif_len] = motif
                pos += 2 * c.motif_len
            else:
                pos += c.motif_len
        return row.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = [self._sample_row(self._rng(step, r))
                for r in range(self.local_batch)]
        return {"tokens": np.stack(rows)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
