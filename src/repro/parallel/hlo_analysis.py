"""Post-compile HLO analysis: collective bytes + matmul FLOPs, loop-aware.

Two things ``cost_analysis()`` cannot give us:

1. **Collective traffic** — not exposed at all. We sum the result bytes of
   every all-gather / all-reduce / reduce-scatter / all-to-all /
   collective-permute in the per-device SPMD module.
2. **Loop-multiplied FLOPs** — XLA counts a while body ONCE, but
   scan-over-layers executes it `trip` times (verified experimentally:
   scan flops = unrolled/L). We therefore count `dot` FLOPs ourselves from
   the HLO text, with each while body's contribution multiplied by its trip
   count (extracted from the loop condition's comparison constant and
   validated against known layer counts in tests).

Both walks share one recursive traversal from ENTRY through calls /
fusions / conditionals / whiles. Elementwise FLOPs are ignored (standard
matmul-MFU convention, stated in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|"
                     r"(?:[\w\[\],\{\}]+))\s+([\w\-]+)")
_CALL_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|[^\s]+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_REF_COMP_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=\s*%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PARAM_TYPE_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*((?:\([^)]*\))|[\w\[\],]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class HLOStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0       # loop-aware operand+result traffic proxy
    # traffic attributable to the jnp flash-attention inner loops (score /
    # context tiles). The Pallas kernel (kernels/flash_attention) keeps
    # these tiles in VMEM, so the kernelized memory term subtracts them.
    flash_bytes: float = 0.0
    trip_counts: List[int] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def add_coll(self, kind: str, nbytes: float, mult: float):
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) \
            + nbytes * mult
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1


@dataclass
class _Comp:
    header: str
    lines: List[str]
    types: Dict[str, str]


def _split_computations(hlo: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry = None
    current: Optional[_Comp] = None
    for raw in hlo.splitlines():
        stripped = raw.strip()
        if not raw.startswith(" ") and "->" in raw and "{" in raw:
            m = _COMP_HDR.match(stripped)
            if m:
                name = m.group(1)
                current = _Comp(header=stripped, lines=[], types={})
                comps[name] = current
                if stripped.startswith("ENTRY") or raw.startswith("ENTRY"):
                    entry = name
                # parameter types from the header signature
                paren = stripped[stripped.find("("):stripped.rfind("->")]
                for pname, ptype in _PARAM_TYPE_RE.findall(paren):
                    current.types[pname] = ptype
                continue
        if current is not None:
            if stripped == "}":
                current = None
            else:
                current.lines.append(stripped)
                dm = _DEF_RE.match(stripped)
                if dm:
                    current.types[dm.group(1)] = dm.group(2)
    return comps, entry


def _trip_count(cond: Optional[_Comp]) -> int:
    if cond is None:
        return 1
    consts = []
    for line in cond.lines:
        if "constant" in line and "compare" not in line:
            consts += [int(c) for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def _split_operands(ops_str: str) -> List[str]:
    """Split an operand list on top-level commas only — shapes embed commas
    (``f32[32,128]{1,0} %copy.3``), so a plain split truncates them."""
    out, depth, cur = [], 0, []
    for ch in ops_str:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _dot_flops_of_line(line: str, comp: _Comp) -> float:
    dm = _DEF_RE.match(line)
    if dm is None or dm.group(3) != "dot":
        return 0.0
    out_dims = _shape_dims(dm.group(2)) or []
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contraction size from lhs operand type
    ops = _OPERANDS_RE.search(line[line.find("dot("):])
    k = 1
    cdm = _DOT_DIMS_RE.search(line)
    if ops and cdm:
        operands = [o.strip() for o in _split_operands(ops.group(1))]
        first = operands[0] if operands else ""
        cdims = [int(c) for c in cdm.group(1).split(",") if c]
        # older-XLA text prints operand types inline; prefer that, fall back
        # to the name->type table of the enclosing computation
        lhs_type = first if _SHAPE_RE.search(first) else \
            comp.types.get(first.split(" ")[-1].lstrip("%"))
        if lhs_type:
            dims = _shape_dims(lhs_type) or []
            for c in cdims:
                if c < len(dims):
                    k *= dims[c]
    return 2.0 * out_elems * k


_FREE_OPS = ("parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             # control flow: carried state is aliased in place, not copied
             # per iteration — counting it would bill all params once per
             # layer-scan trip (observed 47 TB/device phantom traffic)
             "while", "conditional", "call", "custom-call",
             "optimization-barrier", "copy-start", "copy-done")


def _traffic_of_line(line: str, comp: _Comp) -> float:
    """HBM traffic proxy of one top-level instruction: 2 × result bytes
    (one write + one downstream read).

    Results-only, NOT operands: a dynamic-slice fusion reading one layer's
    weights from the (L, …) stacked parameter array lists the *whole stack*
    as its operand — counting operands billed all params once per loop trip
    (observed 47 TB/device phantom traffic). Every real read corresponds to
    some producer's result (or a parameter, read ~once), so results×2 is
    the defensible first-order proxy (EXPERIMENTS.md §Roofline)."""
    dm = _DEF_RE.match(line)
    if dm is None or dm.group(3) in _FREE_OPS:
        return 0.0
    return 2.0 * float(_shape_bytes(dm.group(2)))


def analyze_hlo(hlo: str) -> HLOStats:
    comps, entry = _split_computations(hlo)
    if entry is None and comps:
        entry = list(comps)[-1]
    stats = HLOStats()

    def walk(name: str, mult: float, depth: int = 0, in_fusion=False):
        comp = comps.get(name)
        if comp is None or depth > 50:
            return
        for line in comp.lines:
            cm = _CALL_COLL_RE.search(line)
            if cm:
                kind = cm.group(2).replace("-start", "")
                stats.add_coll(kind, float(_shape_bytes(cm.group(1))), mult)
            f = _dot_flops_of_line(line, comp)
            if f:
                stats.dot_flops += f * mult
            if not in_fusion:
                t = _traffic_of_line(line, comp) * mult
                stats.hbm_bytes += t
                # attribute flash-attention inner-loop tiles by the einsum
                # signature / function frames in the op metadata
                if t and ("bqhg" in line or "kv_block" in line
                          or "q_block" in line):
                    stats.flash_bytes += t
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)   # XLA's own annotation, if any
                trips = (int(tm.group(1)) if tm
                         else _trip_count(comps.get(wm.group(1))))
                stats.trip_counts.append(trips)
                walk(wm.group(2), mult * trips, depth + 1, in_fusion)
                continue
            for ref in _REF_COMP_RE.findall(line):
                if ref in comps and ref != name and "while" not in line:
                    # computations referenced via calls= are fusions/reducers
                    walk(ref, mult, depth + 1, in_fusion=True)
            bm = _BRANCHES_RE.search(line)
            if bm:
                for ref in bm.group(1).split(","):
                    walk(ref.strip().lstrip("%"), mult, depth + 1,
                         in_fusion)

    walk(entry, 1.0)
    return stats


# backwards-compatible aliases
def analyze_collectives(hlo: str) -> HLOStats:
    return analyze_hlo(hlo)


def while_trip_counts(hlo: str) -> List[int]:
    return analyze_hlo(hlo).trip_counts
