"""Logical activation-sharding constraints (MaxText-style rules).

Model code calls ``constrain(x, "batch", "seq", "vocab")`` at key points;
under an active ``activation_rules`` context (set by the launcher at trace
time) this becomes ``with_sharding_constraint`` with the mapped mesh axes,
and is a no-op otherwise (single-device smoke tests).

This is what keeps the big tensors pinned: without the logits constraint,
GSPMD replicates the (B, S, vocab) cross-entropy inputs per device
(observed: 238 GB/device temp on smollm — EXPERIMENTS.md §Perf iteration 0).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_RULES: Optional[Dict[str, Any]] = None
_AXIS_SIZES: Optional[Dict[str, int]] = None


@contextlib.contextmanager
def activation_rules(rules: Dict[str, Any],
                     axis_sizes: Optional[Dict[str, int]] = None):
    global _RULES, _AXIS_SIZES
    prev = (_RULES, _AXIS_SIZES)
    _RULES, _AXIS_SIZES = rules, axis_sizes
    try:
        yield
    finally:
        _RULES, _AXIS_SIZES = prev


def current_rules() -> Optional[Dict[str, Any]]:
    return _RULES


def constrain(x, *logical: Optional[str]):
    """with_sharding_constraint via the active logical rules.

    Same conflict resolution as layers.param_specs: first dim wins a mesh
    axis; dims whose size doesn't divide the mapped axes fall back to
    replicated. No-op without an active context (1-device smoke tests).
    """
    if _RULES is None:
        return x
    used = set()
    out = []
    for dim, a in zip(x.shape, logical):
        m = _RULES.get(a) if a else None
        ms = tuple(m) if isinstance(m, (tuple, list)) else (m,) if m else ()
        if any(ax in used for ax in ms):
            out.append(None)
            continue
        if _AXIS_SIZES is not None and ms:
            total = 1
            for ax in ms:
                total *= _AXIS_SIZES.get(ax, 1)
            if total == 0 or dim % total != 0:
                out.append(None)
                continue
        used.update(ms)
        out.append(m)
    return jax.lax.with_sharding_constraint(x, P(*out))
