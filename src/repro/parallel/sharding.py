"""Sharding plans: logical-axis rules -> PartitionSpec / NamedSharding trees.

Rules map the logical axes declared on every ParamDef (models/layers.py) to
mesh axes. Two presets:

- TRAIN: 2-D param sharding — FSDP over "data" (the `embed` logical axis)
  × TP over "model" (`ffn`/`heads_flat`/`vocab`/`experts`). Optimizer
  moments inherit the param spec, so total state is fully sharded across
  all 256/512 chips.
- SERVE: TP over "model"; weights replicated over "data" for dense archs;
  MoE banks additionally shard `ffn` over "data" so a 480B-expert bank
  still fits (DESIGN.md §4).

Batch/cache specs per shape handle the special cells: `long_500k` has
global_batch=1, so the KV/seq dimension shards over ("data","model")
instead of the batch (sequence-parallel decode).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import model as M


def train_rules(cfg: ModelConfig, mesh_cfg: MeshConfig) -> Dict[str, Any]:
    return {
        "embed": "data",          # FSDP axis
        "ffn": "model",
        "heads_flat": "model",
        "kv_flat": "model",
        "vocab": "model",
        "experts": "model",
        "lora": "model",
        "layers": None,
    }


def serve_rules(cfg: ModelConfig, mesh_cfg: MeshConfig) -> Dict[str, Any]:
    rules = {
        "embed": None,
        "ffn": "model",
        "heads_flat": "model",
        "kv_flat": "model",
        "vocab": "model",
        "experts": "model",
        "lora": "model",
        "layers": None,
    }
    if cfg.moe is not None:
        # expert banks too large to replicate over "data": shard their ffn
        # dim over data instead of model (experts already take "model")
        rules["ffn"] = "data"
    return rules


@dataclass
class ShardingPlan:
    mesh: Mesh
    rules: Dict[str, Any]
    batch_axes: Tuple[str, ...]            # mesh axes sharding global batch
    seq_axes: Tuple[str, ...] = ()         # axes sharding seq (batch==1)

    @property
    def act_rules(self) -> Dict[str, Any]:
        """Logical activation axes -> mesh axes (parallel/act_sharding)."""
        return {
            "batch": self.batch_axes or None,
            "seq": self.seq_axes or None,
            # context-parallel attention: query seq over the model axis
            "flash_seq": self.seq_axes or "model",
            "vocab": "model",
            "embed_act": None,
            "ffn_act": "model",
            "heads_act": "model",
        }

    @property
    def axis_sizes(self) -> Dict[str, int]:
        return dict(zip(self.mesh.axis_names,
                        self.mesh.devices.shape))

    def spec_tree(self, defs):
        return L.param_specs(defs, self.rules, self.axis_sizes)

    def param_shardings(self, cfg: ModelConfig):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.spec_tree(M.model_defs(cfg)))

    def named(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    # -- batches -------------------------------------------------------------
    def token_sharding(self) -> NamedSharding:
        b = self.batch_axes if self.batch_axes else None
        return self.named(b, None)

    def batch_shardings(self, cfg: ModelConfig, kind: str):
        b = self.batch_axes if self.batch_axes else None
        out = {"tokens": self.named(b, None)}
        if cfg.family == "audio":
            out["frames"] = self.named(b, None, "model")
        if cfg.family == "vlm":
            out["patches"] = self.named(b, None, "model")
        if kind == "train":
            pass
        return out

    # -- caches (mirror model.init_caches structure) --------------------------
    def cache_shardings(self, cfg: ModelConfig):
        b = self.batch_axes if self.batch_axes else None
        s = self.seq_axes if self.seq_axes else ("model",)
        fam = cfg.family

        def kv_spec():
            from repro.models.attention import KVCache
            return KVCache(k=self.named(None, b, s, None, None),
                           v=self.named(None, b, s, None, None))

        if fam in ("dense", "moe"):
            if cfg.attention == "mla":
                from repro.models.attention import KVCache
                return KVCache(k=self.named(None, b, s, None), v=None)
            return kv_spec()
        if fam == "hybrid":
            from repro.models.ssm import Mamba2State
            state = Mamba2State(
                ssm=(self.named(None, None, b, "model", None, None),
                     self.named(None, None, b, "model", None)),
                conv=self.named(None, None, b, None, "model"))
            out = {"main": state,
                   "shared": self._grouped_kv(b, s)}
            tail_groups = cfg.num_layers % cfg.hybrid_attn_every
            if tail_groups:
                out["tail"] = Mamba2State(
                    ssm=(self.named(None, b, "model", None, None),
                         self.named(None, b, "model", None)),
                    conv=self.named(None, b, None, "model"))
            return out
        if fam == "ssm":
            # xlstm has only 4 heads — shard the (large) per-head feature
            # dim over "model" instead of the head dim
            from repro.models.ssm import MLSTMState, SLSTMState
            return {
                "mlstm": MLSTMState(
                    C=self.named(None, None, b, None, "model", None),
                    n=self.named(None, None, b, None, "model"),
                    m=self.named(None, None, b, None),
                    conv=self.named(None, None, b, None, "model")),
                "slstm": SLSTMState(
                    c=self.named(None, b, None, "model"),
                    n=self.named(None, b, None, "model"),
                    h=self.named(None, b, None, "model"),
                    m=self.named(None, b, None)),
            }
        if fam == "audio":
            from repro.models.attention import KVCache
            return {"self": kv_spec(),
                    "cross_k": self.named(None, b, None, None, None),
                    "cross_v": self.named(None, b, None, None, None)}
        if fam == "vlm":
            from repro.models.attention import KVCache
            return {"self": KVCache(
                        k=self.named(None, None, b, s, None, None),
                        v=self.named(None, None, b, s, None, None)),
                    "cross_k": self.named(None, b, None, None, None),
                    "cross_v": self.named(None, b, None, None, None)}
        raise ValueError(fam)

    def _grouped_kv(self, b, s):
        from repro.models.attention import KVCache
        return KVCache(k=self.named(None, b, s, None, None),
                       v=self.named(None, b, s, None, None))

    # -- optimizer -----------------------------------------------------------
    def opt_shardings(self, cfg: ModelConfig):
        from repro.optim.adamw import AdamWState
        pspec = self.param_shardings(cfg)
        return AdamWState(step=self.named(), mu=pspec, nu=pspec)


def sanitize_shardings(shardings, abstract, axis_sizes: Dict[str, int]):
    """Drop mesh axes whose size doesn't divide the corresponding dim (jit
    in_shardings require even division) and de-duplicate repeated axes —
    the catch-all guard applied to every dry-run argument tree."""
    def fix(sh, a):
        if sh is None or not isinstance(sh, NamedSharding):
            return sh
        used = set()
        out = []
        spec = tuple(sh.spec) + (None,) * (len(a.shape) - len(sh.spec))
        for dim, ax in zip(a.shape, spec):
            axes = ax if isinstance(ax, tuple) else (ax,) if ax else ()
            n = 1
            for x in axes:
                n *= axis_sizes.get(x, 1)
            if not axes or dim % n != 0 or any(x in used for x in axes):
                out.append(None)
            else:
                used.update(axes)
                out.append(ax)
        return NamedSharding(sh.mesh, P(*out))

    return jax.tree.map(
        fix, shardings, abstract,
        is_leaf=lambda x: x is None or isinstance(x, NamedSharding))


def make_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              mesh_cfg: MeshConfig, mode: str) -> ShardingPlan:
    """mode: 'train' | 'serve'."""
    rules = (train_rules if mode == "train" else serve_rules)(cfg, mesh_cfg)
    data_axes = ("pod", "data") if mesh_cfg.multi_pod else ("data",)
    n_data = 1
    for a, n in zip(mesh_cfg.axes, mesh_cfg.shape):
        if a in data_axes:
            n_data *= n
    if shape.global_batch >= n_data and shape.global_batch % n_data == 0:
        batch_axes: Tuple[str, ...] = data_axes
        seq_axes: Tuple[str, ...] = ()
    elif shape.global_batch == 1:
        batch_axes = ()
        seq_axes = data_axes + ("model",)
    else:
        # batch smaller than data axes: shard over "data" only if divisible
        batch_axes = ("data",) if shape.global_batch % 16 == 0 else ()
        seq_axes = ()
    return ShardingPlan(mesh=mesh, rules=rules, batch_axes=batch_axes,
                        seq_axes=seq_axes)
