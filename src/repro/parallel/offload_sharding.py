"""Sharded blinded offload: one field matmul across many untrusted devices.

The Slalom protocol offloads ``y_b = (x_b @ W_q) mod p`` to ONE untrusted
accelerator. DarKnight (PAPERS.md) shows the same blinding construction
distributes: this module shards each blinded matmul across a
``runtime/devices.DevicePool`` and is the dispatch half of the multi-device
plane (the pool is the health half). Two shard geometries
(``core/plan.ShardPolicy``):

- **rows**: the blinded operand row-shards over the batch/token dim —
  shard j is rows [lo_j, hi_j) of ``x_b``; results concatenate. Each
  device sees a *slice* of the one-time-padded tensor (still uniform over
  Z_p — a slice of a pad is a pad), and the pool's aggregate throughput
  bounds the op, not one part's.
- **shares**: additive secret sharing — ``x_b = (Σ_j x_j) mod p`` with
  every proper subset of shares independently uniform, so **no single
  device ever holds the full blinded tensor** (defense in depth if a
  session pad were ever mismanaged: reconstructing ``x_b`` needs ALL
  shares). Each device multiplies its full-shape share; results sum
  mod p. Work is replicated n×, which is the price of the stronger
  non-collusion guarantee.

Both geometries are linear in ``x``, so the assembled result is
**bit-identical** to the single-device matmul — the executor's logits do
not change when a pool is attached (tests/test_offload_sharding.py).

**Shard-local Freivalds.** Every shard is checked independently with its
own fold vectors ``(s_j, ws_j = W_q @ s_j)`` (core/integrity.py
``shard_fold_stream``; prefetched per session by core/precompute.py via the
SessionPool ring): ``y_j @ s_j ≡ x_j @ ws_j (mod p)``. A corrupt result
therefore indicts a *device*, not the op — only that shard is re-dispatched
to another healthy device (the honest devices' work is never recomputed),
the pool records the failure against the slot (quarantine/probation), and
only when every device is exhausted does the enclave compute the shard
itself. Shards are ALWAYS checked when a plane is active (the adaptive
adversary of runtime/faults.py, which corrupts only unchecked ops, is
structurally neutralized here).

**Straggler hedging.** Shard wall times feed a ``runtime/straggler.py``
``StepWatchdog``; once warmed, a shard exceeding ``deadline_factor`` × the
P50 is duplicated onto the fastest spare healthy device and the first
*verified* result wins (pure duplication — resending the same blinded
shard reveals nothing new to the spare device). The loser's latency still
feeds its EWMA so placement learns to avoid chronic stragglers.

Host-side control flow (retry, hedging, health) cannot live inside a jit
trace — an executor with a pool runs its plan interpreter eagerly
(core/origami.py), which PR 1's kernels make bit-identical to the jitted
trace. Ops traced under ``lax.scan`` stay on the single-device path (the
same per-op addressability limit as precompute/verification).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, wait
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import blinding as B
from repro.core import integrity as IG
from repro.core.plan import SHARD_MODES
from repro.kernels.limb_matmul.ops import field_matmul
from repro.kernels.limb_matmul.ref import P
from repro.runtime.devices import DevicePool, DeviceSlot
from repro.runtime.straggler import StepWatchdog, WatchdogConfig

# fold_in domains: additive-share masks and per-shard fault keys live in
# their own sub-spaces, disjoint from blinding/verify/fault streams
SHARE_DOMAIN = 0x5A8E
_SHARD_FAULT = 0x51


@dataclasses.dataclass
class ShardReport:
    """Per-infer outcome of the sharded plane (host-side counters)."""
    ops: int = 0                    # sharded matmuls dispatched
    dispatches: int = 0             # shard -> device submissions (all)
    checks: int = 0                 # shard-local Freivalds checks run
    failures: int = 0               # checks that mismatched
    retries: int = 0                # single-shard re-dispatches
    hedges: int = 0                 # straggler duplicates launched
    enclave_shards: int = 0         # shards the enclave computed itself
    probes: int = 0                 # probation probes routed

    @property
    def flagged(self) -> bool:
        """A device misbehaved (even though every shard was recovered)."""
        return self.failures > 0

    def add(self, other: "ShardReport") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


def row_spans(t: int, n: int) -> List[Tuple[int, int]]:
    """Balanced contiguous row ranges — shard j owns [lo_j, hi_j).

    Static in (t, n): the split never depends on device health, so the
    assembled result (and the per-shard fold material) is identical
    whichever devices end up computing the shards."""
    base, extra = divmod(t, n)
    spans, lo = [], 0
    for j in range(n):
        hi = lo + base + (1 if j < extra else 0)
        spans.append((lo, hi))
        lo = hi
    return spans


def additive_shares(x_field: jax.Array, session_key: jax.Array,
                    op_index: int, step: int, n: int) -> List[jax.Array]:
    """Split ``x_field`` into n additive shares over Z_p.

    Shares 0..n-2 are fresh uniform masks drawn from the SHARE_DOMAIN
    stream (enclave-private, never reused across (session, op, step));
    the last share is the residual. Any proper subset is jointly uniform —
    reconstructing the blinded tensor needs every share."""
    root = B.stream_key(jax.random.fold_in(session_key, SHARE_DOMAIN),
                        op_index, step)
    shares, acc = [], None
    for j in range(n - 1):
        m = B.blinding_stream(jax.random.fold_in(root, j), x_field.shape)
        shares.append(m)
        acc = m if acc is None else jnp.mod(acc + m, P)
    resid = x_field if acc is None else jnp.mod(x_field - acc + P, P)
    shares.append(resid)
    return shares


@dataclasses.dataclass
class _ShardTask:
    index: int                      # shard id (static)
    op_index: int                   # the blinded op this shard belongs to
    x: jax.Array                    # the operand this shard's device gets
    s: jax.Array                    # fold vectors (d_out, k)
    ws: jax.Array                   # (d_in, k) = W_q @ s mod p
    fault_key: jax.Array


class OffloadPlane:
    """Dispatches blinded field matmuls across a DevicePool."""

    def __init__(self, pool: DevicePool, *, mode: str = "rows",
                 hedging: bool = True,
                 watchdog: Optional[StepWatchdog] = None,
                 matmul_impl: Optional[str] = None):
        assert mode in SHARD_MODES, mode
        self.pool = pool
        self.mode = mode
        self.hedging = hedging
        # kernels/limb_matmul/ops.field_matmul impl override for the shard
        # matmuls (None = auto). Simulated pools on CPU want "ref": the
        # interpreted-Pallas path auto picks for large shapes is
        # Python-level and GIL-bound, which would serialize the per-device
        # worker threads the simulation relies on; the jnp ref backend is
        # bit-identical and releases the GIL.
        self.matmul_impl = matmul_impl
        # shard wall times feed the watchdog; its P50 sets the hedge
        # deadline (deadline_factor × P50 after warmup)
        self.watchdog = watchdog or StepWatchdog(WatchdogConfig(
            deadline_factor=3.0, warmup_steps=4, window=64))
        self.report = ShardReport()         # current-infer counters
        self.totals = ShardReport()         # lifetime counters
        self._lock = threading.Lock()

    @property
    def n_shards(self) -> int:
        return self.pool.size

    def begin_infer(self) -> None:
        """Reset the per-infer report (the executor calls this per trace)."""
        self.report = ShardReport()

    # -- internals ---------------------------------------------------------
    def _record(self, **deltas: int) -> None:
        with self._lock:
            for k, v in deltas.items():
                setattr(self.report, k, getattr(self.report, k) + v)
                setattr(self.totals, k, getattr(self.totals, k) + v)

    def _observe_latency(self, dt: float) -> None:
        with self._lock:
            self.watchdog.start_step(now=0.0)
            self.watchdog.end_step(now=dt)

    def _hedge_deadline(self) -> Optional[float]:
        with self._lock:
            wd = self.watchdog
            if len(wd.history) < wd.cfg.warmup_steps:
                return None
            p50 = wd.p50
        if p50 is None:
            return None
        return max(wd.cfg.deadline_factor * p50, 1e-4)

    def _device_run(self, slot: DeviceSlot, task: _ShardTask,
                    w_q: jax.Array):
        """Runs ON the slot's worker thread: the untrusted device's half.

        Returns (y_field, wall_s). The slot's fault injector corrupts the
        result exactly where a byzantine accelerator would; the latency
        model (sim_gflops / sim_delay_s) sleeps out the modeled compute
        time so hedging and the bench see realistic wall clocks."""
        t0 = time.perf_counter()
        x = task.x
        if slot.jax_device is not None:
            x = jax.device_put(x, slot.jax_device)
        y = self._matmul(x, w_q)
        if slot.fault is not None:
            y, _ = slot.fault.corrupt(y, op_index=task.op_index,
                                      key=task.fault_key,
                                      will_verify=jnp.bool_(True))
        y = jax.block_until_ready(y)
        if slot.sim_gflops:
            flops = 2 * x.shape[0] * x.shape[1] * y.shape[1]
            time.sleep(flops / (slot.sim_gflops * 1e9))
        if slot.sim_delay_s:
            time.sleep(slot.sim_delay_s)
        return y, time.perf_counter() - t0

    def _matmul(self, x: jax.Array, w_q: jax.Array) -> jax.Array:
        if self.matmul_impl is None:
            return field_matmul(x, w_q)
        return field_matmul(x, w_q, impl=self.matmul_impl)

    @staticmethod
    def _shard_ok(y: jax.Array, task: _ShardTask) -> bool:
        return bool(IG.fold_check(y, task.x, task.s, task.ws))

    def _resolve_shard(self, task: _ShardTask, w_q: jax.Array,
                       primary: DeviceSlot, fut,
                       spares: Sequence[DeviceSlot]) -> jax.Array:
        """One shard, submitted ``fut`` to verified finish: hedge onto the
        first spare past the straggler deadline, retry failed checks down
        the spare list, enclave-compute as last resort. (All shards'
        primaries are submitted BEFORE any is resolved — ``matmul`` —
        so distinct devices genuinely overlap.)"""
        futures = {fut: primary}
        spares = list(spares)
        hedged = False
        deadline = self._hedge_deadline()
        while futures:
            done, _ = wait(list(futures), timeout=deadline,
                           return_when=FIRST_COMPLETED)
            if not done:                       # straggler: duplicate once
                # re-check quarantine at use time: the spares list was
                # captured before this op's earlier shards may have
                # benched one of them
                spare = next((s for s in spares if not s.quarantined
                              and s not in futures.values()), None)
                if self.hedging and not hedged and spare is not None:
                    hedged = True
                    spares.remove(spare)
                    futures[spare.submit(self._device_run, task, w_q)] = spare
                    self._record(dispatches=1, hedges=1)
                deadline = None                # wait for whoever finishes
                continue
            fut = next(iter(done))
            slot = futures.pop(fut)
            y, dt = fut.result()
            self._observe_latency(dt)
            self._record(checks=1)
            if self._shard_ok(y, task):
                self.pool.record_success(slot, dt)
                # a hedge loser still teaches the EWMA its wall time
                for f, s in futures.items():
                    f.add_done_callback(
                        lambda f_, s_=s: self._late_latency(f_, s_))
                return y
            self._record(failures=1)
            self.pool.record_failure(slot)
            if not futures:                    # re-dispatch THIS shard only
                retry = next((s for s in spares if not s.quarantined), None)
                if retry is None:
                    self._record(enclave_shards=1)
                    return field_matmul(task.x, w_q)
                spares.remove(retry)
                futures[retry.submit(self._device_run, task, w_q)] = retry
                self._record(dispatches=1, retries=1)
                deadline = None
        raise AssertionError("unreachable: shard loop exited without result")

    def _late_latency(self, fut, slot: DeviceSlot) -> None:
        try:
            _, dt = fut.result()
        except Exception:  # noqa: BLE001 — a dead hedge loser is ignorable
            return
        self._observe_latency(dt)
        self.pool.record_latency(slot, dt)

    # -- public API --------------------------------------------------------
    def matmul(self, x_field: jax.Array, w_q: jax.Array, *,
               session_key: jax.Array, op_index: int, step: int = 0,
               k: int = 1,
               folds: Optional[Sequence[Tuple[jax.Array, jax.Array]]] = None,
               mode: Optional[str] = None,
               group: Optional[Sequence[int]] = None) -> jax.Array:
        """``(x_field @ w_q) mod p`` sharded across the pool.

        ``folds``: per-shard (s_j, ws_j) from the precompute ring (derived
        live — same streams — when absent). ``mode``/``group``: per-step
        ShardPolicy overrides (core/plan.py). Bit-identical to
        ``field_matmul(x_field, w_q)`` for any device behavior the checks
        and retries can recover from."""
        mode = mode or self.mode
        assert mode in SHARD_MODES, mode
        n = self.n_shards
        t, d_in = x_field.shape
        d_out = w_q.shape[1]
        self.pool.begin_dispatch()
        self._record(ops=1)

        if mode == "rows":
            spans = row_spans(t, n)
            operands = [x_field[lo:hi] for lo, hi in spans]
        else:
            operands = additive_shares(x_field, session_key, op_index,
                                       step, n)

        tasks: List[Optional[_ShardTask]] = []
        fault_root = B.stream_key(
            jax.random.fold_in(session_key, _SHARD_FAULT), op_index, step)
        for j, xj in enumerate(operands):
            if xj.shape[0] == 0:               # t < n: nothing to compute
                tasks.append(None)
                continue
            if folds is not None:
                s, ws = folds[j]
            else:
                s = IG.shard_fold_stream(session_key, op_index, step, j,
                                         d_out, k)
                ws = field_matmul(w_q, s)
            tasks.append(_ShardTask(j, op_index, xj, s, ws,
                                    jax.random.fold_in(fault_root, j)))

        healthy = self.pool.healthy(group)
        probe = self.pool.probe_candidate(group)
        probe_j = max((j for j, tk in enumerate(tasks) if tk is not None),
                      default=None)
        results: List[Optional[jax.Array]] = [None] * n
        # submit EVERY shard's primary before resolving any — shards on
        # distinct devices overlap; resolution (verify/hedge/retry) then
        # consumes them in shard order
        pending: List[Tuple[int, _ShardTask, DeviceSlot, object,
                            List[DeviceSlot]]] = []
        for j, task in enumerate(tasks):
            if task is None:
                results[j] = jnp.zeros((0, d_out), x_field.dtype)
                continue
            if probe is not None and j == probe_j:
                # the probation probe: one verified shard on the benched
                # device; a clean check restores it, a failed one re-benches
                # it and the shard retries on the healthy list as usual
                primary, spares = probe, list(healthy)
            elif healthy:
                if mode == "shares":
                    # a device may hold AT MOST ONE share of an op —
                    # wrapping around (or retrying/hedging a share onto a
                    # device that already holds another) would hand one
                    # device enough shares to reconstruct the full blinded
                    # tensor, the exact thing shares mode exists to prevent
                    primary = healthy[j] if j < len(healthy) else None
                else:
                    primary = healthy[j % len(healthy)]
                spares = [s for s in healthy if s is not primary]
            else:
                primary, spares = None, []
            if mode == "shares":
                spares = []        # one device per share, ever (DESIGN §11)
            if primary is None:
                # no device this shard may visit: the enclave computes it
                self._record(enclave_shards=1)
                results[j] = field_matmul(task.x, w_q)
                continue
            if primary is probe:
                self.pool.record_probe(primary)
                self._record(probes=1)
            fut = primary.submit(self._device_run, task, w_q)
            self._record(dispatches=1)
            pending.append((j, task, primary, fut, spares))
        for j, task, primary, fut, spares in pending:
            results[j] = self._resolve_shard(task, w_q, primary, fut,
                                             spares)

        if mode == "rows":
            return jnp.concatenate(results, axis=0)
        out = results[0]
        for y in results[1:]:
            if y.shape[0]:
                out = jnp.mod(out + y, P)
        return out

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            totals = dataclasses.asdict(self.totals)
        return {"mode": self.mode, "hedging": self.hedging,
                "totals": totals, "pool": self.pool.snapshot()}
