"""Sharded blinded offload: one field matmul across many untrusted devices.

The Slalom protocol offloads ``y_b = (x_b @ W_q) mod p`` to ONE untrusted
accelerator. DarKnight (PAPERS.md) shows the same blinding construction
distributes: this module shards each blinded matmul across a
``runtime/devices.DevicePool`` and is the dispatch half of the multi-device
plane (the pool is the health half). Two shard geometries
(``core/plan.ShardPolicy``):

- **rows**: the blinded operand row-shards over the batch/token dim —
  shard j is rows [lo_j, hi_j) of ``x_b``; results concatenate. Each
  device sees a *slice* of the one-time-padded tensor (still uniform over
  Z_p — a slice of a pad is a pad), and the pool's aggregate throughput
  bounds the op, not one part's.
- **shares**: additive secret sharing — ``x_b = (Σ_j x_j) mod p`` with
  every proper subset of shares independently uniform, so **no single
  device ever holds the full blinded tensor** (defense in depth if a
  session pad were ever mismanaged: reconstructing ``x_b`` needs ALL
  shares). Each device multiplies its full-shape share; results sum
  mod p. Work is replicated n×, which is the price of the stronger
  non-collusion guarantee.

Both geometries are linear in ``x``, so the assembled result is
**bit-identical** to the single-device matmul — the executor's logits do
not change when a pool is attached (tests/test_offload_sharding.py).

**Shard-local Freivalds.** Every shard is checked independently with its
own fold vectors ``(s_j, ws_j = W_q @ s_j)`` (core/integrity.py
``shard_fold_stream``; prefetched per session by core/precompute.py via the
SessionPool ring): ``y_j @ s_j ≡ x_j @ ws_j (mod p)``. A corrupt result
therefore indicts a *device*, not the op — only that shard is re-dispatched
to another healthy device (the honest devices' work is never recomputed),
the pool records the failure against the slot (quarantine/probation), and
only when every device is exhausted does the enclave compute the shard
itself. Shards are ALWAYS checked when a plane is active (the adaptive
adversary of runtime/faults.py, which corrupts only unchecked ops, is
structurally neutralized here).

**Straggler hedging.** Shard wall times feed a ``runtime/straggler.py``
``StepWatchdog``; once warmed, a shard exceeding ``deadline_factor`` × the
P50 is duplicated onto the fastest spare healthy device and the first
*verified* result wins (pure duplication — resending the same blinded
shard reveals nothing new to the spare device). The loser's latency still
feeds its EWMA so placement learns to avoid chronic stragglers.

**Liveness recovery ladder (DESIGN.md §12).** The integrity ladder above
handles devices that return *wrong* results; this plane also survives
devices that return *none*:

- **exception containment**: a dispatch that raises (crash, cancelled
  queue) resolves as a liveness failure of that DEVICE — the exception
  never propagates into the batch, and only that shard re-dispatches;
- **hard per-dispatch timeout**: ``liveness.timeout_factor`` × the same
  watchdog P50 the hedge uses (with a floor, and a ``cold_timeout_s``
  fallback before warmup). A dispatch past it is abandoned — the slot's
  wedged queue is cut loose (``DeviceSlot.abandon``) so a hung worker
  never blocks later probes — and the shard re-dispatches;
- **exponential backoff with jitter** between liveness re-dispatches of
  one shard (transient flake storms de-synchronize instead of stampeding);
- **per-device circuit breaker**: ``breaker_after`` consecutive liveness
  failures open the slot's breaker (no traffic); after a cooldown it
  half-opens and ONE probe shard is routed — a verified success closes
  it, failure re-opens with doubled cooldown. Distinct from the
  integrity quarantine; the two compose (a slot serves only when neither
  indicts it).

As with integrity, the enclave computes the shard itself when every
eligible device is exhausted — so **every submitted matmul resolves**
under any liveness fault schedule, and the assembled result stays
bit-identical (recovered shards are recomputed from the same operands).
In ``shares`` mode the confinement rule still applies: a crashed or
timed-out share goes straight to the enclave, never to a second device.

Host-side control flow (retry, hedging, health) cannot live inside a jit
trace — an executor with a pool runs its plan interpreter eagerly
(core/origami.py), which PR 1's kernels make bit-identical to the jitted
trace. Ops traced under ``lax.scan`` stay on the single-device path (the
same per-op addressability limit as precompute/verification).
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from concurrent.futures import FIRST_COMPLETED, wait
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import blinding as B
from repro.core import integrity as IG
from repro.core import tracing
from repro.core.plan import SHARD_MODES
from repro.runtime import faults as FT
from repro.kernels.limb_matmul.ops import field_matmul
from repro.kernels.limb_matmul.ref import P
from repro.runtime.devices import DevicePool, DeviceSlot
from repro.runtime.straggler import StepWatchdog, WatchdogConfig

# fold_in domains: additive-share masks and per-shard fault keys live in
# their own sub-spaces, disjoint from blinding/verify/fault streams
SHARE_DOMAIN = 0x5A8E
_SHARD_FAULT = 0x51


@dataclasses.dataclass
class LivenessConfig:
    """Liveness-ladder knobs (per plane; DESIGN.md §12 tabulates them).

    The hard timeout shares the StepWatchdog baseline with hedging:
    ``timeout_factor × P50`` once the window is warm (floored — a
    sub-millisecond P50 must not turn scheduler jitter into abandons),
    ``cold_timeout_s`` before that. Backoff sleeps
    ``base × factor^attempt × (1 + jitter·u)`` between liveness
    re-dispatches of one shard, u deterministic in (op, shard, attempt).
    """
    timeout_factor: float = 8.0
    timeout_floor_s: float = 0.25
    cold_timeout_s: float = 10.0
    backoff_base_s: float = 0.005
    backoff_factor: float = 2.0
    backoff_max_s: float = 0.25
    backoff_jitter: float = 0.5


@dataclasses.dataclass
class ShardReport:
    """Per-infer outcome of the sharded plane (host-side counters)."""
    ops: int = 0                    # sharded matmuls dispatched
    dispatches: int = 0             # shard -> device submissions (all)
    checks: int = 0                 # shard-local Freivalds checks run
    failures: int = 0               # checks that mismatched
    retries: int = 0                # single-shard re-dispatches
    hedges: int = 0                 # straggler duplicates launched
    enclave_shards: int = 0         # shards the enclave computed itself
    probes: int = 0                 # probation probes routed
    # liveness ladder (DESIGN.md §12)
    crashes: int = 0                # dispatches that raised (contained)
    timeouts: int = 0               # dispatches abandoned past the deadline
    backoffs: int = 0               # backoff sleeps between re-dispatches
    breaker_probes: int = 0         # half-open liveness probes routed

    @property
    def flagged(self) -> bool:
        """A device misbehaved (even though every shard was recovered)."""
        return self.failures > 0

    def add(self, other: "ShardReport") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


def row_spans(t: int, n: int) -> List[Tuple[int, int]]:
    """Balanced contiguous row ranges — shard j owns [lo_j, hi_j).

    Static in (t, n): the split never depends on device health, so the
    assembled result (and the per-shard fold material) is identical
    whichever devices end up computing the shards."""
    base, extra = divmod(t, n)
    spans, lo = [], 0
    for j in range(n):
        hi = lo + base + (1 if j < extra else 0)
        spans.append((lo, hi))
        lo = hi
    return spans


def additive_shares(x_field: jax.Array, session_key: jax.Array,
                    op_index: int, step: int, n: int) -> List[jax.Array]:
    """Split ``x_field`` into n additive shares over Z_p.

    Shares 0..n-2 are fresh uniform masks drawn from the SHARE_DOMAIN
    stream (enclave-private, never reused across (session, op, step));
    the last share is the residual. Any proper subset is jointly uniform —
    reconstructing the blinded tensor needs every share."""
    root = B.stream_key(jax.random.fold_in(session_key, SHARE_DOMAIN),
                        op_index, step)
    shares, acc = [], None
    for j in range(n - 1):
        m = B.blinding_stream(jax.random.fold_in(root, j), x_field.shape)
        shares.append(m)
        acc = m if acc is None else jnp.mod(acc + m, P)
    resid = x_field if acc is None else jnp.mod(x_field - acc + P, P)
    shares.append(resid)
    return shares


@dataclasses.dataclass
class _ShardTask:
    index: int                      # shard id (static)
    op_index: int                   # the blinded op this shard belongs to
    x: jax.Array                    # the operand this shard's device gets
    s: jax.Array                    # fold vectors (d_out, k)
    ws: jax.Array                   # (d_in, k) = W_q @ s mod p
    fault_key: jax.Array


class OffloadPlane:
    """Dispatches blinded field matmuls across a DevicePool."""

    def __init__(self, pool: DevicePool, *, mode: str = "rows",
                 hedging: bool = True,
                 watchdog: Optional[StepWatchdog] = None,
                 matmul_impl: Optional[str] = None,
                 liveness: Optional[LivenessConfig] = None):
        assert mode in SHARD_MODES, mode
        self.pool = pool
        self.mode = mode
        self.hedging = hedging
        self.liveness = liveness or LivenessConfig()
        # kernels/limb_matmul/ops.field_matmul impl override for the shard
        # matmuls (None = auto). Simulated pools on CPU want "ref": the
        # interpreted-Pallas path auto picks for large shapes is
        # Python-level and GIL-bound, which would serialize the per-device
        # worker threads the simulation relies on; the jnp ref backend is
        # bit-identical and releases the GIL.
        self.matmul_impl = matmul_impl
        # shard wall times feed the watchdog; its P50 sets the hedge
        # deadline (deadline_factor × P50 after warmup)
        self.watchdog = watchdog or StepWatchdog(WatchdogConfig(
            deadline_factor=3.0, warmup_steps=4, window=64))
        self.report = ShardReport()         # current-infer counters
        self.totals = ShardReport()         # lifetime counters
        # optional runtime/profiling.FlightRecorder (the engine attaches
        # its own at register time): bad shard outcomes land in the
        # post-mortem ring even though the plane recovers them locally
        self.recorder = None
        self._lock = threading.Lock()

    @property
    def n_shards(self) -> int:
        return self.pool.size

    def begin_infer(self) -> None:
        """Reset the per-infer report (the executor calls this per trace)."""
        self.report = ShardReport()

    # -- internals ---------------------------------------------------------
    def _record(self, **deltas: int) -> None:
        with self._lock:
            for k, v in deltas.items():
                setattr(self.report, k, getattr(self.report, k) + v)
                setattr(self.totals, k, getattr(self.totals, k) + v)

    def _span_start(self, name: str, **attrs):
        """Open a child span of the ambient parent (the op's
        "shard.matmul" span — submission AND resolution both run on the
        batcher thread, so the contextvar parent is always right). None
        when no tracer is active."""
        tr = tracing.current_tracer()
        if tr is None:
            return None
        return tr.start_span(name, "shard", **attrs)

    def _span_end(self, span, **attrs) -> None:
        if span is None:
            return
        tr = tracing.current_tracer()
        if tr is not None:
            tr.end(span, **attrs)

    def _rec_event(self, outcome: str, slot: DeviceSlot) -> None:
        """Log a bad shard outcome to the attached flight recorder."""
        if self.recorder is not None:
            self.recorder.event("shard_" + outcome, device=slot.name)

    def _observe_latency(self, dt: float) -> None:
        with self._lock:
            self.watchdog.start_step(now=0.0)
            self.watchdog.end_step(now=dt)

    def _hedge_deadline(self) -> Optional[float]:
        with self._lock:
            return self.watchdog.deadline(floor=1e-4)

    def _dispatch_timeout(self) -> float:
        """Hard liveness deadline for one shard dispatch: same watchdog
        baseline as the hedge, larger factor + a floor (a hedge fires a
        duplicate; a timeout indicts the device)."""
        lv = self.liveness
        with self._lock:
            return self.watchdog.deadline(factor=lv.timeout_factor,
                                          floor=lv.timeout_floor_s,
                                          cold=lv.cold_timeout_s)

    def _backoff(self, task: _ShardTask, attempt: int) -> None:
        """Sleep before liveness re-dispatch attempt ``attempt`` of one
        shard: exponential with deterministic jitter in (op, shard,
        attempt) — a flake storm across shards de-synchronizes instead of
        stampeding the surviving devices."""
        lv = self.liveness
        u = random.Random(FT.stable_seed(task.op_index, task.index,
                                         attempt)).random()
        dt = min(lv.backoff_base_s * (lv.backoff_factor ** attempt),
                 lv.backoff_max_s) * (1.0 + lv.backoff_jitter * u)
        self._record(backoffs=1)
        time.sleep(dt)

    def _device_run(self, slot: DeviceSlot, task: _ShardTask,
                    w_q: jax.Array):
        """Runs ON the slot's worker thread: the untrusted device's half.

        Returns (y_field, wall_s). The slot's fault injector corrupts the
        result exactly where a byzantine accelerator would; the liveness
        injector crashes/parks/delays the dispatch exactly where a dead
        or braked device would; the latency model (sim_gflops /
        sim_delay_s) sleeps out the modeled compute time so hedging and
        the bench see realistic wall clocks."""
        t0 = time.perf_counter()
        if slot.liveness is not None:
            slot.liveness.perturb(op_index=task.op_index,
                                  cancel=slot.cancel)
        x = task.x
        if slot.jax_device is not None:
            x = jax.device_put(x, slot.jax_device)
        y = self._matmul(x, w_q)
        if slot.fault is not None:
            y, _ = slot.fault.corrupt(y, op_index=task.op_index,
                                      key=task.fault_key,
                                      will_verify=jnp.bool_(True))
        y = jax.block_until_ready(y)
        if slot.sim_gflops:
            flops = 2 * x.shape[0] * x.shape[1] * y.shape[1]
            time.sleep(flops / (slot.sim_gflops * 1e9))
        if slot.sim_delay_s:
            time.sleep(slot.sim_delay_s)
        return y, time.perf_counter() - t0

    def _matmul(self, x: jax.Array, w_q: jax.Array) -> jax.Array:
        if self.matmul_impl is None:
            return field_matmul(x, w_q)
        return field_matmul(x, w_q, impl=self.matmul_impl)

    @staticmethod
    def _shard_ok(y: jax.Array, task: _ShardTask) -> bool:
        return bool(IG.fold_check(y, task.x, task.s, task.ws))

    def _enclave_shard(self, task: _ShardTask, w_q: jax.Array) -> jax.Array:
        """Enclave computes this shard itself (last resort) — traced as its
        own child span so post-hoc analysis sees WHERE offload gave up."""
        self._record(enclave_shards=1)
        with tracing.maybe_span("shard.enclave", "shard",
                                shard=task.index, op_index=task.op_index):
            return field_matmul(task.x, w_q)

    def _resolve_shard(self, task: _ShardTask, w_q: jax.Array,
                       primary: DeviceSlot, fut,
                       spares: Sequence[DeviceSlot],
                       span=None) -> jax.Array:
        """One shard, submitted ``fut`` to verified finish: hedge onto the
        first spare past the straggler deadline, contain crashes, abandon
        dispatches past the hard liveness timeout, retry failures
        (integrity or liveness) down the spare list, enclave-compute as
        last resort. (All shards' primaries are submitted BEFORE any is
        resolved — ``matmul`` — so distinct devices genuinely overlap.)

        ``span``: the primary dispatch's open trace span (from the submit
        site); every re-dispatch/hedge opens its own, and each closes with
        an ``outcome`` attribute when its future resolves."""
        futures: Dict[object, Tuple[DeviceSlot, float, object]] = {
            fut: (primary, time.perf_counter(), span)}
        spares = list(spares)
        hedged = False
        attempt = 0                    # liveness re-dispatches of this shard
        hedge_deadline = self._hedge_deadline()

        def next_spare() -> Optional[DeviceSlot]:
            # re-check health at use time: the spares list was captured
            # before this op's earlier shards may have indicted one of them
            busy = {v[0] for v in futures.values()}
            return next((s for s in spares
                         if s.available and s not in busy), None)

        def submit_to(slot: DeviceSlot, why: str) -> None:
            futures[slot.submit(self._device_run, task, w_q)] = (
                slot, time.perf_counter(),
                self._span_start("shard.dispatch", shard=task.index,
                                 op_index=task.op_index, device=slot.name,
                                 attempt=why))

        def redispatch() -> bool:
            """Backoff, then re-submit this shard to the next spare."""
            nonlocal attempt
            retry = next_spare()
            if retry is None:
                return False
            spares.remove(retry)
            attempt += 1
            self._backoff(task, attempt)
            submit_to(retry, "retry")
            self._record(dispatches=1, retries=1)
            return True

        while futures:
            hard = self._dispatch_timeout()
            now = time.perf_counter()
            wait_t = min(max(v[1] + hard - now, 0.0)
                         for v in futures.values())
            if not hedged and hedge_deadline is not None:
                wait_t = min(wait_t, hedge_deadline)
            done, _ = wait(list(futures), timeout=wait_t,
                           return_when=FIRST_COMPLETED)
            if not done:
                now = time.perf_counter()
                expired = [f for f, v in futures.items()
                           if now - v[1] >= hard]
                if expired:
                    # hard liveness timeout: indict the device, cut its
                    # wedged queue loose so later probes never line up
                    # behind the hung dispatch, re-dispatch elsewhere
                    for f in expired:
                        slot, _, sp = futures.pop(f)
                        self._span_end(sp, outcome="timeout")
                        self._record(timeouts=1)
                        self._rec_event("timeout", slot)
                        self.pool.record_liveness_failure(slot)
                        slot.abandon()
                    if not futures and not redispatch():
                        return self._enclave_shard(task, w_q)
                    continue
                # straggler (still inside the hard deadline): hedge once
                spare = next_spare()
                if self.hedging and not hedged and spare is not None:
                    hedged = True
                    spares.remove(spare)
                    submit_to(spare, "hedge")
                    self._record(dispatches=1, hedges=1)
                hedge_deadline = None  # hard expiries drive the waits now
                continue
            fut = next(iter(done))
            slot, _, sp = futures.pop(fut)
            try:
                y, dt = fut.result()
            except Exception:  # noqa: BLE001 — crash containment (§12)
                # the dispatch raised (injected crash, driver error,
                # abandoned-queue cancellation): a liveness failure of the
                # DEVICE, contained here — it never reaches the batch
                self._span_end(sp, outcome="crash")
                self._record(crashes=1)
                self._rec_event("crash", slot)
                self.pool.record_liveness_failure(slot)
                if not futures and not redispatch():
                    return self._enclave_shard(task, w_q)
                continue
            self._observe_latency(dt)
            self._record(checks=1)
            if self._shard_ok(y, task):
                self._span_end(sp, outcome="verified", device_wall_s=dt)
                self.pool.record_success(slot, dt)
                # a hedge loser still teaches the EWMA its wall time
                for f, v in futures.items():
                    self._span_end(v[2], outcome="superseded")
                    f.add_done_callback(
                        lambda f_, s_=v[0]: self._late_latency(f_, s_))
                return y
            self._span_end(sp, outcome="verify_failed", device_wall_s=dt)
            self._record(failures=1)
            self._rec_event("verify_failed", slot)
            self.pool.record_failure(slot)
            if not futures:                    # re-dispatch THIS shard only
                retry = next_spare()
                if retry is None:
                    return self._enclave_shard(task, w_q)
                spares.remove(retry)
                submit_to(retry, "retry")
                self._record(dispatches=1, retries=1)
        raise AssertionError("unreachable: shard loop exited without result")

    def _late_latency(self, fut, slot: DeviceSlot) -> None:
        try:
            _, dt = fut.result()
        except Exception:  # noqa: BLE001 — a dead hedge loser is ignorable
            return
        self._observe_latency(dt)
        self.pool.record_latency(slot, dt)

    # -- public API --------------------------------------------------------
    def matmul(self, x_field: jax.Array, w_q: jax.Array, *,
               session_key: jax.Array, op_index: int, step: int = 0,
               k: int = 1,
               folds: Optional[Sequence[Tuple[jax.Array, jax.Array]]] = None,
               mode: Optional[str] = None,
               group: Optional[Sequence[int]] = None) -> jax.Array:
        """``(x_field @ w_q) mod p`` sharded across the pool.

        ``folds``: per-shard (s_j, ws_j) from the precompute ring (derived
        live — same streams — when absent). ``mode``/``group``: per-step
        ShardPolicy overrides (core/plan.py). Bit-identical to
        ``field_matmul(x_field, w_q)`` for any device behavior the checks
        and retries can recover from."""
        mode = mode or self.mode
        assert mode in SHARD_MODES, mode
        # one "shard.matmul" span per sharded op; every dispatch/retry/
        # hedge/enclave child parents to it (all created on this thread).
        # Shapes and counts only — the operands are blinded but redaction
        # would reject them anyway (core/tracing.py).
        with tracing.maybe_span("shard.matmul", "shard", op_index=op_index,
                                step=step, mode=mode,
                                n_shards=self.n_shards,
                                t=int(x_field.shape[0]),
                                d_in=int(x_field.shape[1]),
                                d_out=int(w_q.shape[1])):
            return self._sharded_matmul(x_field, w_q,
                                        session_key=session_key,
                                        op_index=op_index, step=step, k=k,
                                        folds=folds, mode=mode, group=group)

    def _sharded_matmul(self, x_field: jax.Array, w_q: jax.Array, *,
                        session_key: jax.Array, op_index: int, step: int,
                        k: int,
                        folds: Optional[Sequence[Tuple[jax.Array,
                                                       jax.Array]]],
                        mode: str,
                        group: Optional[Sequence[int]]) -> jax.Array:
        n = self.n_shards
        t, d_in = x_field.shape
        d_out = w_q.shape[1]
        self.pool.begin_dispatch()
        self._record(ops=1)

        if mode == "rows":
            spans = row_spans(t, n)
            operands = [x_field[lo:hi] for lo, hi in spans]
        else:
            operands = additive_shares(x_field, session_key, op_index,
                                       step, n)

        tasks: List[Optional[_ShardTask]] = []
        fault_root = B.stream_key(
            jax.random.fold_in(session_key, _SHARD_FAULT), op_index, step)
        for j, xj in enumerate(operands):
            if xj.shape[0] == 0:               # t < n: nothing to compute
                tasks.append(None)
                continue
            if folds is not None:
                s, ws = folds[j]
            else:
                s = IG.shard_fold_stream(session_key, op_index, step, j,
                                         d_out, k)
                ws = field_matmul(w_q, s)
            tasks.append(_ShardTask(j, op_index, xj, s, ws,
                                    jax.random.fold_in(fault_root, j)))

        healthy = self.pool.healthy(group)
        probe = self.pool.probe_candidate(group)
        bprobe = self.pool.breaker_candidate(group)
        probe_j = max((j for j, tk in enumerate(tasks) if tk is not None),
                      default=None)
        # the liveness probe rides the lowest shard so the two probe kinds
        # never collide; with a single shard the integrity probe wins and
        # the breaker probe waits for the next op
        bprobe_j = min((j for j, tk in enumerate(tasks) if tk is not None),
                       default=None)
        if probe is not None and bprobe_j == probe_j:
            bprobe = None
        results: List[Optional[jax.Array]] = [None] * n
        # submit EVERY shard's primary before resolving any — shards on
        # distinct devices overlap; resolution (verify/hedge/retry) then
        # consumes them in shard order
        pending: List[Tuple[int, _ShardTask, DeviceSlot, object,
                            List[DeviceSlot], object]] = []
        for j, task in enumerate(tasks):
            if task is None:
                results[j] = jnp.zeros((0, d_out), x_field.dtype)
                continue
            if probe is not None and j == probe_j:
                # the probation probe: one verified shard on the benched
                # device; a clean check restores it, a failed one re-benches
                # it and the shard retries on the healthy list as usual
                primary, spares = probe, list(healthy)
            elif bprobe is not None and j == bprobe_j:
                # the breaker probe: one shard on the half-open device; a
                # verified success closes the breaker (record_success), a
                # crash/timeout re-opens it with a doubled cooldown and the
                # shard retries on the healthy list / enclave as usual
                primary, spares = bprobe, list(healthy)
            elif healthy:
                if mode == "shares":
                    # a device may hold AT MOST ONE share of an op —
                    # wrapping around (or retrying/hedging a share onto a
                    # device that already holds another) would hand one
                    # device enough shares to reconstruct the full blinded
                    # tensor, the exact thing shares mode exists to prevent
                    primary = healthy[j] if j < len(healthy) else None
                else:
                    primary = healthy[j % len(healthy)]
                spares = [s for s in healthy if s is not primary]
            else:
                primary, spares = None, []
            if mode == "shares":
                spares = []        # one device per share, ever (DESIGN §11)
            if primary is None:
                # no device this shard may visit: the enclave computes it
                results[j] = self._enclave_shard(task, w_q)
                continue
            why = "primary"
            if primary is probe:
                self.pool.record_probe(primary)
                self._record(probes=1)
                why = "probe"
            elif primary is bprobe:
                self.pool.record_breaker_probe(primary)
                self._record(breaker_probes=1)
                why = "breaker_probe"
            span = self._span_start("shard.dispatch", shard=j,
                                    op_index=op_index, device=primary.name,
                                    attempt=why)
            fut = primary.submit(self._device_run, task, w_q)
            self._record(dispatches=1)
            pending.append((j, task, primary, fut, spares, span))
        for j, task, primary, fut, spares, span in pending:
            results[j] = self._resolve_shard(task, w_q, primary, fut,
                                             spares, span=span)

        if mode == "rows":
            return jnp.concatenate(results, axis=0)
        out = results[0]
        for y in results[1:]:
            if y.shape[0]:
                out = jnp.mod(out + y, P)
        return out

    def snapshot(self) -> Dict[str, object]:
        lv = self.liveness
        with self._lock:
            totals = dataclasses.asdict(self.totals)
            # the plane's straggler/liveness brain, exported (DESIGN.md
            # §13): the hedge and abandon deadlines in force RIGHT NOW,
            # so a post-hoc chaos drill can explain every hedge/timeout
            watchdog = {
                "p50_s": self.watchdog.p50,
                "samples": len(self.watchdog.history),
                "flagged_steps": self.watchdog.flagged_steps,
                "hedge_deadline_s": self.watchdog.deadline(floor=1e-4),
                "dispatch_timeout_s": self.watchdog.deadline(
                    factor=lv.timeout_factor, floor=lv.timeout_floor_s,
                    cold=lv.cold_timeout_s),
            }
        return {"mode": self.mode, "hedging": self.hedging,
                "totals": totals, "watchdog": watchdog,
                "pool": self.pool.snapshot()}
