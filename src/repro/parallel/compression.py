"""Gradient compression: int8 quantized all-reduce with error feedback.

For the cross-pod ("pod" axis) gradient reduction, DCN bandwidth — not
ICI — is the bottleneck, so we all-reduce int8-quantized gradients (4×
fewer bytes than fp32) and carry the quantization residual into the next
step (error feedback), which keeps SGD/Adam convergence unchanged to first
order (Karimireddy et al. 2019). Per-tensor absmax scales all-reduce
alongside (negligible bytes).

``compressed_psum`` is written against jax.lax collectives so it can run
inside shard_map; ``apply_error_feedback`` wraps any grad pytree for the
pjit path where the all-reduce is implicit (the quantize/dequantize round
trip alone already yields the bandwidth win under GSPMD, which reduces the
int8 tensors).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: jax.Array) -> jax.Array:
    """The quantization round trip (what the wire sees under GSPMD)."""
    q, s = quantize_int8(x)
    return dequantize_int8(q, s)


def apply_error_feedback(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """grads, residual -> (compressed grads, new residual).

    compressed = Q(g + r);  r' = (g + r) - compressed.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        c = compress_decompress(gf)
        return c.astype(g.dtype), gf - c

    out = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_res


def init_residual(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce inside shard_map: quantize locally, psum int32,
    dequantize with the max scale (conservative)."""
    q, s = quantize_int8(x)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    smax = jax.lax.pmax(s, axis_name)
    return total.astype(jnp.float32) * smax
