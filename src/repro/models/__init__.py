"""Model substrate: layers, attention, MoE, SSM, stacks, VGG."""
