"""Mixture-of-Experts: top-k router + two dispatch implementations.

- ``gshard``: dense one-hot dispatch/combine einsums (GShard/Mesh-TF style).
  Simple and exactly differentiable; memory scales with S·E·C so it is the
  *baseline* path (used in smoke tests and as the §Perf baseline).
- ``sorted``: argsort-by-expert with static expert-capacity buffers.
  Memory scales with S·k·d; under EP the (E, C, d) buffer is sharded over
  the "model" axis and GSPMD materializes the token exchange as all-to-all.
  This is the at-scale path (beyond-paper §Perf iteration for qwen3-moe).

Both drop overflow tokens (capacity factor) identically to the GShard
formulation; the router uses softmax-then-top-k with normalized weights.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def moe_defs(cfg: ModelConfig) -> Dict[str, object]:
    m = cfg.moe
    d = cfg.d_model
    defs = {
        "router": {"w": L.ParamDef((d, m.num_experts), "scaled",
                                   ("embed", None), jnp.float32)},
        "w_gate": L.ParamDef((m.num_experts, d, m.d_ff_expert), "scaled",
                             ("experts", "embed", "ffn")),
        "w_up": L.ParamDef((m.num_experts, d, m.d_ff_expert), "scaled",
                           ("experts", "embed", "ffn")),
        "w_down": L.ParamDef((m.num_experts, m.d_ff_expert, d), "scaled",
                             ("experts", "ffn", "embed")),
    }
    if m.dense_residual_d_ff:
        defs["dense_residual"] = {
            "w_gate": L.dense_def(d, m.dense_residual_d_ff, ("embed", "ffn")),
            "w_up": L.dense_def(d, m.dense_residual_d_ff, ("embed", "ffn")),
            "w_down": L.dense_def(m.dense_residual_d_ff, d, ("ffn", "embed")),
        }
    return defs


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(c, m.top_k)


def _route(p, x, cfg: ModelConfig):
    """x: (T, d) -> (weights (T,k), experts (T,k), aux_loss)."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ p["router"]["w"])         # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, m.top_k)            # (T, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(experts, m.num_experts), axis=1), axis=0)
    aux = m.num_experts * jnp.sum(me * ce)
    return weights, experts, aux


def _expert_ffn(p, xe, cfg: ModelConfig):
    """xe: (E, C, d) -> (E, C, d), per-expert gated MLP."""
    act = L.activation(cfg.activation)
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(xe.dtype))


def _dispatch_gshard(p, x, cfg: ModelConfig):
    """Dense one-hot dispatch. x: (T, d)."""
    m = cfg.moe
    T, d = x.shape
    C = _capacity(T, cfg)
    weights, experts, aux = _route(p, x, cfg)
    onehot = jax.nn.one_hot(experts, m.num_experts, dtype=jnp.float32)  # (T,k,E)
    # position of each (token, slot) within its expert queue
    pos = jnp.cumsum(onehot.reshape(T * m.top_k, m.num_experts), axis=0) - 1.0
    pos = pos.reshape(T, m.top_k, m.num_experts)
    pos = jnp.sum(pos * onehot, axis=-1)                        # (T, k)
    keep = pos < C
    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos, C).astype(jnp.int32), C, dtype=jnp.float32)
    dispatch = jnp.einsum("tke,tkc->tec", onehot,
                          pos_oh * keep[..., None])             # (T,E,C)
    combine = jnp.einsum("tk,tke,tkc->tec", weights, onehot,
                         pos_oh * keep[..., None])
    xe = jnp.einsum("tec,td->ecd", dispatch, x.astype(jnp.float32))
    ye = _expert_ffn(p, xe.astype(x.dtype), cfg)
    y = jnp.einsum("tec,ecd->td", combine, ye.astype(jnp.float32))
    return y.astype(x.dtype), aux


def _dispatch_sorted(p, x, cfg: ModelConfig):
    """Argsort dispatch with static (E, C) capacity buffers. x: (T, d)."""
    m = cfg.moe
    T, d = x.shape
    C = _capacity(T, cfg)
    E = m.num_experts
    weights, experts, aux = _route(p, x, cfg)

    flat_e = experts.reshape(-1)                                # (T*k,)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), m.top_k)
    order = jnp.argsort(flat_e)                                 # stable
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    # rank within expert: index minus the expert's first index
    starts = jnp.searchsorted(se, jnp.arange(E))
    rank = jnp.arange(T * m.top_k) - starts[se]
    keep = rank < C
    dest = jnp.where(keep, se * C + rank, E * C)                # drop -> OOB
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(x[stok])
    ye = _expert_ffn(p, buf[:-1].reshape(E, C, d), cfg)
    back = ye.reshape(E * C, d)
    rows = jnp.where(keep[:, None], back[jnp.minimum(dest, E * C - 1)], 0.0)
    y = jnp.zeros((T, d), x.dtype).at[stok].add(
        rows * sw[:, None].astype(x.dtype))
    return y, aux


def _dispatch_sorted_grouped(p, x, cfg: ModelConfig, groups: int = 32):
    """Sorted dispatch within token groups (one per data shard): the
    argsort/scatter stay group-local under GSPMD instead of sorting the
    global token stream (which forced all-gathers of every activation —
    EXPERIMENTS.md §Perf qwen3-moe iteration 3). The inter-group traffic
    that remains is the unavoidable token->expert all-to-all."""
    from repro.parallel import act_sharding as ash
    T, d = x.shape
    while T % groups != 0 and groups > 1:
        groups //= 2
    xg = ash.constrain(x.reshape(groups, T // groups, d),
                       "batch", None, None)

    def one(xi):
        y, aux = _dispatch_sorted(p, xi, cfg)
        return y, aux

    y, aux = jax.vmap(one)(xg)
    return (ash.constrain(y, "batch", None, None).reshape(T, d),
            jnp.mean(aux))


def moe_forward(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d); returns (y, aux_loss)."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    if cfg.moe.dispatch == "sorted_grouped":
        y, aux = _dispatch_sorted_grouped(p, xt, cfg)
    elif cfg.moe.dispatch == "sorted":
        y, aux = _dispatch_sorted(p, xt, cfg)
    else:
        y, aux = _dispatch_gshard(p, xt, cfg)
    if cfg.moe.dense_residual_d_ff:
        act = L.activation(cfg.activation)
        pr = p["dense_residual"]
        h = act(L.dense(pr["w_gate"], xt)) * L.dense(pr["w_up"], xt)
        y = y + L.dense(pr["w_down"], h)
    return y.reshape(B, S, d), aux
