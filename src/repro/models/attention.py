"""Attention: GQA (+RoPE), MLA (latent KV), cross-attention, decode paths.

Projections operate on a flat ``(…, n_heads*head_dim)`` layout so the model
axis shards them evenly even when ``n_heads`` is not divisible by the TP
degree (DESIGN.md §5). The quadratic core runs as chunked online-softmax
("flash" in pure jnp) so 32k prefill fits per-device HBM; a ``cost_mode``
switch swaps in the naive full-score path (identical FLOPs, loop-free) for
roofline cost probes (EXPERIMENTS.md §Roofline methodology).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel import act_sharding as ash


class KVCache(NamedTuple):
    k: jax.Array          # (B, max_seq, KH, D)  [MLA: (B, max_seq, latent+rope)]
    v: Optional[jax.Array]


# ----------------------------------------------------------------------------
# Parameter definitions
# ----------------------------------------------------------------------------

def gqa_defs(cfg: ModelConfig) -> Dict[str, object]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": L.dense_def(d, cfg.num_heads * hd, ("embed", "heads_flat"),
                          bias=cfg.qkv_bias),
        "wk": L.dense_def(d, cfg.num_kv_heads * hd, ("embed", "kv_flat"),
                          bias=cfg.qkv_bias),
        "wv": L.dense_def(d, cfg.num_kv_heads * hd, ("embed", "kv_flat"),
                          bias=cfg.qkv_bias),
        "wo": L.dense_def(cfg.num_heads * hd, d, ("heads_flat", "embed")),
    }


def mla_defs(cfg: ModelConfig) -> Dict[str, object]:
    m, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": L.dense_def(d, m.q_lora_rank, ("embed", "lora")),
        "q_norm": L.norm_def(m.q_lora_rank, "rmsnorm"),
        "wq_b": L.dense_def(m.q_lora_rank, h * qk, ("lora", "heads_flat")),
        "wkv_a": L.dense_def(d, m.kv_lora_rank + m.qk_rope_head_dim,
                             ("embed", "lora")),
        "kv_norm": L.norm_def(m.kv_lora_rank, "rmsnorm"),
        "wkv_b": L.dense_def(m.kv_lora_rank,
                             h * (m.qk_nope_head_dim + m.v_head_dim),
                             ("lora", "heads_flat")),
        "wo": L.dense_def(h * m.v_head_dim, d, ("heads_flat", "embed")),
    }


def cross_attn_defs(cfg: ModelConfig) -> Dict[str, object]:
    return gqa_defs(cfg)


# ----------------------------------------------------------------------------
# Flash (chunked online-softmax) attention core — pure jnp, custom VJP
# ----------------------------------------------------------------------------
#
# The VJP recomputes attention probabilities per (q-chunk × kv-chunk) block
# (FlashAttention-2 backward) instead of letting scan save every block's
# probabilities as residuals — without this, ONE smollm layer's backward
# residuals were 4.8 GB/device (EXPERIMENTS.md §Perf iteration 0).

def _flash_fwd_core(q, k, v, *, causal: bool, scale: float,
                    kv_chunk: int, q_chunk: int, window: int = 0,
                    kv_len: int = 0):
    """q: (B,Sq,KH,G,D); k,v: (B,Skv,KH,D).

    Returns (out (B,Sq,KH,G,Dv), lse (B,Sq,KH,G))."""
    B, Sq, KH, G, D = q.shape
    Skv, Dv = k.shape[1], v.shape[-1]
    kv_chunk = min(kv_chunk, Skv)
    q_chunk = min(q_chunk, Sq)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0, (Sq, q_chunk, Skv, kv_chunk)

    qc = q.reshape(B, nq, q_chunk, KH, G, D)
    kc = k.reshape(B, nk, kv_chunk, KH, D)
    vc = v.reshape(B, nk, kv_chunk, KH, Dv)
    kpos = jnp.arange(Skv).reshape(nk, kv_chunk)

    def q_block(carry, qi):
        qb, qpos = qi                              # (B,qc,KH,G,D), (qc,)
        m0 = jnp.full((B, q_chunk, KH, G), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, KH, G), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, KH, G, Dv), jnp.float32)

        def kv_block(st, ki):
            m, l, acc = st
            kb, vb, kp = ki
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            if causal or kv_len:
                mask = qpos[:, None] >= kp[None, :] if causal else \
                    jnp.ones((qpos.shape[0], kp.shape[0]), bool)
                if window > 0 and causal:
                    mask &= (qpos[:, None] - kp[None, :]) < window
                if kv_len:
                    mask &= (kp < kv_len)[None, :]
                s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kpos))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return carry, (out, lse)

    qpos = jnp.arange(Sq).reshape(nq, q_chunk)
    _, (out, lse) = jax.lax.scan(q_block, None, (qc.swapaxes(0, 1), qpos))
    out = out.swapaxes(0, 1).reshape(B, Sq, KH, G, Dv)
    lse = lse.swapaxes(0, 1).reshape(B, Sq, KH, G)
    return out, lse


def _make_flash(causal: bool, scale: float, kv_chunk: int, q_chunk: int,
                window: int, kv_len: int = 0):
    """Builds a custom-VJP flash attention for fixed static settings."""

    @jax.custom_vjp
    def flash(q, k, v):
        out, _ = _flash_fwd_core(q, k, v, causal=causal, scale=scale,
                                 kv_chunk=kv_chunk, q_chunk=q_chunk,
                                 window=window, kv_len=kv_len)
        return out

    def fwd(q, k, v):
        out, lse = _flash_fwd_core(q, k, v, causal=causal, scale=scale,
                                   kv_chunk=kv_chunk, q_chunk=q_chunk,
                                   window=window, kv_len=kv_len)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        B, Sq, KH, G, D = q.shape
        Skv, Dv = k.shape[1], v.shape[-1]
        kvc = min(kv_chunk, Skv)
        qc_ = min(q_chunk, Sq)
        nq, nk = Sq // qc_, Skv // kvc

        f32 = jnp.float32
        qq = q.astype(f32).reshape(B, nq, qc_, KH, G, D)
        oo = out.astype(f32).reshape(B, nq, qc_, KH, G, Dv)
        do = dout.astype(f32).reshape(B, nq, qc_, KH, G, Dv)
        ll = lse.reshape(B, nq, qc_, KH, G)
        kk = k.astype(f32).reshape(B, nk, kvc, KH, D)
        vv = v.astype(f32).reshape(B, nk, kvc, KH, Dv)
        qpos = jnp.arange(Sq).reshape(nq, qc_)
        kpos = jnp.arange(Skv).reshape(nk, kvc)
        # D_i = rowsum(dO * O)
        Drow = jnp.sum(do * oo, axis=-1)              # (B,nq,qc,KH,G)

        def kv_block(dq_acc, ki):
            kb, vb, kp = ki                           # (B,kvc,KH,*)

            def q_block(dkv, qi):
                dk_c, dv_c = dkv
                qb, dob, lb, Db, qp = qi
                s = jnp.einsum("bqhgd,bkhd->bqhgk", qb, kb) * scale
                if causal or kv_len:
                    mask = qp[:, None] >= kp[None, :] if causal else \
                        jnp.ones((qp.shape[0], kp.shape[0]), bool)
                    if window > 0 and causal:
                        mask &= (qp[:, None] - kp[None, :]) < window
                    if kv_len:
                        mask &= (kp < kv_len)[None, :]
                    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
                p = jnp.exp(s - lb[..., None])        # (B,qc,KH,G,kvc)
                dv_c = dv_c + jnp.einsum("bqhgk,bqhgd->bkhd", p, dob)
                dp = jnp.einsum("bqhgd,bkhd->bqhgk", dob, vb)
                ds = p * (dp - Db[..., None]) * scale
                dq_b = jnp.einsum("bqhgk,bkhd->bqhgd", ds, kb)
                dk_c = dk_c + jnp.einsum("bqhgk,bqhgd->bkhd", ds, qb)
                return (dk_c, dv_c), dq_b

            (dk_c, dv_c), dq_blocks = jax.lax.scan(
                q_block,
                (jnp.zeros((B, kvc, KH, D), f32),
                 jnp.zeros((B, kvc, KH, Dv), f32)),
                (qq.swapaxes(0, 1), do.swapaxes(0, 1), ll.swapaxes(0, 1),
                 Drow.swapaxes(0, 1), qpos))
            dq_acc = dq_acc + dq_blocks.swapaxes(0, 1)
            return dq_acc, (dk_c, dv_c)

        dq0 = jnp.zeros((B, nq, qc_, KH, G, D), f32)
        dq, (dk, dv) = jax.lax.scan(
            kv_block, dq0, (kk.swapaxes(0, 1), vv.swapaxes(0, 1), kpos))
        dq = dq.reshape(B, Sq, KH, G, D).astype(q.dtype)
        dk = dk.swapaxes(0, 1).reshape(B, Skv, KH, D).astype(k.dtype)
        dv = dv.swapaxes(0, 1).reshape(B, Skv, KH, Dv).astype(v.dtype)
        return dq, dk, dv

    flash.defvjp(fwd, bwd)
    return flash


def _flash_core(q, k, v, *, causal: bool, q_offset, scale: float,
                kv_chunk: int, q_chunk: int, window: int = 0,
                kv_len: int = 0):
    """q: (B,Sq,KH,G,D); k,v: (B,Skv,KH,D). q_offset must be 0 (decode uses
    decode_sdpa)."""
    return _make_flash(causal, scale, kv_chunk, q_chunk, window,
                       kv_len)(q, k, v)


def _naive_core(q, k, v, *, causal: bool, q_offset, scale: float,
                window: int = 0, kv_len: int = 0):
    """Full materialized scores — identical math, loop-free (cost probes)."""
    Sq, Skv = q.shape[1], k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal or kv_len:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Skv)
        mask = qpos[:, None] >= kpos[None, :] if causal else \
            jnp.ones((Sq, Skv), bool)
        if window > 0 and causal:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        if kv_len:
            mask &= (kpos < kv_len)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))


def _best_chunk(n: int, want: int) -> int:
    """Largest divisor of n that is <= want (flash tiles must divide)."""
    c = min(want, n)
    while n % c != 0:
        c -= 1
    return c


def sdpa(q, k, v, *, causal=True, q_offset=0, window=0,
         kv_chunk=1024, q_chunk=512, cost_mode=False):
    """q: (B,Sq,H,D); k,v: (B,Skv,KH,D) -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    KH = k.shape[2]
    G = H // KH
    qr = q.reshape(B, Sq, KH, G, D)
    scale = 1.0 / math.sqrt(D)
    # context-parallel flash: queries shard over the model axis (seq dim),
    # K/V replicate (one small all-gather per layer). Without this, GSPMD
    # pads 40/8 heads onto the 16-way axis and all-reduces every f32 score
    # tile — +1.03 TB/device collective traffic on qwen2.5 train_4k
    # (EXPERIMENTS.md §Perf iteration 1).
    qr = ash.constrain(qr, "batch", "flash_seq", None, None, None)
    k = ash.constrain(k, "batch", None, None, None)
    v = ash.constrain(v, "batch", None, None, None)
    qc = _best_chunk(Sq, q_chunk)
    kc = _best_chunk(Skv, kv_chunk)
    kv_len = 0
    if kc < 64 and Skv > 256:
        # irregular KV lengths (vision's 1601 patches): pad to a tile
        # multiple and mask the padded keys inside the flash core
        pad = (-Skv) % 128
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = Skv
        Skv += pad
        kc = _best_chunk(Skv, kv_chunk)
    flashable = qc >= 64 and kc >= 64
    if cost_mode or not flashable or (Sq <= qc and Skv <= kc):
        out = _naive_core(qr, k, v, causal=causal, q_offset=q_offset,
                          scale=scale, window=window, kv_len=kv_len)
    else:
        out = _flash_core(qr, k, v, causal=causal, q_offset=q_offset,
                          scale=scale, kv_chunk=kc, q_chunk=qc,
                          window=window, kv_len=kv_len)
    return out.reshape(B, Sq, H, out.shape[-1]).astype(q.dtype)


def decode_sdpa(q, cache_k, cache_v, pos, *, window=0):
    """One-step decode. q: (B,1,H,D); cache: (B,S,KH,D); pos: scalar."""
    B, _, H, D = q.shape
    S, KH = cache_k.shape[1], cache_k.shape[2]
    G = H // KH
    qr = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) / math.sqrt(D)
    kpos = jnp.arange(S)
    mask = kpos <= pos
    if window > 0:
        mask &= (pos - kpos) < window
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, cache_v.astype(jnp.float32))
    return out.reshape(B, 1, H, out.shape[-1]).astype(q.dtype)


# ----------------------------------------------------------------------------
# GQA block-level ops
# ----------------------------------------------------------------------------

def gqa_project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = L.dense(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = L.dense(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.rope_theta > 0:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(p, x, cfg: ModelConfig, *, positions=None, causal=True,
                cost_mode=False):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = gqa_project_qkv(p, x, cfg, positions)
    window = cfg.window_size if cfg.attention == "windowed" else 0
    out = sdpa(q, k, v, causal=causal, window=window, cost_mode=cost_mode)
    return L.dense(p["wo"], out.reshape(B, S, -1))


def gqa_prefill(p, x, cfg: ModelConfig, *, cost_mode=False):
    """Forward + return the KV cache content for this segment."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = gqa_project_qkv(p, x, cfg, positions)
    window = cfg.window_size if cfg.attention == "windowed" else 0
    out = sdpa(q, k, v, causal=True, window=window, cost_mode=cost_mode)
    return L.dense(p["wo"], out.reshape(B, S, -1)), KVCache(k, v)


def gqa_decode(p, x, cache: KVCache, pos, cfg: ModelConfig):
    """x: (B,1,d). Updates cache in place (functionally) at ``pos``."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos)
    q, k, v = gqa_project_qkv(p, x, cfg, positions)
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype),
                                             pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype),
                                             pos, axis=1)
    window = cfg.window_size if cfg.attention == "windowed" else 0
    out = decode_sdpa(q, ck, cv, pos, window=window)
    return L.dense(p["wo"], out.reshape(B, 1, -1)), KVCache(ck, cv)


# ----------------------------------------------------------------------------
# MLA (MiniCPM3 / DeepSeek-style latent attention)
# ----------------------------------------------------------------------------

def _mla_qkv(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q = L.dense(p["wq_b"], L.apply_norm(p["q_norm"], L.dense(p["wq_a"], x),
                                        "rmsnorm"))
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = L.dense(p["wkv_a"], x)
    latent, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    latent = L.apply_norm(p["kv_norm"], latent, "rmsnorm")
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions,
                          cfg.rope_theta)                     # shared head
    return q_nope, q_rope, latent, k_rope[:, :, 0, :]


def _mla_expand_kv(p, latent, k_rope, cfg: ModelConfig):
    m = cfg.mla
    B, S = latent.shape[:2]
    H = cfg.num_heads
    kv = L.dense(p["wkv_b"], latent).reshape(
        B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_forward(p, x, cfg: ModelConfig, *, positions=None, cost_mode=False):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, x, cfg, positions)
    k, v = _mla_expand_kv(p, latent, k_rope, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = sdpa(q, k, v, causal=True, cost_mode=cost_mode)
    return L.dense(p["wo"], out.reshape(B, S, -1))


def mla_prefill(p, x, cfg: ModelConfig, *, cost_mode=False):
    """Cache stores the *latent* (kv_lora_rank + rope) — the MLA win."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, x, cfg, positions)
    k, v = _mla_expand_kv(p, latent, k_rope, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = sdpa(q, k, v, causal=True, cost_mode=cost_mode)
    y = L.dense(p["wo"], out.reshape(B, S, -1))
    cache = jnp.concatenate([latent, k_rope], axis=-1)   # (B,S,rank+rope)
    return y, KVCache(cache, None)


def mla_decode(p, x, cache: KVCache, pos, cfg: ModelConfig,
               absorbed: bool = True):
    """Decode against the latent cache.

    ``absorbed=True`` uses the weight-absorption identity (scores computed in
    latent space; ``wkv_b`` folded into q and the output projection) so the
    per-step cost is O(S·rank) instead of O(S·H·head_dim) — this is the
    beyond-paper optimized path recorded in EXPERIMENTS §Perf.
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    positions = jnp.full((B, 1), pos)
    q_nope, q_rope, latent_new, k_rope_new = _mla_qkv(p, x, cfg, positions)
    new_entry = jnp.concatenate([latent_new, k_rope_new], axis=-1)
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache.k, new_entry.astype(cache.k.dtype), pos, axis=1)
    latents, k_ropes = ck[..., :m.kv_lora_rank], ck[..., m.kv_lora_rank:]
    S = ck.shape[1]
    kpos = jnp.arange(S)
    mask = (kpos <= pos)[None, None, :]

    if absorbed:
        wkv_b = p["wkv_b"]["w"].reshape(
            m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
        w_uk = wkv_b[..., :m.qk_nope_head_dim]       # (rank, H, nope)
        w_uv = wkv_b[..., m.qk_nope_head_dim:]       # (rank, H, v)
        # fold q_nope through w_uk -> latent-space queries
        q_lat = jnp.einsum("bqhn,rhn->bhr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))          # (B,H,rank)
        s = jnp.einsum("bhr,bsr->bhs", q_lat,
                       latents.astype(jnp.float32))
        s = s + jnp.einsum("bqhr,bsr->bhs", q_rope.astype(jnp.float32),
                           k_ropes.astype(jnp.float32))
        s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        s = jnp.where(mask, s, -jnp.inf)
        pattn = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhs,bsr->bhr", pattn,
                         latents.astype(jnp.float32))         # (B,H,rank)
        out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
        y = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    else:
        k, v = _mla_expand_kv(p, latents, k_ropes, cfg)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = decode_sdpa(q, k, v, pos)
        y = out.reshape(B, 1, -1)
    return L.dense(p["wo"], y), KVCache(ck, None)


# ----------------------------------------------------------------------------
# Cross-attention (whisper decoder / llama-vision image layers)
# ----------------------------------------------------------------------------

def cross_kv(p, memory, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder/vision memory."""
    B = memory.shape[0]
    hd = cfg.resolved_head_dim
    k = L.dense(p["wk"], memory).reshape(B, -1, cfg.num_kv_heads, hd)
    v = L.dense(p["wv"], memory).reshape(B, -1, cfg.num_kv_heads, hd)
    return k, v


def cross_attn_forward(p, x, memory, cfg: ModelConfig, *, cost_mode=False):
    """x: (B,S,d) queries; memory: (B,M,d) encoder/vision states."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k, v = cross_kv(p, memory, cfg)
    out = sdpa(q, k, v, causal=False, cost_mode=cost_mode)
    return L.dense(p["wo"], out.reshape(B, S, -1))


def cross_attn_cached(p, x, ck, cv, cfg: ModelConfig):
    """Cross-attention against precomputed K/V (decode fast path)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    out = sdpa(q, ck, cv, causal=False)
    return L.dense(p["wo"], out.reshape(B, S, -1))
