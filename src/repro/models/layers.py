"""Base layers: pure-functional modules over param pytrees.

Every parameter is declared as a ``ParamDef`` carrying its shape, initializer
and *logical axis names*. ``init_params`` materializes arrays (or abstract
ShapeDtypeStructs under ``jax.eval_shape``) and ``param_specs`` turns the same
declaration tree into a ``PartitionSpec`` tree via logical-to-mesh rules —
this keeps init and sharding permanently in sync.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    init: str                      # normal | zeros | ones | embed | scaled
    axes: Tuple[Optional[str], ...]  # logical axis name per dim
    dtype: Any = None              # overrides model dtype (e.g. fp32 norms)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(key: jax.Array, defs, dtype) -> Any:
    """Materialize a ParamDef tree into arrays. eval_shape-safe."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for k, d in zip(keys, leaves):
        dt = d.dtype or dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            # fan-in = product of all non-output dims, excluding stacking
            # axes ("layers" from stacked_defs, "experts" from MoE banks).
            fan_in = 1
            for dim, ax in zip(d.shape[:-1], d.axes[:-1]):
                if ax not in ("layers", "experts"):
                    fan_in *= dim
            fan_in = max(fan_in, 1) if len(d.shape) > 1 else max(
                d.shape[-1], 1)
            scale = {"normal": 0.02,
                     "embed": 0.02,
                     "scaled": 1.0 / math.sqrt(max(fan_in, 1))}[d.init]
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def param_specs(defs, rules: Dict[str, Optional[str]],
                axis_sizes: Optional[Dict[str, int]] = None) -> Any:
    """ParamDef tree -> PartitionSpec tree under logical->mesh ``rules``.

    Conflict resolution:
    - a mesh axis may appear at most once per spec: when two logical axes of
      one tensor map to the same mesh axis (e.g. MoE ("experts","embed",
      "ffn") with experts->model and ffn->model), the FIRST keeps it;
    - with ``axis_sizes`` given, a dim whose size is not divisible by its
      mapped axes falls back to replicated (jit in_shardings require even
      division — e.g. xlstm's 4/3-projection dims).
    """
    def spec(d: ParamDef) -> P:
        used = set()
        out = []
        for dim, a in zip(d.shape, d.axes):
            m = rules.get(a) if a else None
            ms = tuple(m) if isinstance(m, (tuple, list)) \
                else (m,) if m else ()
            if any(x in used for x in ms):
                out.append(None)
                continue
            if axis_sizes is not None and ms:
                total = 1
                for x in ms:
                    total *= axis_sizes.get(x, 1)
                if total == 0 or dim % total != 0:
                    out.append(None)
                    continue
            used.update(ms)
            out.append(m)
        return P(*out)
    return jax.tree.map(spec, defs, is_leaf=is_def)


def param_bytes(defs, dtype_bytes: int = 2) -> int:
    tot = 0
    for d in jax.tree.leaves(defs, is_leaf=is_def):
        n = math.prod(d.shape)
        b = dtype_bytes
        if d.dtype is not None:
            b = jnp.dtype(d.dtype).itemsize
        tot += n * b
    return tot


def param_count(defs) -> int:
    return sum(math.prod(d.shape)
               for d in jax.tree.leaves(defs, is_leaf=is_def))


# ----------------------------------------------------------------------------
# Dense / norm / embedding
# ----------------------------------------------------------------------------

def dense_def(d_in: int, d_out: int, axes=("embed", "ffn"),
              bias: bool = False) -> Dict[str, ParamDef]:
    d = {"w": ParamDef((d_in, d_out), "scaled", axes)}
    if bias:
        d["b"] = ParamDef((d_out,), "zeros", (axes[1],))
    return d


# Trace-time override point: the Origami executor installs the Slalom
# blinded-offload protocol here while tracing tier-1 (core/origami.py).
_DENSE_IMPL = None


@contextlib.contextmanager
def dense_impl(fn):
    global _DENSE_IMPL
    prev = _DENSE_IMPL
    _DENSE_IMPL = fn
    try:
        yield
    finally:
        _DENSE_IMPL = prev


def dense(p, x):
    if _DENSE_IMPL is not None:
        return _DENSE_IMPL(p, x)
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_def(dim: int, kind: str) -> Dict[str, ParamDef]:
    d = {"scale": ParamDef((dim,), "ones", ("embed",), jnp.float32)}
    if kind == "layernorm":
        d["bias"] = ParamDef((dim,), "zeros", ("embed",), jnp.float32)
    return d


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y.astype(x.dtype)


def embed_def(vocab: int, dim: int) -> Dict[str, ParamDef]:
    return {"table": ParamDef((vocab, dim), "embed", ("vocab", "embed"))}


def embed_lookup(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ----------------------------------------------------------------------------
# Rotary position embedding
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]        # broadcast over heads
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ----------------------------------------------------------------------------
# Conv / pooling (VGG family, NHWC)
# ----------------------------------------------------------------------------

def conv_def(c_in: int, c_out: int, k: int = 3) -> Dict[str, ParamDef]:
    return {"w": ParamDef((k, k, c_in, c_out), "scaled",
                          (None, None, None, "ffn")),
            "b": ParamDef((c_out,), "zeros", ("ffn",))}


# Same trace-time override mechanism as _DENSE_IMPL, for VGG tier-1 convs.
_CONV_IMPL = None


@contextlib.contextmanager
def conv_impl(fn):
    global _CONV_IMPL
    prev = _CONV_IMPL
    _CONV_IMPL = fn
    try:
        yield
    finally:
        _CONV_IMPL = prev


def conv2d(p, x, stride: int = 1, padding: str = "SAME"):
    if _CONV_IMPL is not None:
        return _CONV_IMPL(p, x, stride)
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"].astype(x.dtype)


def maxpool2d(x, k: int = 2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


# ----------------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean CE; logits may be over a padded vocab (pad columns masked).

    The label pick uses a fused iota==label masked-reduce instead of
    take_along_axis: gathers along a vocab-sharded axis force GSPMD to
    replicate the logits (observed +13 GB/device); the masked reduce stays
    local + one psum.
    """
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab_size:
        pad = logits.shape[-1] - vocab_size
        mask = jnp.concatenate(
            [jnp.zeros((vocab_size,), jnp.float32),
             jnp.full((pad,), -1e9, jnp.float32)])
        logits = logits + mask
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    return jnp.mean(lse - ll)
