"""VGG-16/19 (the paper's evaluation models), NHWC, pure JAX.

``vgg_forward(..., capture=k)`` additionally returns the feature map after
layer ``k`` (1-based over the cnn_layers list, matching the paper's layer
numbering in Figs. 7/8) — the tensor the c-GAN adversary observes.
``apply_layer_range`` mirrors models/model.py:apply_range so the Origami
executor can split tier-1/tier-2 at any layer.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _parse(spec: str) -> Tuple[str, int]:
    for prefix in ("conv", "fc"):
        if spec.startswith(prefix):
            return prefix, int(spec[len(prefix):])
    return spec, 0


def layer_kind(cfg: ModelConfig, i: int) -> Tuple[str, int]:
    """(kind, width) of layer ``i`` — "conv" | "pool" | "fc" | "logits"."""
    return _parse(cfg.cnn_layers[i])


def feature_shapes(cfg: ModelConfig) -> List[Tuple[int, ...]]:
    """Shape (H, W, C) entering each layer."""
    h = w = cfg.image_size
    c = cfg.image_channels
    shapes = []
    flat = None
    for spec in cfg.cnn_layers:
        kind, n = _parse(spec)
        shapes.append((h, w, c) if flat is None else (flat,))
        if kind == "conv":
            c = n
        elif kind == "pool":
            h, w = h // 2, w // 2
        elif kind == "fc":
            flat = flat if flat is not None else h * w * c
            flat = n
        elif kind == "logits":
            flat = flat if flat is not None else h * w * c
            flat = cfg.num_classes
    return shapes


def vgg_defs(cfg: ModelConfig) -> Dict[str, object]:
    h = w = cfg.image_size
    c = cfg.image_channels
    defs: Dict[str, object] = {}
    flat = None
    for i, spec in enumerate(cfg.cnn_layers):
        kind, n = _parse(spec)
        if kind == "conv":
            defs[f"l{i}"] = L.conv_def(c, n)
            c = n
        elif kind == "pool":
            h, w = h // 2, w // 2
        elif kind == "fc":
            flat_in = flat if flat is not None else h * w * c
            defs[f"l{i}"] = L.dense_def(flat_in, n, ("embed", "ffn"),
                                        bias=True)
            flat = n
        elif kind == "logits":
            flat_in = flat if flat is not None else h * w * c
            defs[f"l{i}"] = L.dense_def(flat_in, cfg.num_classes,
                                        ("embed", "ffn"), bias=True)
            flat = cfg.num_classes
        else:
            raise ValueError(spec)
    return defs


def apply_layer(params, x, cfg: ModelConfig, i: int):
    kind, _ = _parse(cfg.cnn_layers[i])
    if kind == "conv":
        return jax.nn.relu(L.conv2d(params[f"l{i}"], x))
    if kind == "pool":
        return L.maxpool2d(x)
    if kind == "fc":
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        return jax.nn.relu(L.dense(params[f"l{i}"], x))
    if kind == "logits":
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        return L.dense(params[f"l{i}"], x)
    raise ValueError(kind)


def apply_layer_range(params, x, cfg: ModelConfig, lo: int, hi: int):
    for i in range(lo, hi):
        x = apply_layer(params, x, cfg, i)
    return x


def layer_program(cfg: ModelConfig):
    """(prologue, segment, epilogue) — the CNN layer iterator the plan
    interpreter walks (core/plan.py:program_for). The logits ARE the last
    layer's output, so the epilogue is the identity."""
    def prologue(params, batch):
        return batch["images"], None

    def segment(params, x, lo, hi, memory=None):
        return apply_layer_range(params, x, cfg, lo, hi)

    def epilogue(params, x, batch, memory=None):
        return x

    return prologue, segment, epilogue


def blinded_op_records(params, cfg: ModelConfig, layer_ids, batch_size: int):
    """Static blinded-op records for BlindedLayerCache.from_records —
    derived from the config's layer specs alone, no eval_shape re-trace.

    One record per linear layer in ``layer_ids`` (plan order): conv layers
    contribute their im2col shape (t = B·H·W rows since tier-1 convs are
    stride-1 SAME, d_in = kh·kw·cin) with the RAW (kh, kw, cin, cout)
    weight leaf (the cache builder reorders it to im2col columns outside
    any trace); fc/logits layers contribute (t = B, d_in, d_out).
    """
    shapes = feature_shapes(cfg)
    records = []
    for i in layer_ids:
        kind, _ = _parse(cfg.cnn_layers[i])
        w = params[f"l{i}"]["w"]
        if kind == "conv":
            h, wd, _c = shapes[i]
            kh, kw, cin, cout = w.shape
            records.append({"kind": "conv", "w": w,
                            "t": batch_size * h * wd,
                            "d_in": kh * kw * cin, "d_out": cout})
        elif kind in ("fc", "logits"):
            d_in, d_out = w.shape
            records.append({"kind": "dense", "w": w, "t": batch_size,
                            "d_in": d_in, "d_out": d_out})
        else:
            raise ValueError(f"layer {i} ({kind}) has no blinded op")
    return records


def vgg_forward(params, images, cfg: ModelConfig,
                capture: Optional[int] = None):
    """images: (B,H,W,C). capture: 1-based layer index to also return."""
    x = images
    captured = None
    for i in range(len(cfg.cnn_layers)):
        x = apply_layer(params, x, cfg, i)
        if capture is not None and i == capture - 1:
            captured = x
    return (x, captured) if capture is not None else x


def layer_output_bytes(cfg: ModelConfig, batch: int = 1,
                       dtype_bytes: int = 4) -> List[int]:
    """Intermediate feature-map sizes (paper §VI: 47MB/51MB totals)."""
    sizes = []
    h = w = cfg.image_size
    c = cfg.image_channels
    flat = None
    for spec in cfg.cnn_layers:
        kind, n = _parse(spec)
        if kind == "conv":
            c = n
        elif kind == "pool":
            h, w = h // 2, w // 2
        elif kind in ("fc", "logits"):
            flat = n if kind == "fc" else cfg.num_classes
        numel = (h * w * c) if flat is None else flat
        sizes.append(batch * numel * dtype_bytes)
    return sizes
