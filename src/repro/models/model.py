"""Top-level model API: config -> init / forward / prefill / decode.

``apply_range(params, x, cfg, lo, hi)`` runs blocks [lo, hi) so the Origami
executor can place the tier-1 prefix under the blinded-dense context and run
tier-2 open (core/origami.py). Grouped families (hybrid/ssm/vlm) implement
ranges by slicing their super-block structure.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.transformer import lm_defs  # re-export
from repro.parallel import act_sharding as act


# ----------------------------------------------------------------------------
# init / specs / counting
# ----------------------------------------------------------------------------

def model_defs(cfg: ModelConfig):
    if cfg.family == "cnn":
        from repro.models.vgg import vgg_defs
        return vgg_defs(cfg)
    return lm_defs(cfg)


def init_params(cfg: ModelConfig, key: jax.Array):
    return L.init_params(key, model_defs(cfg), jnp.dtype(cfg.dtype))


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params_analytic(cfg: ModelConfig) -> int:
    return L.param_count(model_defs(cfg))


def active_params_analytic(cfg: ModelConfig) -> int:
    """Activated params per token (MoE: top_k of num_experts)."""
    total = count_params_analytic(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    inactive = cfg.num_layers * (m.num_experts - m.top_k) * per_expert
    return total - inactive


# ----------------------------------------------------------------------------
# embed / head
# ----------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig):
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "audio" or (cfg.attention == "none"
                                 and cfg.rope_theta == 0.0):
        S_ = tokens.shape[-1]
        x = x + L.sinusoidal_positions(S_, cfg.d_model).astype(x.dtype)
    return act.constrain(x, "batch", "seq", "embed_act")


def head(params, x, cfg: ModelConfig):
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].astype(x.dtype).T
    else:
        logits = L.dense(params["lm_head"], x)
    return act.constrain(logits, "batch", "seq", "vocab")


# ----------------------------------------------------------------------------
# apply_range per family
# ----------------------------------------------------------------------------

def _range_uniform(params, x, cfg, lo, hi, cost_mode, train):
    blocks = T.slice_layers(params["blocks"], lo, hi)

    def blk(p, h, _):
        return T.decoder_block_fwd(p, h, cfg, cost_mode=cost_mode)

    return T.scan_blocks(blk, blocks, x, cfg, train=train)


def _shared_attn_fwd(p, x, cfg, cost_mode):
    h = x + A.gqa_forward(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm),
                          cfg, cost_mode=cost_mode)
    return h + T.mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg.norm),
                             cfg)


def _mamba_blk(p, x, cfg):
    return x + S.mamba2_forward(p["mamba"],
                                L.apply_norm(p["norm"], x, cfg.norm), cfg)


def _range_hybrid(params, x, cfg, lo, hi, cost_mode, train):
    e = cfg.hybrid_attn_every
    n_main = (cfg.num_layers // e) * e
    groups = n_main // e

    def scan_mamba(stacked, h):
        def blk(p, h_, _):
            return _mamba_blk(p, h_, cfg), 0.0
        h, _ = T.scan_blocks(blk, stacked, h, cfg, train=train)
        return h

    for g in range(groups):
        g_lo, g_hi = g * e, (g + 1) * e
        a, b = max(lo, g_lo), min(hi, g_hi)
        if a >= b:
            continue
        sub = jax.tree.map(lambda t: t[g], params["mamba_main"])
        x = scan_mamba(T.slice_layers(sub, a - g_lo, b - g_lo), x)
        if b == g_hi and hi >= g_hi:   # group completed inside range
            x = _shared_attn_fwd(params["shared_attn"], x, cfg, cost_mode)
    a, b = max(lo, n_main), min(hi, cfg.num_layers)
    if a < b and "mamba_tail" in params:
        x = scan_mamba(T.slice_layers(params["mamba_tail"],
                                      a - n_main, b - n_main), x)
    return x, 0.0


def _mlstm_blk(p, x, cfg):
    return x + S.mlstm_forward(p["mlstm"],
                               L.apply_norm(p["norm"], x, cfg.norm), cfg)


def _range_xlstm(params, x, cfg, lo, hi, cost_mode, train):
    e = cfg.ssm.slstm_every
    groups = cfg.num_layers // e
    for g in range(groups):
        g_lo = g * e
        a, b = max(lo, g_lo), min(hi, g_lo + e - 1)   # mlstm sub-blocks
        if a < b:
            sub = jax.tree.map(lambda t: t[g], params["mlstm_groups"])

            def blk(p, h, _):
                return _mlstm_blk(p, h, cfg), 0.0
            x, _ = T.scan_blocks(blk, T.slice_layers(sub, a - g_lo, b - g_lo),
                                 x, cfg, train=train)
        sidx = g_lo + e - 1
        if lo <= sidx < hi:
            sp = jax.tree.map(lambda t: t[g], params["slstm_groups"])
            y, _ = S.slstm_forward(sp["slstm"],
                                   L.apply_norm(sp["norm"], x, cfg.norm), cfg)
            x = x + y
    return x, 0.0


def _range_vlm(params, x, cfg, lo, hi, cost_mode, train, patches=None):
    e = cfg.cross_attn_every
    groups = cfg.num_layers // e
    for g in range(groups):
        g_lo = g * e
        a, b = max(lo, g_lo), min(hi, g_lo + e - 1)   # self sub-blocks
        if a < b:
            sub = jax.tree.map(lambda t: t[g], params["self_groups"])

            def blk(p, h, _):
                return T.decoder_block_fwd(p, h, cfg, cost_mode=cost_mode)
            x, _ = T.scan_blocks(blk, T.slice_layers(sub, a - g_lo, b - g_lo),
                                 x, cfg, train=train)
        cidx = g_lo + e - 1
        if lo <= cidx < hi:
            cp = jax.tree.map(lambda t: t[g], params["cross_groups"])
            x = T.vlm_cross_block_fwd(cp, x, patches, cfg,
                                      cost_mode=cost_mode)
    return x, 0.0


def _range_audio_encoder(params, x, cfg, lo, hi, cost_mode, train):
    blocks = T.slice_layers(params["enc_blocks"], lo, hi)

    def blk(p, h, _):
        return T.encoder_block_fwd(p, h, cfg, cost_mode=cost_mode), 0.0

    return T.scan_blocks(blk, blocks, x, cfg, train=train)


def apply_range(params, x, cfg: ModelConfig, lo: int, hi: int, *,
                cost_mode=False, train=False, memory=None):
    """Run blocks [lo, hi) on hidden states x. ``memory`` = patches (vlm)."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _range_uniform(params, x, cfg, lo, hi, cost_mode, train)
    if fam == "hybrid":
        return _range_hybrid(params, x, cfg, lo, hi, cost_mode, train)
    if fam == "ssm":
        return _range_xlstm(params, x, cfg, lo, hi, cost_mode, train)
    if fam == "vlm":
        return _range_vlm(params, x, cfg, lo, hi, cost_mode, train,
                          patches=memory)
    if fam == "audio":
        # ranges apply to the encoder prefix (tier-1 ⊆ encoder, DESIGN.md §5)
        return _range_audio_encoder(params, x, cfg, lo, hi, cost_mode, train)
    raise ValueError(fam)


def layer_program(cfg: ModelConfig):
    """(prologue, segment, epilogue) — the LM/audio/vlm layer iterator the
    plan interpreter walks (core/plan.py:program_for).

    Audio plans range over the *encoder* blocks (tier-1 ⊆ encoder — the
    private input is the audio, DESIGN.md §5); the decoder runs in the
    epilogue, always in the clear like the LM head."""
    if cfg.family == "audio":
        def prologue(params, batch):
            frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
            x = frames + L.sinusoidal_positions(
                frames.shape[1], cfg.d_model).astype(frames.dtype)
            return x, None

        def segment(params, x, lo, hi, memory=None):
            x, _ = apply_range(params, x, cfg, lo, hi)
            return x

        def epilogue(params, x, batch, memory=None):
            mem = L.apply_norm(params["enc_norm"], x, cfg.norm)
            return forward_audio_decoder(params, batch, mem, cfg)

        return prologue, segment, epilogue

    def prologue(params, batch):
        memory = batch.get("patches") if cfg.family == "vlm" else None
        return embed_tokens(params, batch["tokens"], cfg), memory

    def segment(params, x, lo, hi, memory=None):
        x, _ = apply_range(params, x, cfg, lo, hi, memory=memory)
        return x

    def epilogue(params, x, batch, memory=None):
        return head(params, x, cfg)

    return prologue, segment, epilogue


# ----------------------------------------------------------------------------
# forward (teacher-forced) per family
# ----------------------------------------------------------------------------

def forward(params, batch: Dict[str, jax.Array], cfg: ModelConfig, *,
            cost_mode=False, train=False) -> T.LMOutputs:
    fam = cfg.family
    if fam == "audio":
        return _forward_audio(params, batch, cfg, cost_mode, train)
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    memory = batch.get("patches") if fam == "vlm" else None
    x, aux = apply_range(params, x, cfg, 0, cfg.num_layers,
                         cost_mode=cost_mode, train=train, memory=memory)
    return T.LMOutputs(head(params, x, cfg), aux)


def encode_audio(params, frames, cfg: ModelConfig, *, cost_mode=False,
                 train=False):
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x, _ = _range_audio_encoder(params, x, cfg, 0, cfg.num_layers,
                                cost_mode, train)
    return L.apply_norm(params["enc_norm"], x, cfg.norm)


def forward_audio_decoder(params, batch, memory, cfg: ModelConfig, *,
                          cost_mode=False, train=False):
    """Decoder over a precomputed encoder memory (Origami tier-2 path)."""
    x = embed_tokens(params, batch["tokens"], cfg)

    def blk(p, h, _):
        return T.cross_decoder_block_fwd(p, h, memory, cfg,
                                         cost_mode=cost_mode), 0.0

    x, _ = T.scan_blocks(blk, params["dec_blocks"], x, cfg, train=train)
    return head(params, x, cfg)


def _forward_audio(params, batch, cfg, cost_mode, train):
    memory = encode_audio(params, batch["frames"], cfg, cost_mode=cost_mode,
                          train=train)
    return T.LMOutputs(
        forward_audio_decoder(params, batch, memory, cfg,
                              cost_mode=cost_mode, train=train), 0.0)


def loss_fn(params, batch, cfg: ModelConfig, aux_weight: float = 0.01):
    out = forward(params, batch, cfg, train=True)
    logits = out.logits[:, :-1]
    labels = batch["tokens"][:, 1:]
    ce = L.cross_entropy(logits, labels, cfg.vocab_size)
    return ce + aux_weight * out.aux_loss, ce


# ----------------------------------------------------------------------------
# caches
# ----------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    fam = cfg.family
    hd = cfg.resolved_head_dim

    def kv(n_layers, seq=max_seq, kh=cfg.num_kv_heads):
        return A.KVCache(
            k=jnp.zeros((n_layers, batch, seq, kh, hd), dtype),
            v=jnp.zeros((n_layers, batch, seq, kh, hd), dtype))

    if fam in ("dense", "moe"):
        if cfg.attention == "mla":
            m = cfg.mla
            width = m.kv_lora_rank + m.qk_rope_head_dim
            return A.KVCache(
                k=jnp.zeros((cfg.num_layers, batch, max_seq, width), dtype),
                v=None)
        return kv(cfg.num_layers)
    if fam == "hybrid":
        e = cfg.hybrid_attn_every
        groups = (cfg.num_layers // e)
        tail = cfg.num_layers - groups * e

        def stack_states(n_outer, n_inner=None):
            st = S.mamba2_init_state(cfg, batch)
            def rep(x, n):
                return jnp.broadcast_to(x[None], (n,) + x.shape)
            if n_inner is None:
                return jax.tree.map(lambda x: rep(x, n_outer), st)
            return jax.tree.map(
                lambda x: rep(rep(x, n_inner), n_outer), st)

        caches = {"main": stack_states(groups, e),
                  "shared": kv(groups)}
        if tail:
            caches["tail"] = stack_states(tail)
        return caches
    if fam == "ssm":
        e = cfg.ssm.slstm_every
        groups = cfg.num_layers // e
        mst = S.mlstm_init_state(cfg, batch)
        sst = S.slstm_init_state(cfg, batch)

        def rep(x, n):
            return jnp.broadcast_to(x[None], (n,) + x.shape)
        return {"mlstm": jax.tree.map(
                    lambda x: rep(rep(x, e - 1), groups), mst),
                "slstm": jax.tree.map(lambda x: rep(x, groups), sst)}
    if fam == "audio":
        M_ = cfg.encoder_seq_len
        return {"self": kv(cfg.num_layers),
                "cross_k": jnp.zeros((cfg.num_layers, batch, M_,
                                      cfg.num_kv_heads, hd), dtype),
                "cross_v": jnp.zeros((cfg.num_layers, batch, M_,
                                      cfg.num_kv_heads, hd), dtype)}
    if fam == "vlm":
        e = cfg.cross_attn_every
        groups = cfg.num_layers // e
        M_ = cfg.vision_seq_len
        return {"self": A.KVCache(
                    k=jnp.zeros((groups, e - 1, batch, max_seq,
                                 cfg.num_kv_heads, hd), dtype),
                    v=jnp.zeros((groups, e - 1, batch, max_seq,
                                 cfg.num_kv_heads, hd), dtype)),
                "cross_k": jnp.zeros((groups, batch, M_,
                                      cfg.num_kv_heads, hd), dtype),
                "cross_v": jnp.zeros((groups, batch, M_,
                                      cfg.num_kv_heads, hd), dtype)}
    raise ValueError(fam)


# ----------------------------------------------------------------------------
# prefill
# ----------------------------------------------------------------------------

def prefill(params, batch, cfg: ModelConfig, *, max_seq: Optional[int] = None,
            cost_mode=False):
    """Returns (last-position logits, caches sized to max_seq)."""
    fam = cfg.family
    tokens = batch["tokens"]
    B, S_len = tokens.shape
    max_seq = max_seq or S_len
    cache_dtype = jnp.bfloat16

    def pad_cache(c):
        """Grow stacked prefill caches (L,B,S,...) to (L,B,max_seq,...)."""
        if max_seq == S_len:
            return c
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, max_seq - S_len)
        return jnp.pad(c, pad)

    if fam in ("dense", "moe"):
        x = embed_tokens(params, tokens, cfg)

        def blk(p, h, _):
            h, cache, aux = T.decoder_block_prefill(p, h, cfg,
                                                    cost_mode=cost_mode)
            return h, (cache, aux)

        def body(carry, p_i):
            return blk(p_i, carry, None)

        x, (caches, auxs) = jax.lax.scan(body, x, params["blocks"])
        caches = jax.tree.map(
            lambda c: pad_cache(c.astype(cache_dtype))
            if c is not None else None, caches,
            is_leaf=lambda v: v is None)
        return head(params, x[:, -1:], cfg), caches
    if fam == "audio":
        memory = encode_audio(params, batch["frames"], cfg,
                              cost_mode=cost_mode)
        x = embed_tokens(params, tokens, cfg)

        def body(carry, p_i):
            h, cache = T.cross_decoder_block_prefill(
                p_i, carry, memory, cfg, cost_mode=cost_mode)
            ck, cv = A.cross_kv(p_i["xattn"], memory, cfg)
            return h, (cache, ck, cv)

        x, (caches, cks, cvs) = jax.lax.scan(body, x, params["dec_blocks"])
        return head(params, x[:, -1:], cfg), {
            "self": jax.tree.map(lambda c: pad_cache(c.astype(cache_dtype)),
                                 caches),
            "cross_k": cks.astype(cache_dtype),
            "cross_v": cvs.astype(cache_dtype)}
    # grouped families: prefill == forward + state capture, implemented via
    # their decode-oriented state functions (hybrid/ssm) below.
    raise NotImplementedError(
        f"prefill for family {fam}: use forward() + decode-from-scratch; "
        "assigned prefill cells cover dense/moe/audio/vlm via prefill_vlm")


def prefill_vlm(params, batch, cfg: ModelConfig, *, max_seq=None,
                cost_mode=False):
    tokens, patches = batch["tokens"], batch["patches"]
    B, S_len = tokens.shape
    max_seq = max_seq or S_len
    x = embed_tokens(params, tokens, cfg)
    e = cfg.cross_attn_every
    groups = cfg.num_layers // e
    self_caches, cross_ks, cross_vs = [], [], []
    for g in range(groups):
        sub = jax.tree.map(lambda t: t[g], params["self_groups"])

        def body(carry, p_i):
            h, cache, _ = T.decoder_block_prefill(p_i, carry, cfg,
                                                  cost_mode=cost_mode)
            return h, cache

        x, caches = jax.lax.scan(body, x, sub)
        cp = jax.tree.map(lambda t: t[g], params["cross_groups"])
        x = T.vlm_cross_block_fwd(cp, x, patches.astype(x.dtype), cfg,
                                  cost_mode=cost_mode)
        ck, cv = A.cross_kv(cp["xattn"], patches.astype(x.dtype), cfg)
        self_caches.append(caches)
        cross_ks.append(ck)
        cross_vs.append(cv)

    def pad_cache(c):
        if max_seq == c.shape[2]:
            return c
        pad = [(0, 0)] * c.ndim
        pad[2] = (0, max_seq - c.shape[2])
        return jnp.pad(c, pad)

    stack = lambda xs: jax.tree.map(lambda *a: jnp.stack(a), *xs)
    return head(params, x[:, -1:], cfg), {
        "self": jax.tree.map(lambda c: pad_cache(c.astype(jnp.bfloat16)),
                             stack(self_caches)),
        "cross_k": jnp.stack(cross_ks).astype(jnp.bfloat16),
        "cross_v": jnp.stack(cross_vs).astype(jnp.bfloat16)}


# ----------------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------------

def decode_range(params, x, caches, pos, cfg: ModelConfig,
                 lo: int, hi: int):
    """One-token step through blocks [lo, hi) (dense/moe families)."""
    blocks = T.slice_layers(params["blocks"], lo, hi)
    sub_caches = jax.tree.map(
        lambda c: None if c is None else c[lo:hi], caches,
        is_leaf=lambda v: v is None)

    def body(carry, xs):
        p_i, c_i = xs
        h, c_new = T.decoder_block_decode(p_i, carry, c_i, pos, cfg)
        return h, c_new

    x, new_caches = jax.lax.scan(body, x, (blocks, sub_caches))
    merged = jax.tree.map(
        lambda full, new: jax.lax.dynamic_update_slice_in_dim(
            full, new.astype(full.dtype), lo, axis=0)
        if full is not None else None,
        caches, new_caches, is_leaf=lambda v: v is None)
    return x, merged


def decode_range_unrolled(params, x, caches, pos, cfg: ModelConfig,
                          lo: int, hi: int):
    """``decode_range`` with the block walk UNROLLED at trace time
    (dense family): a Python loop over blocks [lo, hi) instead of
    ``lax.scan`` over stacked params.

    Every linear op of every block becomes an individually-addressable
    traced call, which is what lets the decode interpreter
    (core/origami.py) bind per-(token, layer) blinding factors from the
    token-slot ring and run per-step Freivalds verification — the thing
    the scanned walk structurally cannot do (DESIGN.md §16). Numerically
    identical to ``decode_range``; the scanned form stays the fast path
    for plain segments and open generation."""
    new = []
    for i in range(lo, hi):
        p_i = jax.tree.map(lambda t: t[i], params["blocks"])
        c_i = jax.tree.map(lambda c: None if c is None else c[i], caches,
                           is_leaf=lambda v: v is None)
        x, c_new = T.decoder_block_decode(p_i, x, c_i, pos, cfg)
        new.append(c_new)
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *new)
    merged = jax.tree.map(
        lambda full, upd: jax.lax.dynamic_update_slice_in_dim(
            full, upd.astype(full.dtype), lo, axis=0)
        if full is not None else None,
        caches, stacked, is_leaf=lambda v: v is None)
    return x, merged


def prefill_range(params, x, cfg: ModelConfig, lo: int, hi: int, *,
                  cost_mode=False):
    """Prefill blocks [lo, hi) on hidden states x (dense/moe families).

    Returns ``(x, caches)`` with the caches' leading dim = hi - lo — the
    per-segment half of ``prefill``, so the plan interpreter can walk the
    prompt through the base plan's segments (blinded prefix under the
    dense intercept, open suffix without) and still come out with the
    full KV caches the decode loop needs."""
    blocks = T.slice_layers(params["blocks"], lo, hi)

    def body(carry, p_i):
        h, cache, _aux = T.decoder_block_prefill(p_i, carry, cfg,
                                                 cost_mode=cost_mode)
        return h, cache

    return jax.lax.scan(body, x, blocks)


def prefill_range_unrolled(params, x, cfg: ModelConfig, lo: int, hi: int, *,
                           cost_mode=False):
    """``prefill_range`` with the block walk unrolled at trace time —
    the prompt-side twin of ``decode_range_unrolled``: inside a blinded
    plan segment every prompt linear op becomes its own traced call, so
    it draws its own blinding key and Freivalds fold instead of sharing
    one scanned call (and one pad) across layers."""
    cs = []
    for i in range(lo, hi):
        p_i = jax.tree.map(lambda t: t[i], params["blocks"])
        x, cache, _aux = T.decoder_block_prefill(p_i, x, cfg,
                                                 cost_mode=cost_mode)
        cs.append(cache)
    return x, jax.tree.map(lambda *a: jnp.stack(a), *cs)


def concat_layer_caches(parts, max_seq: int, dtype=jnp.bfloat16):
    """Stitch per-segment prefill caches (leading layer dim) back into one
    stack, padded along the sequence axis to ``max_seq`` and cast to the
    decode cache dtype."""
    caches = jax.tree.map(
        lambda *cs: (None if cs[0] is None
                     else jnp.concatenate(cs, axis=0)),
        *parts, is_leaf=lambda v: v is None)

    def pad(c):
        if c is None:
            return None
        if c.shape[2] == max_seq:
            return c.astype(dtype)
        padw = [(0, 0)] * c.ndim
        padw[2] = (0, max_seq - c.shape[2])
        return jnp.pad(c, padw).astype(dtype)

    return jax.tree.map(pad, caches, is_leaf=lambda v: v is None)


def decode_step(params, token, caches, pos, cfg: ModelConfig):
    """token: (B, 1) int32; pos: scalar int32. Returns (logits, caches)."""
    fam = cfg.family
    x = embed_tokens_at(params, token, pos, cfg)
    if fam in ("dense", "moe"):
        x, caches = decode_range(params, x, caches, pos, cfg,
                                 0, cfg.num_layers)
        return head(params, x, cfg), caches
    if fam == "hybrid":
        return _decode_hybrid(params, x, caches, pos, cfg)
    if fam == "ssm":
        return _decode_xlstm(params, x, caches, pos, cfg)
    if fam == "audio":
        return _decode_audio(params, x, caches, pos, cfg)
    if fam == "vlm":
        return _decode_vlm(params, x, caches, pos, cfg)
    raise ValueError(fam)


def embed_tokens_at(params, token, pos, cfg: ModelConfig):
    x = L.embed_lookup(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "audio" or (cfg.attention == "none"
                                 and cfg.rope_theta == 0.0):
        d = cfg.d_model
        half = jnp.arange(0, d, 2, dtype=jnp.float32)
        div = jnp.exp(half * (-jnp.log(10000.0) / d))
        ang = pos.astype(jnp.float32) * div
        pe = jnp.zeros((d,), jnp.float32)
        pe = pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
        x = x + pe.astype(x.dtype)
    return x


def _decode_hybrid(params, x, caches, pos, cfg):
    e = cfg.hybrid_attn_every
    groups = cfg.num_layers // e
    new_main, new_shared_k, new_shared_v = [], [], []
    for g in range(groups):
        sub_p = jax.tree.map(lambda t: t[g], params["mamba_main"])
        sub_c = jax.tree.map(lambda t: t[g], caches["main"])

        def body(carry, xs):
            p_i, c_i = xs
            y, c_new = S.mamba2_decode(
                p_i["mamba"], L.apply_norm(p_i["norm"], carry, cfg.norm),
                c_i, cfg)
            return carry + y, c_new

        x, c_new = jax.lax.scan(body, x, (sub_p, sub_c))
        new_main.append(c_new)
        sp = params["shared_attn"]
        shared_cache = jax.tree.map(lambda t: t[g], caches["shared"])
        a, sc = A.gqa_decode(sp["attn"], L.apply_norm(sp["ln1"], x, cfg.norm),
                             shared_cache, pos, cfg)
        x = x + a
        x = x + T.mlp_forward(sp["mlp"],
                              L.apply_norm(sp["ln2"], x, cfg.norm), cfg)
        new_shared_k.append(sc.k)
        new_shared_v.append(sc.v)
    out_caches = {
        "main": jax.tree.map(lambda *a: jnp.stack(a), *new_main),
        "shared": A.KVCache(jnp.stack(new_shared_k),
                            jnp.stack(new_shared_v)),
    }
    if "tail" in caches:
        def body(carry, xs):
            p_i, c_i = xs
            y, c_new = S.mamba2_decode(
                p_i["mamba"], L.apply_norm(p_i["norm"], carry, cfg.norm),
                c_i, cfg)
            return carry + y, c_new
        x, c_new = jax.lax.scan(body, x, (params["mamba_tail"],
                                          caches["tail"]))
        out_caches["tail"] = c_new
    return head(params, x, cfg), out_caches


def _decode_xlstm(params, x, caches, pos, cfg):
    e = cfg.ssm.slstm_every
    groups = cfg.num_layers // e
    new_m, new_s = [], []
    for g in range(groups):
        sub_p = jax.tree.map(lambda t: t[g], params["mlstm_groups"])
        sub_c = jax.tree.map(lambda t: t[g], caches["mlstm"])

        def body(carry, xs):
            p_i, c_i = xs
            y, c_new = S.mlstm_decode(
                p_i["mlstm"], L.apply_norm(p_i["norm"], carry, cfg.norm),
                c_i, cfg)
            return carry + y, c_new

        x, c_new = jax.lax.scan(body, x, (sub_p, sub_c))
        new_m.append(c_new)
        sp = jax.tree.map(lambda t: t[g], params["slstm_groups"])
        sc = jax.tree.map(lambda t: t[g], caches["slstm"])
        y, sc_new = S.slstm_forward(
            sp["slstm"], L.apply_norm(sp["norm"], x, cfg.norm), cfg, state=sc)
        x = x + y
        new_s.append(sc_new)
    return head(params, x, cfg), {
        "mlstm": jax.tree.map(lambda *a: jnp.stack(a), *new_m),
        "slstm": jax.tree.map(lambda *a: jnp.stack(a), *new_s)}


def _decode_audio(params, x, caches, pos, cfg):
    def body(carry, xs):
        p_i, c_i, ck, cv = xs
        h, c_new = T.cross_decoder_block_decode(p_i, carry, ck, cv, c_i,
                                                pos, cfg)
        return h, c_new

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], caches["self"],
                  caches["cross_k"], caches["cross_v"]))
    return head(params, x, cfg), {**caches, "self": new_self}


def _decode_vlm(params, x, caches, pos, cfg):
    e = cfg.cross_attn_every
    groups = cfg.num_layers // e
    new_selfs = []
    for g in range(groups):
        sub_p = jax.tree.map(lambda t: t[g], params["self_groups"])
        sub_c = jax.tree.map(lambda t: t[g], caches["self"])

        def body(carry, xs):
            p_i, c_i = xs
            h, c_new = T.decoder_block_decode(p_i, carry, c_i, pos, cfg)
            return h, c_new

        x, c_new = jax.lax.scan(body, x, (sub_p, sub_c))
        new_selfs.append(c_new)
        cp = jax.tree.map(lambda t: t[g], params["cross_groups"])
        x = T.vlm_cross_block_cached(cp, x, caches["cross_k"][g],
                                     caches["cross_v"][g], cfg)
    return head(params, x, cfg), {
        **caches,
        "self": jax.tree.map(lambda *a: jnp.stack(a), *new_selfs)}
