"""Transformer stacks for every assigned family.

All uniform stacks scan over stacked layer params (small HLO, fast 1-core
compiles); heterogeneous families (hybrid, vlm, xlstm) scan over
*super-blocks*. Every stack exposes three entry points:

    forward(params, x, ...)   teacher-forced full-sequence (train loss path)
    prefill(params, x, ...)   forward + per-layer caches/states
    decode(params, x, caches, pos, ...) one-token step against caches

``layer_range`` slices the stacked params so the Origami executor can run
tier-1 ([0, p)) under the blinded-dense context and tier-2 ([p, L)) open —
see core/origami.py.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.parallel import act_sharding as ash


# ----------------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------------

def _gated(cfg: ModelConfig) -> bool:
    return cfg.activation == "silu"


def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if _gated(cfg):
        return {"w_gate": L.dense_def(d, d_ff, ("embed", "ffn")),
                "w_up": L.dense_def(d, d_ff, ("embed", "ffn")),
                "w_down": L.dense_def(d_ff, d, ("ffn", "embed"))}
    return {"w_up": L.dense_def(d, d_ff, ("embed", "ffn")),
            "w_down": L.dense_def(d_ff, d, ("ffn", "embed"))}


def mlp_forward(p, x, cfg: ModelConfig):
    act = L.activation(cfg.activation)
    if "w_gate" in p:
        h = act(L.dense(p["w_gate"], x)) * L.dense(p["w_up"], x)
    else:
        h = act(L.dense(p["w_up"], x))
    h = ash.constrain(h, "batch", "seq", "ffn_act")
    return L.dense(p["w_down"], h)


# ----------------------------------------------------------------------------
# Decoder blocks (dense / moe)
# ----------------------------------------------------------------------------

def decoder_block_defs(cfg: ModelConfig):
    attn = A.mla_defs(cfg) if cfg.attention == "mla" else A.gqa_defs(cfg)
    d = {"ln1": L.norm_def(cfg.d_model, cfg.norm), "attn": attn,
         "ln2": L.norm_def(cfg.d_model, cfg.norm)}
    if cfg.moe is not None:
        d["moe"] = M.moe_defs(cfg)
    else:
        d["mlp"] = mlp_defs(cfg)
    return d


def _attn_fwd(p, x, cfg, *, cost_mode):
    if cfg.attention == "mla":
        return A.mla_forward(p, x, cfg, cost_mode=cost_mode)
    return A.gqa_forward(p, x, cfg, cost_mode=cost_mode)


def _attn_prefill(p, x, cfg, *, cost_mode):
    if cfg.attention == "mla":
        return A.mla_prefill(p, x, cfg, cost_mode=cost_mode)
    return A.gqa_prefill(p, x, cfg, cost_mode=cost_mode)


def _attn_decode(p, x, cache, pos, cfg):
    if cfg.attention == "mla":
        return A.mla_decode(p, x, cache, pos, cfg)
    return A.gqa_decode(p, x, cache, pos, cfg)


def _ffn(p, x, cfg):
    if cfg.moe is not None:
        return M.moe_forward(p["moe"], x, cfg)
    return mlp_forward(p["mlp"], x, cfg), 0.0


def decoder_block_fwd(p, x, cfg: ModelConfig, *, cost_mode=False):
    h = x + _attn_fwd(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm), cfg,
                      cost_mode=cost_mode)
    y, aux = _ffn(p, L.apply_norm(p["ln2"], h, cfg.norm), cfg)
    # NOTE: a Megatron-SP variant ("boundary_seq"->model here) was measured
    # and REFUTED on qwen2.5 train_4k: collective 24.0->20.8 s but memory
    # 25.7->52.2 s and compute 3.12->5.17 s — GSPMD materializes the
    # boundary reshards (EXPERIMENTS.md §Perf Cell A iteration 3).
    return ash.constrain(h + y, "batch", "seq", "embed_act"), aux


def decoder_block_prefill(p, x, cfg: ModelConfig, *, cost_mode=False):
    a, cache = _attn_prefill(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm),
                             cfg, cost_mode=cost_mode)
    h = x + a
    y, aux = _ffn(p, L.apply_norm(p["ln2"], h, cfg.norm), cfg)
    return h + y, cache, aux


def decoder_block_decode(p, x, cache, pos, cfg: ModelConfig):
    a, cache = _attn_decode(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm),
                            cache, pos, cfg)
    h = x + a
    y, _ = _ffn(p, L.apply_norm(p["ln2"], h, cfg.norm), cfg)
    return h + y, cache


# ----------------------------------------------------------------------------
# Stack helpers: stacked-param init + scan
# ----------------------------------------------------------------------------

def stacked_defs(defs, n: int):
    """Prepend a layer dimension to every ParamDef in ``defs``."""
    def stack(d: L.ParamDef) -> L.ParamDef:
        return L.ParamDef((n,) + d.shape, d.init, ("layers",) + d.axes,
                          d.dtype)
    return jax.tree.map(stack, defs, is_leaf=L.is_def)


def slice_layers(stacked, lo: int, hi: int):
    return jax.tree.map(lambda a: a[lo:hi], stacked)


def _maybe_remat(fn, cfg: ModelConfig, train: bool):
    if train and cfg.remat != "none":
        return jax.checkpoint(fn)
    return fn


def scan_blocks(block_fn, params, x, cfg: ModelConfig, *, train: bool,
                extras=None):
    """Scan ``block_fn(p_i, x, extra_i) -> (x, aux)`` over stacked params."""
    def body(carry, xs):
        p_i = xs[0] if extras is not None else xs
        e_i = xs[1] if extras is not None else None
        y, aux = block_fn(p_i, carry, e_i)
        return y, aux

    body = _maybe_remat(body, cfg, train)
    xs = (params, extras) if extras is not None else params
    x, auxs = jax.lax.scan(body, x, xs)
    return x, jnp.sum(auxs) if auxs is not None else 0.0


# ----------------------------------------------------------------------------
# LM top level (embed -> stack -> norm -> head), family dispatch
# ----------------------------------------------------------------------------

class LMOutputs(NamedTuple):
    logits: jax.Array
    aux_loss: Any


def lm_defs(cfg: ModelConfig) -> Dict[str, object]:
    d: Dict[str, object] = {
        "embed": L.embed_def(cfg.padded_vocab, cfg.d_model),
        "final_norm": L.norm_def(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        d["lm_head"] = L.dense_def(cfg.d_model, cfg.padded_vocab,
                                   ("embed", "vocab"))
    fam = cfg.family
    if fam in ("dense", "moe"):
        d["blocks"] = stacked_defs(decoder_block_defs(cfg), cfg.num_layers)
    elif fam == "hybrid":
        n_main = (cfg.num_layers // cfg.hybrid_attn_every) \
            * cfg.hybrid_attn_every
        groups = n_main // cfg.hybrid_attn_every
        mamba = {"norm": L.norm_def(cfg.d_model, cfg.norm),
                 "mamba": S.mamba2_defs(cfg)}
        d["mamba_main"] = stacked_defs(
            stacked_defs(mamba, cfg.hybrid_attn_every), groups)
        if cfg.num_layers - n_main:
            d["mamba_tail"] = stacked_defs(mamba, cfg.num_layers - n_main)
        d["shared_attn"] = {
            "ln1": L.norm_def(cfg.d_model, cfg.norm),
            "attn": A.gqa_defs(cfg),
            "ln2": L.norm_def(cfg.d_model, cfg.norm),
            "mlp": mlp_defs(cfg),
        }
    elif fam == "ssm":         # xlstm
        every = cfg.ssm.slstm_every
        assert cfg.num_layers % every == 0, "xlstm layers % slstm_every"
        groups = cfg.num_layers // every
        mblock = {"norm": L.norm_def(cfg.d_model, cfg.norm),
                  "mlstm": S.mlstm_defs(cfg)}
        sblock = {"norm": L.norm_def(cfg.d_model, cfg.norm),
                  "slstm": S.slstm_defs(cfg)}
        d["mlstm_groups"] = stacked_defs(stacked_defs(mblock, every - 1),
                                         groups)
        d["slstm_groups"] = stacked_defs(sblock, groups)
    elif fam == "audio":       # whisper enc-dec
        d["enc_blocks"] = stacked_defs(encoder_block_defs(cfg),
                                       cfg.num_layers)
        d["enc_norm"] = L.norm_def(cfg.d_model, cfg.norm)
        d["dec_blocks"] = stacked_defs(cross_decoder_block_defs(cfg),
                                       cfg.num_layers)
    elif fam == "vlm":
        every = cfg.cross_attn_every
        assert cfg.num_layers % every == 0
        groups = cfg.num_layers // every
        d["self_groups"] = stacked_defs(
            stacked_defs(decoder_block_defs(cfg), every - 1), groups)
        d["cross_groups"] = stacked_defs(vlm_cross_block_defs(cfg), groups)
    else:
        raise ValueError(f"unknown family {fam}")
    return d


# ----------------------------------------------------------------------------
# Whisper blocks
# ----------------------------------------------------------------------------

def encoder_block_defs(cfg: ModelConfig):
    return {"ln1": L.norm_def(cfg.d_model, cfg.norm),
            "attn": A.gqa_defs(cfg),
            "ln2": L.norm_def(cfg.d_model, cfg.norm),
            "mlp": mlp_defs(cfg)}


def encoder_block_fwd(p, x, cfg, *, cost_mode=False):
    h = x + A.gqa_forward(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm),
                          cfg, causal=False, cost_mode=cost_mode)
    return h + mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg.norm), cfg)


def cross_decoder_block_defs(cfg: ModelConfig):
    return {"ln1": L.norm_def(cfg.d_model, cfg.norm),
            "attn": A.gqa_defs(cfg),
            "ln_x": L.norm_def(cfg.d_model, cfg.norm),
            "xattn": A.cross_attn_defs(cfg),
            "ln2": L.norm_def(cfg.d_model, cfg.norm),
            "mlp": mlp_defs(cfg)}


def cross_decoder_block_fwd(p, x, memory, cfg, *, cost_mode=False):
    h = x + A.gqa_forward(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm),
                          cfg, cost_mode=cost_mode)
    h = h + A.cross_attn_forward(p["xattn"],
                                 L.apply_norm(p["ln_x"], h, cfg.norm),
                                 memory, cfg, cost_mode=cost_mode)
    return h + mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg.norm), cfg)


def cross_decoder_block_prefill(p, x, memory, cfg, *, cost_mode=False):
    a, cache = A.gqa_prefill(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm),
                             cfg, cost_mode=cost_mode)
    h = x + a
    h = h + A.cross_attn_forward(p["xattn"],
                                 L.apply_norm(p["ln_x"], h, cfg.norm),
                                 memory, cfg, cost_mode=cost_mode)
    return (h + mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg.norm),
                            cfg), cache)


def cross_decoder_block_decode(p, x, cross_ck, cross_cv, cache, pos, cfg):
    """Decode with *precomputed* cross K/V (avoids re-projecting memory)."""
    a, cache = A.gqa_decode(p["attn"], L.apply_norm(p["ln1"], x, cfg.norm),
                            cache, pos, cfg)
    h = x + a
    h = h + A.cross_attn_cached(p["xattn"],
                                L.apply_norm(p["ln_x"], h, cfg.norm),
                                cross_ck, cross_cv, cfg)
    return (h + mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg.norm),
                            cfg), cache)


# ----------------------------------------------------------------------------
# Llama-vision cross block (gated cross-attention)
# ----------------------------------------------------------------------------

def vlm_cross_block_defs(cfg: ModelConfig):
    return {"ln1": L.norm_def(cfg.d_model, cfg.norm),
            "xattn": A.cross_attn_defs(cfg),
            "attn_gate": L.ParamDef((1,), "zeros", (None,), jnp.float32),
            "ln2": L.norm_def(cfg.d_model, cfg.norm),
            "mlp": mlp_defs(cfg),
            "mlp_gate": L.ParamDef((1,), "zeros", (None,), jnp.float32)}


def vlm_cross_block_fwd(p, x, patches, cfg, *, cost_mode=False):
    a = A.cross_attn_forward(p["xattn"], L.apply_norm(p["ln1"], x, cfg.norm),
                             patches, cfg, cost_mode=cost_mode)
    h = x + jnp.tanh(p["attn_gate"]).astype(x.dtype) * a
    m = mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg.norm), cfg)
    return h + jnp.tanh(p["mlp_gate"]).astype(x.dtype) * m


def vlm_cross_block_cached(p, x, ck, cv, cfg):
    a = A.cross_attn_cached(p["xattn"], L.apply_norm(p["ln1"], x, cfg.norm),
                            ck, cv, cfg)
    h = x + jnp.tanh(p["attn_gate"]).astype(x.dtype) * a
    m = mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg.norm), cfg)
    return h + jnp.tanh(p["mlp_gate"]).astype(x.dtype) * m
