"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Both Mamba2's SSD and the mLSTM matrix memory are instances of the same
*gated linear recurrence*::

    C_t = a_t · C_{t-1} + b_t · k_t v_tᵀ          (state  (dk, dv))
    y_t = q_t · C_t      [ / max(|q_t · n_t|, floor) for mLSTM ]

so we implement one chunked (intra-chunk parallel, inter-chunk scanned)
routine ``chunked_linear_recurrence`` in log-decay space and instantiate it
for both. Decode is the O(1)-state single-step update — this is what makes
the ``long_500k`` cell tractable for these families (DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


# ----------------------------------------------------------------------------
# Generic chunked gated linear recurrence
# ----------------------------------------------------------------------------

def chunked_linear_recurrence(q, k, v, log_a, b, *, chunk: int,
                              init_state=None, normalize=False,
                              den_floor=None):
    """q,k: (B,S,H,dk); v: (B,S,H,dv); log_a,b: (B,S,H).

    Returns (y (B,S,H,dv), final_state (B,H,dk,dv), final_norm (B,H,dk)).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    Lc = min(chunk, S)
    assert S % Lc == 0, (S, Lc)
    nc = S // Lc

    f32 = jnp.float32
    qc = q.astype(f32).reshape(B, nc, Lc, H, dk)
    kc = k.astype(f32).reshape(B, nc, Lc, H, dk)
    vc = v.astype(f32).reshape(B, nc, Lc, H, dv)
    lac = log_a.astype(f32).reshape(B, nc, Lc, H)
    bc = b.astype(f32).reshape(B, nc, Lc, H)
    La = jnp.cumsum(lac, axis=2)                       # inclusive cumsum

    if init_state is None:
        C0 = jnp.zeros((B, H, dk, dv), f32)
        n0 = jnp.zeros((B, H, dk), f32)
    else:
        C0, n0 = init_state

    tri = jnp.tril(jnp.ones((Lc, Lc), f32))            # s <= t

    def chunk_step(carry, inp):
        C, n = carry
        qb, kb, vb, Lab, bb = inp                      # (B,Lc,H,*)
        # intra-chunk: S[t,s] = exp(La_t - La_s) * b_s * (q_t . k_s)
        qk = jnp.einsum("bthd,bshd->bhts", qb, kb)
        # mask BEFORE exp: for t < s the exponent is positive and overflows
        ldiff = Lab[:, :, None, :] - Lab[:, None, :, :]           # (B,t,s,H)
        ldiff = jnp.where(tri[None, :, :, None] > 0, ldiff, -jnp.inf)
        decay = jnp.exp(ldiff).transpose(0, 3, 1, 2)
        scores = qk * decay * bb.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhts,bshd->bthd", scores, vb)
        den_intra = jnp.sum(scores, axis=-1)           # (B,H,t)
        # inter-chunk: state contribution
        Aq = jnp.exp(Lab)                              # (B,Lc,H)
        y_inter = jnp.einsum("bthd,bhde->bthe", qb, C) * Aq[..., None]
        den_inter = jnp.einsum("bthd,bhd->bth", qb, n) * Aq    # (B,Lc,H)
        # carry update
        tail = jnp.exp(Lab[:, -1:, :] - Lab) * bb      # (B,Lc,H)
        kw = kb * tail[..., None]
        chunk_decay = jnp.exp(Lab[:, -1])              # (B,H)
        C_new = C * chunk_decay[..., None, None] \
            + jnp.einsum("bshd,bshe->bhde", kw, vb)
        n_new = n * jnp.exp(Lab[:, -1]).reshape(B, H, 1) + jnp.sum(kw, axis=1)
        y = y_intra + y_inter
        den = den_intra.transpose(0, 2, 1) + den_inter  # (B,Lc,H)
        return (C_new, n_new), (y, den)

    (Cf, nf), (ys, dens) = jax.lax.scan(
        chunk_step, (C0, n0),
        (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
         La.swapaxes(0, 1), bc.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B, S, H, dv)
    den = dens.swapaxes(0, 1).reshape(B, S, H)
    if normalize:
        floor = den_floor if den_floor is not None else 1e-6
        y = y / jnp.maximum(jnp.abs(den), floor)[..., None]
    return y, (Cf, nf)


def linear_recurrence_step(q, k, v, a, b, state, *, normalize=False,
                           den_floor=None):
    """Single decode step. q,k: (B,H,dk); v: (B,H,dv); a,b: (B,H)."""
    C, n = state
    C = C * a[..., None, None] + b[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = n * a[..., None] + b[..., None] * k
    y = jnp.einsum("bhd,bhde->bhe", q, C)
    if normalize:
        den = jnp.einsum("bhd,bhd->bh", q, n)
        floor = den_floor if den_floor is not None else 1e-6
        y = y / jnp.maximum(jnp.abs(den), floor)[..., None]
    return y, (C, n)


# ----------------------------------------------------------------------------
# Causal depthwise conv1d (mamba2 / mLSTM front conv)
# ----------------------------------------------------------------------------

def causal_conv1d(w, x, *, cache=None):
    """w: (K, C) depthwise; x: (B,S,C). cache: (B,K-1,C) trailing context."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(K))
    new_cache = xp[:, -(K - 1):, :] if K > 1 else pad
    return y, new_cache


# ----------------------------------------------------------------------------
# Mamba2 block
# ----------------------------------------------------------------------------

class Mamba2State(NamedTuple):
    ssm: Tuple[jax.Array, jax.Array]   # C (B,H,N,P), n (unused placeholder)
    conv: jax.Array                    # (B, K-1, conv_channels)


def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = s.num_ssm_heads
    P = d_inner // H
    N = s.state_dim
    conv_ch = d_inner + 2 * N          # conv over [x, B, C], one group
    return d_inner, H, P, N, conv_ch


def mamba2_defs(cfg: ModelConfig) -> Dict[str, object]:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, P, N, conv_ch = mamba2_dims(cfg)
    return {
        "in_proj": L.dense_def(d, 2 * d_inner + 2 * N + H, ("embed", "ffn")),
        "conv_w": L.ParamDef((s.conv_dim, conv_ch), "scaled", (None, "ffn")),
        "A_log": L.ParamDef((H,), "zeros", (None,), jnp.float32),
        "D": L.ParamDef((H,), "ones", (None,), jnp.float32),
        "dt_bias": L.ParamDef((H,), "zeros", (None,), jnp.float32),
        "out_norm": L.norm_def(d_inner, "rmsnorm"),
        "out_proj": L.dense_def(d_inner, d, ("ffn", "embed")),
    }


def _mamba2_inner(p, x, cfg: ModelConfig, conv_cache=None):
    d_inner, H, P, N, conv_ch = mamba2_dims(cfg)
    B, S, _ = x.shape
    zxbcdt = L.dense(p["in_proj"], x)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)
    xbc, new_conv = causal_conv1d(p["conv_w"], jax.nn.silu(xbc),
                                  cache=conv_cache)
    xs, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])        # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,) < 0
    log_a = dt * A[None, None, :]
    xh = xs.reshape(B, S, H, P)
    kq_k = jnp.broadcast_to(Bmat[:, :, None, :], (B, S, H, N))
    kq_q = jnp.broadcast_to(Cmat[:, :, None, :], (B, S, H, N))
    return z, xh, kq_q, kq_k, log_a, dt, new_conv


def mamba2_forward(p, x, cfg: ModelConfig):
    z, xh, q, k, log_a, dt, _ = _mamba2_inner(p, x, cfg)
    y, _ = chunked_linear_recurrence(
        q, k, xh, log_a, dt, chunk=cfg.ssm.chunk_size, normalize=False)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    B, S = x.shape[:2]
    y = y.reshape(B, S, -1).astype(x.dtype)
    y = L.apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(z)
    return L.dense(p["out_proj"], y)


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, H, P, N, conv_ch = mamba2_dims(cfg)
    return Mamba2State(
        ssm=(jnp.zeros((batch, H, N, P), dtype),
             jnp.zeros((batch, H, N), dtype)),
        conv=jnp.zeros((batch, cfg.ssm.conv_dim - 1, conv_ch), dtype))


def mamba2_decode(p, x, state: Mamba2State, cfg: ModelConfig):
    """x: (B,1,d) -> (y (B,1,d), new state). O(1) per step."""
    z, xh, q, k, log_a, dt, new_conv = _mamba2_inner(
        p, x, cfg, conv_cache=state.conv)
    a = jnp.exp(log_a[:, 0])                                   # (B,H)
    y, ssm = linear_recurrence_step(
        q[:, 0], k[:, 0], xh[:, 0].astype(jnp.float32),
        a, dt[:, 0], state.ssm, normalize=False)
    y = y + xh[:, 0].astype(jnp.float32) * p["D"][None, :, None]
    B = x.shape[0]
    y = y.reshape(B, 1, -1).astype(x.dtype)
    y = L.apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(z)
    return L.dense(p["out_proj"], y), Mamba2State(ssm=ssm, conv=new_conv)


# ----------------------------------------------------------------------------
# xLSTM: mLSTM block (matrix memory) and sLSTM block (scalar memory)
# ----------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: jax.Array      # (B,H,dk,dv)
    n: jax.Array      # (B,H,dk)
    m: jax.Array      # (B,H)
    conv: jax.Array   # (B,K-1,di)


def mlstm_dims(cfg: ModelConfig):
    di = cfg.ssm.expand * cfg.d_model
    H = cfg.ssm.num_ssm_heads
    dh = di // H
    return di, H, dh


def mlstm_defs(cfg: ModelConfig) -> Dict[str, object]:
    d = cfg.d_model
    di, H, dh = mlstm_dims(cfg)
    return {
        "w_up": L.dense_def(d, 2 * di, ("embed", "ffn")),
        "conv_w": L.ParamDef((4, di), "scaled", (None, "ffn")),
        # block-diagonal per-head q/k/v (official xLSTM structure)
        "wq": L.ParamDef((H, dh, dh), "scaled", (None, None, None)),
        "wk": L.ParamDef((H, dh, dh), "scaled", (None, None, None)),
        "wv": L.ParamDef((H, dh, dh), "scaled", (None, None, None)),
        "w_igate": L.dense_def(di, H, ("ffn", None), bias=True),
        "w_fgate": L.dense_def(di, H, ("ffn", None), bias=True),
        "out_norm": L.norm_def(di, "rmsnorm"),
        "w_down": L.dense_def(di, d, ("ffn", "embed")),
    }


def _blockdiag(w, x, H, dh):
    """x: (..., H*dh) -> per-head (..., H, dh) @ w (H, dh, dh)."""
    xh = x.reshape(x.shape[:-1] + (H, dh))
    return jnp.einsum("...hd,hde->...he", xh, w.astype(x.dtype))


def _stabilizer_scan(f_log, i_log, m0):
    """m_t = max(m_{t-1} + f_log_t, i_log_t) via associative scan.

    Represent each element as affine max-plus pair (A, Bv):
    m_t = max(m_{t-1} + A, Bv); composition is associative.
    """
    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax + ay, jnp.maximum(bx + ay, by)
    A, Bv = jax.lax.associative_scan(combine, (f_log, i_log), axis=1)
    return jnp.maximum(m0[:, None] + A, Bv)           # (B,S,H)


def _mlstm_gates(p, xi, m0):
    """xi: (B,S,di). Returns (log_a, b, m, den_floor)."""
    f_log = jax.nn.log_sigmoid(
        L.dense(p["w_fgate"], xi).astype(jnp.float32))         # (B,S,H)
    i_log = L.dense(p["w_igate"], xi).astype(jnp.float32)
    m = _stabilizer_scan(f_log, i_log, m0)
    m_prev = jnp.concatenate([m0[:, None], m[:, :-1]], axis=1)
    log_a = f_log + m_prev - m
    b = jnp.exp(i_log - m)
    den_floor = jnp.exp(-m)
    return log_a, b, m, den_floor


def mlstm_forward(p, x, cfg: ModelConfig):
    di, H, dh = mlstm_dims(cfg)
    B, S, _ = x.shape
    up = L.dense(p["w_up"], x)
    xi, z = jnp.split(up, 2, axis=-1)
    xc, _ = causal_conv1d(p["conv_w"], xi)
    xc = jax.nn.silu(xc)
    q = _blockdiag(p["wq"], xc, H, dh) / math.sqrt(dh)
    k = _blockdiag(p["wk"], xc, H, dh)
    v = _blockdiag(p["wv"], xi, H, dh)
    m0 = jnp.zeros((B, H), jnp.float32)
    log_a, b, m, den_floor = _mlstm_gates(p, xi, m0)
    y, _ = chunked_linear_recurrence(
        q, k, v, log_a, b, chunk=cfg.ssm.chunk_size,
        normalize=True, den_floor=den_floor)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = L.apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(z)
    return L.dense(p["w_down"], y)


def mlstm_init_state(cfg: ModelConfig, batch: int):
    di, H, dh = mlstm_dims(cfg)
    return MLSTMState(
        C=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
        m=jnp.zeros((batch, H), jnp.float32),
        conv=jnp.zeros((batch, 3, di), jnp.float32))


def mlstm_decode(p, x, state: MLSTMState, cfg: ModelConfig):
    di, H, dh = mlstm_dims(cfg)
    B = x.shape[0]
    up = L.dense(p["w_up"], x)
    xi, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = causal_conv1d(p["conv_w"], xi, cache=state.conv)
    xc = jax.nn.silu(xc)
    q = _blockdiag(p["wq"], xc, H, dh)[:, 0] / math.sqrt(dh)
    k = _blockdiag(p["wk"], xc, H, dh)[:, 0]
    v = _blockdiag(p["wv"], xi, H, dh)[:, 0]
    f_log = jax.nn.log_sigmoid(
        L.dense(p["w_fgate"], xi)[:, 0].astype(jnp.float32))   # (B,H)
    i_log = L.dense(p["w_igate"], xi)[:, 0].astype(jnp.float32)
    m = jnp.maximum(state.m + f_log, i_log)
    a = jnp.exp(f_log + state.m - m)
    b = jnp.exp(i_log - m)
    y, (C, n) = linear_recurrence_step(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        a, b, (state.C, state.n), normalize=True, den_floor=jnp.exp(-m))
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = L.apply_norm(p["out_norm"], y, "rmsnorm") * jax.nn.silu(z)
    return L.dense(p["w_down"], y), MLSTMState(C=C, n=n, m=m, conv=new_conv)


class SLSTMState(NamedTuple):
    c: jax.Array      # (B,H,dh)
    n: jax.Array
    h: jax.Array
    m: jax.Array      # (B,H)


def slstm_dims(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.ssm.num_ssm_heads
    dh = d // H
    d_up = int(d * cfg.ssm.slstm_proj_factor)
    d_up = (d_up // 8) * 8 or 8
    return d, H, dh, d_up


def slstm_defs(cfg: ModelConfig) -> Dict[str, object]:
    d, H, dh, d_up = slstm_dims(cfg)
    return {
        "w_gates": L.dense_def(d, 4 * d, ("embed", "ffn"), bias=True),
        "r_gates": L.ParamDef((4, H, dh, dh), "scaled",
                              (None, None, None, None)),
        "out_norm": L.norm_def(d, "rmsnorm"),
        "w_up": L.dense_def(d, d_up, ("embed", "ffn")),
        "w_down": L.dense_def(d_up, d, ("ffn", "embed")),
    }


def _slstm_step(p_r, gates_x, state: SLSTMState):
    """gates_x: (B, 4, H, dh) precomputed input contributions."""
    rec = jnp.einsum("bhd,ghde->bghe", state.h, p_r.astype(jnp.float32))
    g = gates_x.astype(jnp.float32) + rec                     # (B,4,H,dh)
    zt = jnp.tanh(g[:, 0])
    it = jnp.mean(g[:, 1], axis=-1)                           # scalar/head
    ft = jnp.mean(g[:, 2], axis=-1)
    ot = jax.nn.sigmoid(g[:, 3])
    f_log = jax.nn.log_sigmoid(ft)
    m = jnp.maximum(f_log + state.m, it)
    ip = jnp.exp(it - m)
    fp = jnp.exp(f_log + state.m - m)
    c = fp[..., None] * state.c + ip[..., None] * zt
    n = fp[..., None] * state.n + ip[..., None]
    h = ot * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, h=h, m=m)


def slstm_forward(p, x, cfg: ModelConfig, state: Optional[SLSTMState] = None):
    d, H, dh, d_up = slstm_dims(cfg)
    B, S, _ = x.shape
    gates = L.dense(p["w_gates"], x).reshape(B, S, 4, H, dh)
    if state is None:
        state = SLSTMState(*(jnp.zeros((B, H, dh), jnp.float32)
                             for _ in range(3)),
                           m=jnp.zeros((B, H), jnp.float32))

    def step(st, gx):
        st = _slstm_step(p["r_gates"], gx, st)
        return st, st.h

    state, hs = jax.lax.scan(step, state, gates.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    y = L.apply_norm(p["out_norm"], y, "rmsnorm")
    y = L.dense(p["w_down"], jax.nn.gelu(L.dense(p["w_up"], y)))
    return y, state


def slstm_init_state(cfg: ModelConfig, batch: int):
    d, H, dh, _ = slstm_dims(cfg)
    return SLSTMState(*(jnp.zeros((batch, H, dh), jnp.float32)
                        for _ in range(3)),
                      m=jnp.zeros((batch, H), jnp.float32))
