"""Pallas TPU kernel: Freivalds fold ``(Y @ S) mod p`` over int8 limb planes.

The integrity check (core/integrity.py, DESIGN.md §9) folds a (M, K) field
matrix against a skinny (K, k) fold matrix, k ∈ {1, 2}. Reuses the limb
representation and nine-matmul step of limb_matmul.py, but with the fold
columns lane-padded to one 128-wide block held resident in VMEM — the grid
is (M/bm, K/bk) with no n dimension, so a fold costs one pass over Y
instead of a full matmul grid.

VMEM per step (bm=256, bk=1024): 3×256×1024 int8 Y block (0.75 MiB) +
3×1024×128 int8 fold block (0.375 MiB) + 256×128 int32 out (128 KiB).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.limb_matmul.limb_matmul import _step_partial
from repro.kernels.limb_matmul.ref import P

FOLD_LANES = 128


def _kernel(x_ref, s_ref, o_ref):
    """x_ref: (3, bm, bk) int8; s_ref: (3, bk, 128) int8; o_ref: (bm, 128)."""
    k_idx = pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = _step_partial(x_ref, s_ref, o_ref[...])
    o_ref[...] = jnp.mod(o_ref[...] + acc, P)


def limb_fold_planes(x_limbs, s_limbs, *, bm=256, bk=1024, interpret=False):
    """x_limbs: (3, M, K) int8; s_limbs: (3, K, 128) int8 (fold columns
    zero-padded to one lane block) -> (M, 128) int32 in [0, p).

    M and K must be multiples of the block sizes (ops.py pads).
    """
    _, M, K = x_limbs.shape
    _, _, nf = s_limbs.shape
    assert nf == FOLD_LANES, s_limbs.shape
    bm, bk = min(bm, M), min(bk, K)
    assert M % bm == 0 and K % bk == 0, (M, K, bm, bk)
    assert bk <= 43000, bk           # same int32 exactness bound as matmul
    grid = (M // bm, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, bm, bk), lambda m, k: (0, m, k)),
            pl.BlockSpec((3, bk, FOLD_LANES), lambda m, k: (0, k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, FOLD_LANES), lambda m, k: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((M, FOLD_LANES), jnp.int32),
        interpret=interpret,
    )(x_limbs, s_limbs)
