"""Pure-jnp oracle for the blinded modular matmul (Z_p, p = 2^23 - 15).

This is the TPU-native adaptation of Slalom's field arithmetic (DESIGN.md
§3): Slalom relies on fp64-exact float tricks on GPU; TPUs have neither fp64
nor wide integer MXU paths, but they *do* have an exact int8×int8→int32
matmul. We therefore represent signed-canonical field elements
(s ∈ [-(p−1)/2, (p−1)/2]) in **balanced base-256**: three int8 digits
l0 + 256·l1 + 256²·l2 with l_i ∈ [−128, 127] (covers ±8,355,711 ⊃
±HALF = ±4,194,296). A field matmul is then nine int8 MXU matmuls
P_ij = X_i · W_j plus a recombination y = Σ_{i,j} P_ij · 256^{i+j} (mod p),
all in int32:

- exactness: |P_ij| ≤ K·128² ⇒ exact for K ≤ 2^17 (asserted);
- since p < 2^23, y·256 < 2^31 for y ∈ [0, p), so the power-of-256
  multiplies reduce byte-by-byte without overflow.

(Slalom's field was 2^24-scale; we give up one bit of quantization headroom
for an int8-exact limb representation — recorded in DESIGN.md §3.)

Everything here is plain jnp — it runs on CPU exactly and serves as the
allclose oracle for the Pallas kernel in limb_matmul.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

P = (1 << 23) - 15           # 8388593, prime
HALF = (P - 1) // 2          # signed-canonical bound
MAX_K = 1 << 17              # int32 accumulation exactness bound


def to_signed(v):
    """Field element [0, p) -> signed canonical [-(p-1)/2, (p-1)/2]."""
    v = jnp.asarray(v, jnp.int32)
    return jnp.where(v > HALF, v - P, v)


def from_signed(s):
    """Signed canonical -> [0, p)."""
    s = jnp.asarray(s, jnp.int32)
    return jnp.mod(s, P)


def to_limbs(s):
    """Signed canonical int32 -> three balanced base-256 int8 digits.

    Returns (..., 3) int8. digit_i ∈ [-128, 127]. Digit extraction uses
    bitwise ops instead of mod/div: ``(v & 255)`` equals ``v mod 256`` on
    two's-complement int32, and ``(s - l0) >> 8`` is exact division because
    ``s - l0`` is a multiple of 256 (arithmetic shift floors, remainder is
    zero). Same outputs, an order of magnitude cheaper on CPU where integer
    division dominates the limb-encode cost.
    """
    s = jnp.asarray(s, jnp.int32)
    l0 = ((s + 128) & 255) - 128
    s1 = (s - l0) >> 8
    l1 = ((s1 + 128) & 255) - 128
    s2 = (s1 - l1) >> 8
    return jnp.stack([l0, l1, s2], axis=-1).astype(jnp.int8)


def from_limbs(l):
    """(..., 3) int8 -> signed canonical int32 (for testing round-trips)."""
    l = l.astype(jnp.int32)
    return l[..., 0] + 256 * l[..., 1] + 65536 * l[..., 2]


def mod_mul_pow256(y, k: int):
    """(y * 256**k) mod p without int32 overflow. y ∈ [0, p) < 2^23."""
    y = jnp.asarray(y, jnp.int32)
    for _ in range(k):
        y = jnp.mod(y * 256, P)      # y*256 < 2^31: no overflow
    return y


# Limb products are exact in float32 iff every partial sum of the dot stays
# within the 2^24 integer-exact mantissa range: |digit| ≤ 128 so any partial
# sum over K terms is ≤ K·128² = K·2^14, hence K ≤ 2^10 keeps every
# accumulation order (blocked, FMA, vectorized) rounding-free. Inside that
# bound the f32 GEMM result, cast back to int32, is bit-identical to the
# int8→int32 dot — but runs on the CPU BLAS fast path instead of XLA's slow
# integer-matmul lowering.
MAX_K_F32 = 1 << 10


def field_matmul_ref(x_field, w_field):
    """Exact (X @ W) mod p for field-element matrices in [0, p).

    x_field: (M, K) int32; w_field: (K, N) int32. K must be ≤ 2^17.
    The limb products run as float32 GEMMs when K ≤ 2^10 (exact — see
    ``MAX_K_F32``), else as int8→int32 dots; both yield the same integers,
    so the output is bit-identical either way.
    """
    K = x_field.shape[-1]
    assert K <= MAX_K, f"K={K} exceeds int32 exactness bound {MAX_K}"
    xl = to_limbs(to_signed(x_field))            # (M, K, 3)
    wl = to_limbs(to_signed(w_field))            # (K, N, 3)
    f32_exact = K <= MAX_K_F32
    if f32_exact:
        xl = xl.astype(jnp.float32)
        wl = wl.astype(jnp.float32)
    acc = jnp.zeros(x_field.shape[:-1] + (w_field.shape[-1],), jnp.int32)
    for i in range(3):
        for j in range(3):
            pij = jax.lax.dot_general(
                xl[..., i], wl[..., j],
                dimension_numbers=(((xl.ndim - 2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32 if f32_exact
                else jnp.int32)
            pij = pij.astype(jnp.int32)
            acc = jnp.mod(acc + mod_mul_pow256(jnp.mod(pij, P), i + j), P)
    return acc


def field_add(a, b):
    return jnp.mod(jnp.asarray(a, jnp.int32) + jnp.asarray(b, jnp.int32), P)


def field_sub(a, b):
    return jnp.mod(jnp.asarray(a, jnp.int32) - jnp.asarray(b, jnp.int32), P)
