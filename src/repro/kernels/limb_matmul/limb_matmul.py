"""Pallas TPU kernel: blinded modular matmul over Z_p via int8 limb planes.

Grid: (M/bm, N/bn, K/bk), k innermost. Each step performs the nine
int8×int8→int32 MXU matmuls between limb planes, groups partials by limb
power s = i+j, reduces mod p, recombines with the overflow-free
shift-and-reduce (2^24 ≡ 3 mod p) and accumulates into the output block.

Two epilogues (DESIGN.md §6):

- ``limb_matmul_planes``       plain field result (M, N) int32 in [0, p);
- ``limb_matmul_planes_fused`` on the final k step the output block is
  unblinded in-register (subtract the precomputed factor ``u``), mapped to
  signed canonical and dequantized to float — the device→enclave tensor
  never round-trips HBM as a field element.

VMEM per step (bm=bn=256, bk=1024): 2 × 3×256×1024 int8 (1.5 MiB) limb
blocks + 256×256 int32 out block (256 KiB); the fused epilogue adds an
int32 ``u`` block and a float32 out block (512 KiB) — comfortably inside
16 MiB VMEM with double buffering. MXU dims are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.limb_matmul.ref import HALF, P

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 1024


def _mod_mul_pow256(y, k: int):
    for _ in range(k):
        y = jnp.mod(y * 256, P)      # p < 2^23 so y*256 < 2^31
    return y


def _step_partial(x_ref, w_ref, o_like):
    """One k-step of the nine-matmul limb product, reduced mod p."""
    # group the nine partial products by limb power s = i + j
    sums = [None] * 5
    for i in range(3):
        xi = x_ref[i]
        for j in range(3):
            pij = jax.lax.dot_general(
                xi, w_ref[j],
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            s = i + j
            sums[s] = pij if sums[s] is None else sums[s] + pij
    acc = jnp.zeros_like(o_like)
    for s in range(5):
        acc = acc + _mod_mul_pow256(jnp.mod(sums[s], P), s)
    return acc


def _kernel(x_ref, w_ref, o_ref):
    """x_ref: (3, bm, bk) int8; w_ref: (3, bk, bn) int8; o_ref: (bm, bn)."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = _step_partial(x_ref, w_ref, o_ref[...])
    o_ref[...] = jnp.mod(o_ref[...] + acc, P)


def _kernel_fused(x_ref, w_ref, u_ref, scale_ref, y_ref, acc_ref, *,
                  nk: int, out_dtype):
    """Fused epilogue: on the last k step, unblind + dequantize in-register.

    u_ref: (bm, bn) int32 precomputed unblinding factors; scale_ref: (1, 1)
    float32 combined dequantization scale x_scale·w_scale·2^-(k_act+k_w).
    acc_ref is a VMEM scratch block carrying the running field accumulator
    across the (sequential) k steps — the field result never touches HBM;
    y_ref is the float output.
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc = _step_partial(x_ref, w_ref, acc_ref[...])
    acc_ref[...] = jnp.mod(acc_ref[...] + acc, P)

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        d = jnp.mod(acc_ref[...] - u_ref[...] + P, P)
        s = jnp.where(d > HALF, d - P, d)       # [0,p) -> signed canonical
        y_ref[...] = (s.astype(jnp.float32)
                      * scale_ref[0, 0]).astype(out_dtype)


def _check_blocks(M, N, K, bm, bn, bk):
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    # int32 accumulation exactness: per-step partials are ≤ 3·bk·128² and the
    # running block is < p, so bk is bounded by (2^31 − p)/(3·128²).
    assert bk <= 43000, bk
    return bm, bn, bk


def limb_matmul_planes(x_limbs, w_limbs, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                       bk=DEFAULT_BK, interpret=False):
    """x_limbs: (3, M, K) int8; w_limbs: (3, K, N) int8 -> (M, N) int32 mod p.

    M, N, K must be multiples of the block sizes (ops.py pads).
    """
    _, M, K = x_limbs.shape
    _, _, N = w_limbs.shape
    bm, bn, bk = _check_blocks(M, N, K, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, bm, bk), lambda m, n, k: (0, m, k)),
            pl.BlockSpec((3, bk, bn), lambda m, n, k: (0, k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(x_limbs, w_limbs)


def limb_matmul_planes_fused(x_limbs, w_limbs, u, scale, *,
                             bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                             out_dtype=jnp.float32, interpret=False):
    """Field matmul with fused unblind+dequantize epilogue.

    x_limbs: (3, M, K) int8; w_limbs: (3, K, N) int8; u: (M, N) int32
    precomputed unblinding factors; scale: (1, 1) float32 combined scale.
    Returns (M, N) ``out_dtype`` — already unblinded and dequantized.
    """
    _, M, K = x_limbs.shape
    _, _, N = w_limbs.shape
    assert u.shape == (M, N), (u.shape, M, N)
    bm, bn, bk = _check_blocks(M, N, K, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel_fused, nk=grid[2], out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, bm, bk), lambda m, n, k: (0, m, k)),
            pl.BlockSpec((3, bk, bn), lambda m, n, k: (0, k, n)),
            pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
            pl.BlockSpec((1, 1), lambda m, n, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_limbs, w_limbs, u, scale)
