"""Jitted public wrapper for the blinded modular matmul.

``field_matmul(x, w)`` takes field matrices in [0, p) (int32), handles limb
decomposition, padding to kernel block multiples, and backend selection:
Pallas-compiled on TPU, Pallas ``interpret=True`` elsewhere (bit-exact, used
by CPU tests), or the pure-jnp reference for very small shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.limb_matmul import ref
from repro.kernels.limb_matmul.limb_matmul import limb_matmul_planes

_LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bn", "bk"))
def field_matmul(x_field, w_field, *, impl: str = "auto",
                 bm=256, bn=256, bk=1024):
    """(X @ W) mod p. x: (M, K) int32 in [0, p); w: (K, N) int32 in [0, p)."""
    M, K = x_field.shape
    K2, N = w_field.shape
    assert K == K2
    if impl == "ref" or (impl == "auto" and M * N * K <= 64 ** 3):
        return ref.field_matmul_ref(x_field, w_field)
    xl = jnp.moveaxis(ref.to_limbs(ref.to_signed(x_field)), -1, 0)  # (3,M,K)
    wl = jnp.moveaxis(ref.to_limbs(ref.to_signed(w_field)), -1, 0)  # (3,K,N)
    bm_, bn_, bk_ = min(bm, _LANE * ((M + 127) // 128)), bn, bk
    xl = _pad_to(_pad_to(xl, bm, 1), bk, 2)
    wl = _pad_to(_pad_to(wl, bk, 1), bn, 2)
    out = limb_matmul_planes(
        xl, wl, bm=bm, bn=bn, bk=bk,
        interpret=(impl == "interpret") or (impl == "auto" and not _on_tpu()))
    return out[:M, :N]


def blinded_matmul(x_blinded, w_field, **kw):
    """Alias with protocol-level naming: the untrusted-device operation."""
    return field_matmul(x_blinded, w_field, **kw)
