"""Jitted public wrappers for the blinded modular matmul.

``field_matmul(x, w)`` takes field matrices in [0, p) (int32), handles limb
decomposition, padding to kernel block multiples, and backend selection:
Pallas-compiled on TPU, the pure-jnp reference elsewhere (bit-identical and
far faster than interpreted Pallas on CPU — the serving hot path), with
``impl="interpret"`` keeping the Pallas interpreter reachable for kernel
parity tests.

``fused_blinded_matmul`` is the single-chain fast path (DESIGN.md §6): one
Pallas pass that scales+quantizes+blinds+limb-encodes the activations, one
Pallas matmul whose epilogue unblinds and dequantizes in-register. With the
weight planes pre-encoded (``encode_weight_planes``, cached offline by
core/precompute.py) the blinded operand makes exactly one HBM round trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import tracing
from repro.kernels.blind.blind import blind_encode_pallas
from repro.kernels.limb_matmul import ref
from repro.kernels.limb_matmul.limb_matmul import (limb_matmul_planes,
                                                  limb_matmul_planes_fused)

_LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fit_block(dim: int, target: int) -> int:
    """Largest block ≤ target that exactly tiles the lane-rounded dim.

    Pads only to the 128-lane multiple, never to a block multiple: a dim
    just over the default block (e.g. K=1152 with bk=1024) used to round up
    to 2·bk and nearly double the matmul work; instead shrink the block to
    an exact divisor (1152 -> 384×3)."""
    lanes = _round_up(dim, _LANE) // _LANE
    n = -(-lanes * _LANE // target)          # ceil-div to stay ≤ target
    while lanes % n:
        n += 1
    return lanes // n * _LANE


def block_plan(M: int, K: int, N: int, *, bm=256, bn=256, bk=1024):
    """Exact-fit blocks for the limb matmul grid.

    Returns (bm, bn, bk, Mp, Kp, Np) with each padded dim the 128-lane
    round-up of the operand dim and divisible by its block. The (K, N) half
    of the plan is independent of M, so weight planes encoded offline
    (core/precompute.py) line up with activations encoded per request.
    """
    bm = _fit_block(M, bm)
    bn = _fit_block(N, bn)
    bk = _fit_block(K, bk)
    return (bm, bn, bk,
            _round_up(M, _LANE), _round_up(K, _LANE), _round_up(N, _LANE))


def encode_weight_planes(w_field, *, bn=256, bk=1024):
    """(K, N) int32 field weights -> (3, Kp, Np) int8 limb planes, padded to
    the block plan. Done once per layer by the precompute cache."""
    K, N = w_field.shape
    _, bn_, bk_, _, _, _ = block_plan(1, K, N, bn=bn, bk=bk)
    wl = jnp.moveaxis(ref.to_limbs(ref.to_signed(w_field)), -1, 0)  # (3,K,N)
    return _pad_to(_pad_to(wl, bk_, 1), bn_, 2)


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bn", "bk"))
def _field_matmul_jit(x_field, w_field, *, impl: str = "auto",
                      bm=256, bn=256, bk=1024):
    M, K = x_field.shape
    K2, N = w_field.shape
    assert K == K2
    # auto: off-TPU the pure-jnp reference (f32-exact limb GEMMs for
    # K ≤ 2^10) beats interpreted Pallas by orders of magnitude and is
    # bit-identical — same policy _field_fold_jit has always used.
    if impl == "ref" or (impl == "auto" and
                         (not _on_tpu() or M * N * K <= 64 ** 3)):
        return ref.field_matmul_ref(x_field, w_field)
    bm_, bn_, bk_, _, _, _ = block_plan(M, K, N, bm=bm, bn=bn, bk=bk)
    xl = jnp.moveaxis(ref.to_limbs(ref.to_signed(x_field)), -1, 0)  # (3,M,K)
    wl = jnp.moveaxis(ref.to_limbs(ref.to_signed(w_field)), -1, 0)  # (3,K,N)
    xl = _pad_to(_pad_to(xl, bm_, 1), bk_, 2)
    wl = _pad_to(_pad_to(wl, bk_, 1), bn_, 2)
    out = limb_matmul_planes(
        xl, wl, bm=bm_, bn=bn_, bk=bk_,
        interpret=(impl == "interpret") or (impl == "auto" and not _on_tpu()))
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("k_bits", "k_out_bits", "impl",
                                             "bm", "bn", "bk", "out_dtype"))
def _fused_blinded_matmul_jit(x, r, w_limbs, u, inv_scale, out_scale, *,
                              k_bits: int, k_out_bits: int,
                              impl: str = "auto", bm=256, bn=256, bk=1024,
                              out_dtype=jnp.float32):
    """Blind -> limb-encode -> field matmul -> unblind -> dequantize, fused.

    x: (M, K) float activations (unscaled); r: (M, K) int32 blinding stream;
    w_limbs: (3, Kp, Np) int8 pre-encoded weight planes
    (``encode_weight_planes``); u: (M, N) int32 precomputed unblinding
    factors (r @ W_q mod p over the *unpadded* dims); inv_scale: scalar f32
    reciprocal of the activation scale; out_scale: scalar f32 combined
    dequantization scale x_scale·w_scale·2^-k_out_bits.

    Returns (M, N) ``out_dtype``: dequant(unblind(blind(x/s) @ W)) · scale.
    Bit-identical across ref / interpret / compiled backends.
    """
    M, K = x.shape
    N = u.shape[1]
    bm_, bn_, bk_, Mp, Kp, Np = block_plan(M, K, N, bm=bm, bn=bn, bk=bk)
    assert w_limbs.shape == (3, Kp, Np), (w_limbs.shape, (3, Kp, Np))
    inv2 = jnp.asarray(inv_scale, jnp.float32).reshape(1, 1)
    sc2 = jnp.asarray(out_scale, jnp.float32).reshape(1, 1)
    if impl == "ref" or (impl == "auto" and
                         (not _on_tpu() or M * N * K <= 64 ** 3)):
        # pure-jnp fallback, same op order as the kernels (bit-exact);
        # selected off-TPU like _field_matmul_jit / _field_fold_jit
        # because interpreted Pallas pays per-element python dispatch
        from repro.kernels.blind.ref import blind_ref
        xs = x.astype(jnp.float32) * inv2[0, 0]
        w_f = ref.from_signed(
            ref.from_limbs(jnp.moveaxis(w_limbs[:, :K, :N], 0, -1)))
        y_b = ref.field_matmul_ref(blind_ref(xs, r, k_bits), w_f)
        s = ref.to_signed(ref.field_sub(y_b, u))
        return (s.astype(jnp.float32) * sc2[0, 0]).astype(out_dtype)
    interpret = (impl == "interpret") or (impl == "auto" and not _on_tpu())
    if interpret and Kp > K:
        # interpret mode pays per-element python dispatch, so K-padding is
        # real work (compiled TPU lanes make it free): encode at natural K,
        # then pad the planes — bit-identical (zero x + zero r -> zero limbs)
        xl = blind_encode_pallas(_pad_to(x, bm_, 0), _pad_to(r, bm_, 0),
                                 inv2, k_bits, bm=bm_, bk=K, interpret=True)
        xl = _pad_to(xl, bk_, 2)
    else:
        xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
        rp = _pad_to(_pad_to(r, bm_, 0), bk_, 1)
        xl = blind_encode_pallas(xp, rp, inv2, k_bits, bm=bm_, bk=bk_,
                                 interpret=interpret)
    up = _pad_to(_pad_to(u, bm_, 0), bn_, 1)
    y = limb_matmul_planes_fused(xl, w_limbs, up, sc2, bm=bm_, bn=bn_,
                                 bk=bk_, out_dtype=out_dtype,
                                 interpret=interpret)
    return y[:M, :N]


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bk"))
def _field_fold_jit(x_field, s_field, *, impl: str = "auto", bm=256, bk=1024):
    """Freivalds fold ``(X @ S) mod p`` for a skinny fold matrix.

    x_field: (M, K) int32 in [0, p); s_field: (K, k) int32 in [0, p) with
    k ≤ 128 (the integrity layer uses k ∈ {1, 2}). Enclave-side cost of
    verifying a device matmul: one pass over X instead of a matmul grid
    (kernels/limb_matmul/fold.py); off-TPU the pure-jnp reference is both
    exact and faster than interpreted Pallas for these shapes.
    """
    from repro.kernels.limb_matmul.fold import FOLD_LANES, limb_fold_planes
    M, K = x_field.shape
    K2, kf = s_field.shape
    assert K == K2 and kf <= FOLD_LANES, (x_field.shape, s_field.shape)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.field_matmul_ref(x_field, s_field)
    bm_, _, bk_, _, _, _ = block_plan(M, K, FOLD_LANES, bm=bm, bk=bk)
    xl = jnp.moveaxis(ref.to_limbs(ref.to_signed(x_field)), -1, 0)  # (3,M,K)
    sl = jnp.moveaxis(ref.to_limbs(ref.to_signed(s_field)), -1, 0)  # (3,K,kf)
    xl = _pad_to(_pad_to(xl, bm_, 1), bk_, 2)
    sl = _pad_to(_pad_to(sl, bk_, 1), FOLD_LANES, 2)
    out = limb_fold_planes(xl, sl, bm=bm_, bk=bk_,
                           interpret=(impl == "interpret"))
    return out[:M, :kf]


def field_matmul(x_field, w_field, **kw):
    """(X @ W) mod p. x: (M, K) int32 in [0, p); w: (K, N) int32 in [0, p).

    Thin profiling wrapper over the jitted kernel: when a tracer with
    kernel spans is ambient (core/tracing.py) and the operands are
    concrete, the call is fenced with ``block_until_ready`` on both sides
    and recorded as a ``kernel.limb_matmul`` span; otherwise it is the
    jitted call, untouched."""
    return tracing.profiled_kernel("kernel.limb_matmul", _field_matmul_jit,
                                   x_field, w_field, **kw)


def fused_blinded_matmul(x, r, w_limbs, u, inv_scale, out_scale, **kw):
    """Profiling wrapper over the fused chain (``kernel.fused_blind_matmul``
    spans cover blind_encode + limb matmul + in-register unblind)."""
    return tracing.profiled_kernel("kernel.fused_blind_matmul",
                                   _fused_blinded_matmul_jit, x, r, w_limbs,
                                   u, inv_scale, out_scale, **kw)


def field_fold(x_field, s_field, **kw):
    """Profiling wrapper over the jitted Freivalds fold (``kernel.fold``)."""
    return tracing.profiled_kernel("kernel.fold", _field_fold_jit,
                                   x_field, s_field, **kw)


def blinded_matmul(x_blinded, w_field, **kw):
    """Alias with protocol-level naming: the untrusted-device operation."""
    return field_matmul(x_blinded, w_field, **kw)
