"""Pure-jnp oracle for the fused blind/unblind elementwise ops.

blind:    y = (round(x · 2^k) mod p + r) mod p          (enclave -> device)
unblind:  x = signed((y − u) mod p) / 2^(k_x + k_w)     (device -> enclave)

``r`` is the one-time-pad stream (uniform over Z_p, enclave-private) and
``u = (r @ W_q) mod p`` the precomputed unblinding factor. These two ops are
the per-layer overhead Slalom pays everywhere and Origami pays only in
tier-1 — the 4 ms / 6 MB constant of paper §VI-C.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.limb_matmul.ref import HALF, P, from_signed, to_signed


def quantize(x, k_bits: int):
    """float -> signed-canonical field int32 with scale 2^k (clipped)."""
    scaled = jnp.round(x.astype(jnp.float32) * (2.0 ** k_bits))
    clipped = jnp.clip(scaled, -HALF, HALF)
    return clipped.astype(jnp.int32)


def dequantize(s, k_bits: int, dtype=jnp.float32):
    return (s.astype(jnp.float32) / (2.0 ** k_bits)).astype(dtype)


def blind_ref(x, r, k_bits: int):
    """x float, r field [0,p) -> blinded field [0,p)."""
    return jnp.mod(from_signed(quantize(x, k_bits)) + r, P)


def unblind_ref(y, u, k_out_bits: int, dtype=jnp.float32):
    """y field, u field -> dequantized float (scale 2^k_out)."""
    return dequantize(to_signed(jnp.mod(y - u + P, P)), k_out_bits, dtype)


def blind_encode_ref(x, r, inv_scale, k_bits: int):
    """Oracle for the fused scale+quantize+blind+limb-encode kernel.

    x: (M, K) float; r: (M, K) int32 field; inv_scale: scalar float32
    reciprocal of the activation scale. Returns (3, M, K) int8 limb planes.
    Uses multiply-by-reciprocal (not division) to stay bit-identical to the
    Pallas kernel.
    """
    from repro.kernels.limb_matmul.ref import to_limbs
    xs = x.astype(jnp.float32) * jnp.asarray(inv_scale, jnp.float32).reshape(())
    b = jnp.mod(from_signed(quantize(xs, k_bits)) + r, P)
    return jnp.moveaxis(to_limbs(to_signed(b)), -1, 0).astype(jnp.int8)
