"""Jitted public wrappers for blind/unblind with backend selection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import tracing
from repro.kernels.blind import ref
from repro.kernels.blind.blind import blind_pallas, unblind_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k_bits", "impl"))
def _blind_jit(x, r, k_bits: int, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu() and x.size < 2 ** 16):
        return ref.blind_ref(x, r, k_bits)
    return blind_pallas(x, r, k_bits,
                        interpret=(impl == "interpret")
                        or (impl == "auto" and not _on_tpu()))


@functools.partial(jax.jit, static_argnames=("k_out_bits", "out_dtype",
                                             "impl"))
def _unblind_jit(y, u, k_out_bits: int, out_dtype=jnp.float32,
                 impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu() and y.size < 2 ** 16):
        return ref.unblind_ref(y, u, k_out_bits, out_dtype)
    return unblind_pallas(y, u, k_out_bits, out_dtype,
                          interpret=(impl == "interpret")
                          or (impl == "auto" and not _on_tpu()))


def blind(x, r, k_bits: int, impl: str = "auto"):
    """Profiling wrapper (``kernel.blind_encode`` spans, core/tracing.py):
    fenced wall-time when a tracer with kernel spans is ambient and the
    operands are concrete; the plain jitted call otherwise."""
    return tracing.profiled_kernel("kernel.blind_encode", _blind_jit,
                                   x, r, k_bits=k_bits, impl=impl)


def unblind(y, u, k_out_bits: int, out_dtype=jnp.float32,
            impl: str = "auto"):
    """Profiling wrapper (``kernel.unblind`` spans)."""
    return tracing.profiled_kernel("kernel.unblind", _unblind_jit,
                                   y, u, k_out_bits=k_out_bits,
                                   out_dtype=out_dtype, impl=impl)
