"""Pallas TPU kernels: fused quantize+blind and unblind+dequantize.

Single VMEM pass per tile (vs. quantize, mod, add as separate HBM-bound
passes): these streams are pure-VPU elementwise work at ~6 bytes/elem of
traffic, so fusing the three stages triples effective blinding throughput —
the direct TPU analogue of the paper's observation that blinding cost is
the Slalom bottleneck.

``blind_encode_pallas`` goes one step further (DESIGN.md §6): it scales,
quantizes, blinds AND emits the three balanced base-256 int8 limb planes in
the same VMEM pass, so the blinded operand leaves the kernel already in the
layout the limb matmul consumes — no intermediate int32 field tensor, no
separate ``to_signed``/``to_limbs``/``moveaxis`` jnp passes over HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.limb_matmul.ref import HALF, P

BLOCK = (256, 512)


def _blind_kernel(x_ref, r_ref, o_ref, *, k_bits: int):
    x = x_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x * (2.0 ** k_bits)), -HALF, HALF).astype(jnp.int32)
    q = jnp.mod(q, P)                       # signed -> [0, p)
    o_ref[...] = jnp.mod(q + r_ref[...], P)


def _unblind_kernel(y_ref, u_ref, o_ref, *, k_out_bits: int, out_dtype):
    d = jnp.mod(y_ref[...] - u_ref[...] + P, P)
    s = jnp.where(d > HALF, d - P, d)       # [0,p) -> signed canonical
    o_ref[...] = (s.astype(jnp.float32)
                  / (2.0 ** k_out_bits)).astype(out_dtype)


def _tiled_call(kernel, out_dtype, x, *others, interpret=False):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    others2 = [o.reshape(x2.shape) for o in others]
    M, N = x2.shape
    bm, bn = min(BLOCK[0], M), min(BLOCK[1], N)
    pm, pn = (-M) % bm, (-N) % bn
    if pm or pn:
        x2 = jnp.pad(x2, ((0, pm), (0, pn)))
        others2 = [jnp.pad(o, ((0, pm), (0, pn))) for o in others2]
    Mp, Np = x2.shape
    out = pl.pallas_call(
        kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))] * (1 + len(others2)),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        interpret=interpret,
    )(x2, *others2)
    return out[:M, :N].reshape(shape)


def _blind_encode_kernel(x_ref, r_ref, inv_ref, o_ref, *, k_bits: int):
    """Scale + quantize + blind + limb-encode one (bm, bk) tile.

    inv_ref: (1, 1) float32 reciprocal of the activation absmax scale.
    o_ref: (3, bm, bk) int8 balanced base-256 limb planes of the blinded
    signed-canonical field element.
    """
    x = x_ref[...].astype(jnp.float32) * inv_ref[0, 0]
    q = jnp.clip(jnp.round(x * (2.0 ** k_bits)), -HALF, HALF).astype(jnp.int32)
    b = jnp.mod(jnp.mod(q, P) + r_ref[...], P)
    s = jnp.where(b > HALF, b - P, b)       # [0,p) -> signed canonical
    l0 = jnp.mod(s + 128, 256) - 128
    s1 = (s - l0) // 256
    l1 = jnp.mod(s1 + 128, 256) - 128
    s2 = (s1 - l1) // 256
    o_ref[...] = jnp.stack([l0, l1, s2]).astype(jnp.int8)


def blind_encode_pallas(x, r, inv_scale, k_bits: int, *, bm=256, bk=512,
                        interpret=False):
    """x: (M, K) float; r: (M, K) int32 field; inv_scale: (1, 1) float32.

    M, K must be multiples of (bm, bk) — the caller pads to the limb-matmul
    block plan so the output feeds ``limb_matmul_planes`` directly.
    Returns (3, M, K) int8 blinded limb planes.
    """
    M, K = x.shape
    assert M % bm == 0 and K % bk == 0, (M, K, bm, bk)
    return pl.pallas_call(
        functools.partial(_blind_encode_kernel, k_bits=k_bits),
        grid=(M // bm, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((3, bm, bk), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((3, M, K), jnp.int8),
        interpret=interpret,
    )(x, r, inv_scale)


def blind_pallas(x, r, k_bits: int, *, interpret=False):
    """x: float (...); r: int32 field (...). Returns blinded field int32."""
    return _tiled_call(
        functools.partial(_blind_kernel, k_bits=k_bits),
        jnp.int32, x, r, interpret=interpret)


def unblind_pallas(y, u, k_out_bits: int, out_dtype=jnp.float32, *,
                   interpret=False):
    """y, u: int32 field (...). Returns dequantized float."""
    return _tiled_call(
        functools.partial(_unblind_kernel, k_out_bits=k_out_bits,
                          out_dtype=out_dtype),
        out_dtype, y, u, interpret=interpret)
