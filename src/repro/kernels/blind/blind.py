"""Pallas TPU kernels: fused quantize+blind and unblind+dequantize.

Single VMEM pass per tile (vs. quantize, mod, add as separate HBM-bound
passes): these streams are pure-VPU elementwise work at ~6 bytes/elem of
traffic, so fusing the three stages triples effective blinding throughput —
the direct TPU analogue of the paper's observation that blinding cost is
the Slalom bottleneck.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.limb_matmul.ref import HALF, P

BLOCK = (256, 512)


def _blind_kernel(x_ref, r_ref, o_ref, *, k_bits: int):
    x = x_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x * (2.0 ** k_bits)), -HALF, HALF).astype(jnp.int32)
    q = jnp.mod(q, P)                       # signed -> [0, p)
    o_ref[...] = jnp.mod(q + r_ref[...], P)


def _unblind_kernel(y_ref, u_ref, o_ref, *, k_out_bits: int, out_dtype):
    d = jnp.mod(y_ref[...] - u_ref[...] + P, P)
    s = jnp.where(d > HALF, d - P, d)       # [0,p) -> signed canonical
    o_ref[...] = (s.astype(jnp.float32)
                  / (2.0 ** k_out_bits)).astype(out_dtype)


def _tiled_call(kernel, out_dtype, x, *others, interpret=False):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    others2 = [o.reshape(x2.shape) for o in others]
    M, N = x2.shape
    bm, bn = min(BLOCK[0], M), min(BLOCK[1], N)
    pm, pn = (-M) % bm, (-N) % bn
    if pm or pn:
        x2 = jnp.pad(x2, ((0, pm), (0, pn)))
        others2 = [jnp.pad(o, ((0, pm), (0, pn))) for o in others2]
    Mp, Np = x2.shape
    out = pl.pallas_call(
        kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))] * (1 + len(others2)),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        interpret=interpret,
    )(x2, *others2)
    return out[:M, :N].reshape(shape)


def blind_pallas(x, r, k_bits: int, *, interpret=False):
    """x: float (...); r: int32 field (...). Returns blinded field int32."""
    return _tiled_call(
        functools.partial(_blind_kernel, k_bits=k_bits),
        jnp.int32, x, r, interpret=interpret)


def unblind_pallas(y, u, k_out_bits: int, out_dtype=jnp.float32, *,
                   interpret=False):
    """y, u: int32 field (...). Returns dequantized float."""
    return _tiled_call(
        functools.partial(_unblind_kernel, k_out_bits=k_out_bits,
                          out_dtype=out_dtype),
        out_dtype, y, u, interpret=interpret)
