"""Jitted wrapper for the Pallas flash-attention forward."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import mha_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "impl"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, impl: str = "auto"):
    """q: (B,Sq,H,D); k,v: (B,Skv,KH,D) -> (B,Sq,H,D)."""
    if impl == "ref":
        return mha_ref(q, k, v, causal=causal)
    return flash_attention_fwd(
        q, k, v, causal=causal, bq=bq, bk=bk,
        interpret=(impl == "interpret") or (impl == "auto" and not _on_tpu()))
