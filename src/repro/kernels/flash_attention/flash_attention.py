"""Pallas TPU kernel: flash-attention forward (FlashAttention-2 tiling).

Grid: (batch×kv_head, q_blocks, kv_blocks), kv innermost. Per step the
kernel holds one q tile (bq × G·D), one K/V tile (bk × D) and the running
(m, l, acc) statistics in VMEM — the S² score tiles NEVER touch HBM, which
removes the dominant memory-roofline term of the XLA-compiled jnp flash
(EXPERIMENTS.md §Perf iteration 2: 25.7 s -> 4.8 s memory term on
qwen2.5-14b train_4k).

VMEM at bq=bk=512, G·D ≤ 5·128: q 640 KB + k/v 256 KB + scores
512×512 f32 1 MB + acc 1.3 MB — well inside 16 MiB with double buffering.
MXU dims (D=128, bk=512) are lane-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, nk: int, causal: bool, scale: float):
    """q_ref: (bq, GD); k_ref/v_ref: (bk, D); o_ref: (bq, GD).

    GD = G*D flattened query-group dim; scores computed per G slice.
    """
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    D = k_ref.shape[-1]
    G = q_ref.shape[-1] // D
    q = q_ref[0].astype(jnp.float32).reshape(bq, G, D)
    k = k_ref[0].astype(jnp.float32)
    # scores: (bq, G, bk)
    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where((qpos >= kpos)[:, None, :], s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                             (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc = acc_ref[...].reshape(bq, G, D) * corr[..., None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc.reshape(bq, G * D)

    @pl.when(ki == nk - 1)
    def _emit():
        out = acc_ref[...].reshape(bq, G, D) \
            / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(bq, G * D).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, bq: int = 512,
                        bk: int = 512, interpret: bool = False):
    """q: (B,Sq,H,D); k,v: (B,Skv,KH,D) -> (B,Sq,H,D), GQA-aware.

    Layout: grid batch-major over (B·KH), queries grouped (G per kv head).
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    bq, bk = min(bq, Sq), min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(D)

    # (B,KH, Sq, G*D): group queries of one kv head together
    q4 = q.reshape(B, Sq, KH, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B * KH, Sq, G * D)
    k4 = k.transpose(0, 2, 1, 3).reshape(B * KH, Skv, D)
    v4 = v.transpose(0, 2, 1, 3).reshape(B * KH, Skv, D)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, nk=nk,
                               causal=causal, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * KH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, G * D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, G * D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KH, Sq, G * D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, G), jnp.float32),        # running max m
            pltpu.VMEM((bq, G), jnp.float32),        # running sum l
            pltpu.VMEM((bq, G * D), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(q4, k4, v4)
    return out.reshape(B, KH, Sq, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Sq, H, D)
