"""Pure-jnp oracle for the Pallas flash-attention forward kernel.

Plain materialized causal attention over GQA-shaped inputs — the allclose
target for the tiled kernel (and numerically identical to
models/attention.py's naive core).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mha_ref(q, k, v, *, causal: bool = True):
    """q: (B,Sq,H,D); k,v: (B,Skv,KH,D) -> (B,Sq,H,Dv)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qr = q.reshape(B, Sq, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qr, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)
