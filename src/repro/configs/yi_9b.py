"""Yi-9B — llama-arch dense GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig, OrigamiConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    qkv_bias=False,
    attention="gqa",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="silu",
    origami=OrigamiConfig(enabled=True, tier1_layers=4),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
