"""Qwen2.5-14B — dense GQA with QKV bias [hf:Qwen/Qwen2.5 family]."""
from repro.configs.base import ModelConfig, OrigamiConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    attention="gqa",
    rope_theta=1000000.0,
    norm="rmsnorm",
    activation="silu",
    origami=OrigamiConfig(enabled=True, tier1_layers=4),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
