"""SmolLM-135M — small llama-arch dense GQA [hf:HuggingFaceTB/SmolLM-135M].

Also the end-to-end training-example arch (examples/train_smollm.py).
"""
from repro.configs.base import ModelConfig, OrigamiConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    qkv_bias=False,
    attention="gqa",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="silu",
    tie_embeddings=True,
    origami=OrigamiConfig(enabled=True, tier1_layers=3),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=96, num_heads=3, num_kv_heads=1, head_dim=32,
        d_ff=192, vocab_size=512, origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
