"""Config schema for all model families.

Configs are plain frozen dataclasses so they can be hashed into jit static
arguments and serialized into checkpoints / dry-run artifacts.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # Arctic-style dense residual FFN running in parallel with the experts.
    dense_residual_d_ff: int = 0
    # "gshard" = dense one-hot dispatch (baseline); "sorted" = argsort +
    # capacity buffers (optimized EP path used at scale).
    dispatch: str = "gshard"
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    variant: str = "mamba2"        # "mamba2" | "xlstm"
    state_dim: int = 64            # N: SSM state size per head
    conv_dim: int = 4              # depthwise conv width (mamba2)
    expand: int = 2                # inner dim = expand * d_model
    num_ssm_heads: int = 8         # mamba2 heads (d_inner / head_dim)
    chunk_size: int = 256          # chunked-scan block length
    # xlstm only: one sLSTM block every `slstm_every` blocks (rest mLSTM).
    slstm_every: int = 8
    slstm_proj_factor: float = 1.333


@dataclass(frozen=True)
class OrigamiConfig:
    """The paper's technique: tier-1 blinded-offload prefix, tier-2 open."""
    enabled: bool = False
    tier1_layers: int = 0          # partition point p (blocks, not sublayers)
    field_bits: int = 24           # p = 2**24 - 3
    quant_bits: int = 8            # activation/weight quantization bits
    # verify partition with c-GAN at p, p+1, p+2 per Algorithm 1
    verify_depth: int = 2


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | audio | vlm | ssm | cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    vocab_pad_to: int = 1          # pad vocab to a multiple (TP divisibility)
    qkv_bias: bool = False
    attention: str = "gqa"         # gqa | mla | windowed | none
    window_size: int = 0           # for attention == "windowed"
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    activation: str = "silu"       # silu | gelu | relu
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a shared full-attention block applied every k SSM blocks
    hybrid_attn_every: int = 0
    # enc-dec (whisper): num_layers applies to BOTH encoder and decoder
    encoder_decoder: bool = False
    encoder_seq_len: int = 1500    # whisper frame count after conv stub
    # vlm (llama-3.2-vision): cross-attention every k layers
    cross_attn_every: int = 0
    vision_seq_len: int = 1601     # patches from the (stub) vision tower
    # CNN (VGG) family
    cnn_layers: Tuple[str, ...] = ()
    image_size: int = 224
    image_channels: int = 3
    num_classes: int = 1000
    dtype: str = "bfloat16"
    origami: OrigamiConfig = field(default_factory=OrigamiConfig)
    remat: str = "block"           # none | block | full
    # number of layer-groups for scan-over-layers (1 = plain scan)
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementations)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), default=str, indent=1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per-arch)."""
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_training(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    pipeline_over_pod: bool = False

    @property
    def shape(self):
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    # bf16 moments for very large models (arctic-480b / qwen3-moe-235b)
    moment_dtype: str = "float32"
    microbatches: int = 1          # gradient accumulation steps
    grad_compression: bool = False # int8 + error feedback on cross-pod axis
    seed: int = 0
