"""MiniCPM3-4B — dense with Multi-head Latent Attention [hf:openbmb/MiniCPM3-4B].

vocab 73,448 is not divisible by the 16-way model axis; padded to a multiple
of 256 (73,472) — recorded in DESIGN.md §5.
"""
from repro.configs.base import MLAConfig, ModelConfig, OrigamiConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,                    # v head dim (MLA decouples qk dims)
    d_ff=6400,
    vocab_size=73448,
    vocab_pad_to=256,
    attention="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="silu",
    origami=OrigamiConfig(enabled=True, tier1_layers=4),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, vocab_pad_to=16,
        mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                      qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
        origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
