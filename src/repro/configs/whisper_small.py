"""Whisper-small backbone — enc-dec transformer; the audio conv frontend is a
STUB per assignment (input_specs provides precomputed frame embeddings)
[arXiv:2212.04356].

vocab 51,865 padded to a multiple of 256 (51,968) for vocab TP — DESIGN.md §5.
"""
from repro.configs.base import ModelConfig, OrigamiConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,                  # 12 encoder + 12 decoder
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    vocab_pad_to=256,
    attention="gqa",
    norm="layernorm",
    activation="gelu",
    encoder_decoder=True,
    encoder_seq_len=1500,
    tie_embeddings=True,
    rope_theta=0.0,                 # whisper uses learned/sinusoidal positions
    origami=OrigamiConfig(enabled=True, tier1_layers=2),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=96, num_heads=3, num_kv_heads=3, head_dim=32,
        d_ff=192, vocab_size=512, vocab_pad_to=16, encoder_seq_len=64,
        origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
