"""Architecture config registry.

``get_config(name)`` returns the full published config; ``get_smoke(name)``
returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (MLAConfig, MeshConfig, ModelConfig, MoEConfig,
                                OrigamiConfig, SHAPES, ShapeConfig, SSMConfig,
                                TrainConfig)

ARCHS: List[str] = [
    "qwen2_5_14b",
    "yi_9b",
    "minicpm3_4b",
    "smollm_135m",
    "qwen3_moe_235b",
    "arctic_480b",
    "zamba2_1_2b",
    "whisper_small",
    "llama3_2_vision_11b",
    "xlstm_1_3b",
]

PAPER_MODELS: List[str] = ["vgg16", "vgg19"]

# Canonical external ids (--arch accepts both forms).
ALIASES: Dict[str, str] = {
    "qwen2.5-14b": "qwen2_5_14b",
    "yi-9b": "yi_9b",
    "minicpm3-4b": "minicpm3_4b",
    "smollm-135m": "smollm_135m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "arctic-480b": "arctic_480b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-small": "whisper_small",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "xlstm-1.3b": "xlstm_1_3b",
    "vgg-16": "vgg16",
    "vgg-19": "vgg19",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs() -> List[str]:
    return list(ARCHS)


# Which shape cells apply per arch (see DESIGN.md §5 for skip rationale).
def applicable_shapes(name: str) -> List[str]:
    name = ALIASES.get(name, name)
    cfg = get_config(name)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    # long_500k only for sub-quadratic (SSM / hybrid) archs.
    if cfg.family in ("ssm", "hybrid"):
        shapes.append("long_500k")
    return shapes


SKIPPED_CELLS = [
    (a, "long_500k", "pure full-attention arch; no sub-quadratic variant in "
     "published config (DESIGN.md §5)")
    for a in ARCHS
    if get_config(a).family not in ("ssm", "hybrid")
]

__all__ = [
    "ARCHS", "PAPER_MODELS", "ALIASES", "SHAPES", "SKIPPED_CELLS",
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "OrigamiConfig",
    "ShapeConfig", "MeshConfig", "TrainConfig",
    "get_config", "get_smoke", "list_archs", "applicable_shapes",
]
