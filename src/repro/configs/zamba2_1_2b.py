"""Zamba2-1.2B — hybrid: Mamba2 backbone + shared full-attention block
applied every 6 SSM blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, OrigamiConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,                      # shared attention block's FFN
    vocab_size=32000,
    attention="gqa",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="gelu",
    ssm=SSMConfig(variant="mamba2", state_dim=64, conv_dim=4, expand=2,
                  num_ssm_heads=64, chunk_size=256),
    hybrid_attn_every=6,
    origami=OrigamiConfig(enabled=True, tier1_layers=3),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=6, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        ssm=SSMConfig(variant="mamba2", state_dim=16, conv_dim=4, expand=2,
                      num_ssm_heads=8, chunk_size=32),
        hybrid_attn_every=3,
        origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
