"""VGG-19 — the paper's second evaluation model."""
from repro.configs.base import ModelConfig, OrigamiConfig

_LAYERS = (
    "conv64", "conv64", "pool",
    "conv128", "conv128", "pool",
    "conv256", "conv256", "conv256", "conv256", "pool",
    "conv512", "conv512", "conv512", "conv512", "pool",
    "conv512", "conv512", "conv512", "conv512", "pool",
    "fc4096", "fc4096", "logits",
)

CONFIG = ModelConfig(
    name="vgg19",
    family="cnn",
    num_layers=len(_LAYERS),
    d_model=0, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=0,
    cnn_layers=_LAYERS,
    image_size=224,
    image_channels=3,
    num_classes=1000,
    dtype="float32",
    origami=OrigamiConfig(enabled=True, tier1_layers=6),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        cnn_layers=("conv8", "conv8", "pool", "conv16", "conv16", "conv16",
                    "pool", "fc32", "logits"),
        num_layers=9, image_size=32, num_classes=10,
        origami=OrigamiConfig(enabled=True, tier1_layers=3),
    )
