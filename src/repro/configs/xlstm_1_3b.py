"""xLSTM-1.3B — recurrent: mLSTM blocks with one sLSTM block every 8
(d_ff = 0: blocks carry their own up/down projections) [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig, OrigamiConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    attention="none",
    norm="layernorm",
    activation="gelu",
    ssm=SSMConfig(variant="xlstm", expand=2, num_ssm_heads=4, chunk_size=256,
                  slstm_every=8, slstm_proj_factor=1.333),
    origami=OrigamiConfig(enabled=True, tier1_layers=3),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        vocab_size=512,
        ssm=SSMConfig(variant="xlstm", expand=2, num_ssm_heads=2,
                      chunk_size=16, slstm_every=4),
        origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
