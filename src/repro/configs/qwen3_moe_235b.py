"""Qwen3-MoE-235B-A22B — 128-expert top-8 MoE [hf:Qwen/Qwen3 family]."""
from repro.configs.base import ModelConfig, MoEConfig, OrigamiConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                      # per-expert FFN width
    vocab_size=151936,
    qkv_bias=False,
    attention="gqa",
    rope_theta=1000000.0,
    norm="rmsnorm",
    activation="silu",
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536,
                  dispatch="sorted_grouped"),
    origami=OrigamiConfig(enabled=True, tier1_layers=4),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=64, vocab_size=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                      dispatch="gshard"),
        origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
