"""Snowflake Arctic-480B — 128-expert top-2 MoE with dense residual FFN
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ModelConfig, MoEConfig, OrigamiConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                      # per-expert FFN width
    vocab_size=32000,
    qkv_bias=False,
    attention="gqa",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="silu",
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual_d_ff=4864, dispatch="sorted_grouped"),
    origami=OrigamiConfig(enabled=True, tier1_layers=3),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=64, vocab_size=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                      dense_residual_d_ff=64, dispatch="gshard"),
        origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
