"""Llama-3.2-Vision-11B backbone — decoder with cross-attention image layers
every 5 blocks; the vision tower is a STUB (precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from repro.configs.base import ModelConfig, OrigamiConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    attention="gqa",
    rope_theta=500000.0,
    norm="rmsnorm",
    activation="silu",
    cross_attn_every=5,
    vision_seq_len=1601,
    origami=OrigamiConfig(enabled=True, tier1_layers=4),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=5, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, cross_attn_every=5, vision_seq_len=16,
        origami=OrigamiConfig(enabled=True, tier1_layers=1),
    )
