"""VGG-16 — the paper's primary evaluation model [Simonyan & Zisserman 2014].

``cnn_layers`` entries: "convC" (3x3 s1 conv to C channels + ReLU),
"pool" (2x2 maxpool), "fcN" (dense to N + ReLU), "logits" (dense to classes).
Layer indices in the paper ("partition at layer 6") count conv/pool layers in
this list, 1-based, matching Fig. 7/8.
"""
from repro.configs.base import ModelConfig, OrigamiConfig

_LAYERS = (
    "conv64", "conv64", "pool",            # 1,2,3
    "conv128", "conv128", "pool",          # 4,5,6
    "conv256", "conv256", "conv256", "pool",
    "conv512", "conv512", "conv512", "pool",
    "conv512", "conv512", "conv512", "pool",
    "fc4096", "fc4096", "logits",
)

CONFIG = ModelConfig(
    name="vgg16",
    family="cnn",
    num_layers=len(_LAYERS),
    d_model=0, num_heads=0, num_kv_heads=0, d_ff=0,
    vocab_size=0,
    cnn_layers=_LAYERS,
    image_size=224,
    image_channels=3,
    num_classes=1000,
    dtype="float32",
    # Paper: partition after layer 6 (first pool of block 2) is the minimum
    # safe point verified by the c-GAN (Fig. 7/8).
    origami=OrigamiConfig(enabled=True, tier1_layers=6),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        cnn_layers=("conv8", "conv8", "pool", "conv16", "conv16", "pool",
                    "fc32", "logits"),
        num_layers=8, image_size=32, num_classes=10,
        origami=OrigamiConfig(enabled=True, tier1_layers=3),
    )
