"""AdamW with optional reduced-precision moments (no optax dependency).

``moment_dtype="bfloat16"`` halves optimizer-state HBM — required to fit
arctic-480b / qwen3-moe-235b training states on 256 chips (DESIGN.md §4);
the update math still runs in fp32.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params, cfg: TrainConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, cfg: TrainConfig,
           lr: jax.Array):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm}


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32) + 1.0      # opt.step is pre-increment
    warm = jnp.minimum(1.0, s / jnp.maximum(cfg.warmup_steps, 1))
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))
