"""Remote-attestation simulation: enclave measurement & quote verification.

Models SGX's EREPORT/quote flow (paper §II-A): the enclave "measurement" is
a structural hash over the tier-1 code identity (config + partition + field
parameters + weight digests), so a client can verify WHICH model prefix and
protocol version will process its data before releasing the session key —
exactly the guarantee the paper assumes ("the user may verify the model").
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import json
from dataclasses import dataclass
from typing import Any, Dict

import jax
import numpy as np

from repro.configs.base import ModelConfig


def _digest_params(params, max_bytes: int = 1 << 16) -> str:
    h = hashlib.sha256()
    for path, leaf in sorted(
            jax.tree_util.tree_flatten_with_path(params)[0],
            key=lambda kv: str(kv[0])):
        h.update(str(path).encode())
        arr = np.asarray(leaf).reshape(-1)
        h.update(np.asarray(arr[: max_bytes // max(arr.itemsize, 1)]).tobytes())
    return h.hexdigest()


@dataclass(frozen=True)
class Quote:
    measurement: str
    config_name: str
    partition: int
    field_p: int
    protocol_version: str = "origami-1"
    # PlacementPlan digest (core/plan.py): binds the quote to the exact
    # per-layer placement the enclave will execute, not just the prefix
    # cut ("" for pre-plan callers — folded into the measurement only
    # when set, so their measurements are unchanged).
    plan_digest: str = ""


def measure_enclave(cfg: ModelConfig, params, partition: int,
                    plan_digest: str = "") -> Quote:
    from repro.kernels.limb_matmul.ref import P
    ident = {
        "config": cfg.to_json(),
        "partition": partition,
        "field_p": P,
        "weights": _digest_params(params),
    }
    if plan_digest:
        ident["plan"] = plan_digest
    m = hashlib.sha256(json.dumps(ident, sort_keys=True).encode()).hexdigest()
    return Quote(measurement=m, config_name=cfg.name, partition=partition,
                 field_p=P, plan_digest=plan_digest)


def _canonical(quote: Quote) -> bytes:
    """Fixed-length canonical encoding for constant-time comparison: the
    sha256 of the sorted-key JSON of all quote fields (hashing first also
    removes any length side channel between differently-sized quotes)."""
    return hashlib.sha256(json.dumps(
        dataclasses.asdict(quote), sort_keys=True).encode()).digest()


def verify_quote(quote: Quote, expected: Quote) -> bool:
    """Constant-time quote check — dataclass ``==`` short-circuits on the
    first differing field/character, leaking where a forged measurement
    diverges; compare canonical digests with ``hmac.compare_digest``."""
    return hmac.compare_digest(_canonical(quote), _canonical(expected))
