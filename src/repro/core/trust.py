"""Trust domains and the enclave cost/residency model.

This container has no SGX part (and the TPU target has no enclave at all),
so absolute enclave timings are *modeled*, calibrated to the paper's own
measurements (§VI), while all byte/FLOP quantities are computed from our
actual model implementations. The reproduction target is the paper's
relative results (Figs 9/10/12/13, Tables I/II) — see DESIGN.md §7.

Calibration constants (from the paper):
  - blinding/unblinding throughput: 6 MB per 4 ms          (§VI-C)
  - GPU ≈ 49× CPU on VGG inference (321× / 6.5×)           (§III-A)
  - enclave(JIT-loading) ≈ CPU / 6.4..6.5                  (Fig. 2)
  - enclave pre-loaded ≈ CPU / 16.7..18.3 (paging-bound)   (Fig. 2)
  - power-event recovery ≈ re-init + EPC re-encryption      (Table II)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class EnclaveParams:
    """Calibrated so the VGG-16 strategy costs land on the paper's numbers
    (see benchmarks/paper_fig9_10.py for the target-vs-model table)."""
    epc_limit_mb: float = 128.0
    epc_usable_mb: float = 93.0
    cpu_flops: float = 1.0e11          # effective CPU conv/matmul throughput
    gpu_speedup: float = 49.0          # paper: 321x / 6.5x
    sgx_slowdown: float = 5.2          # compute-only slowdown (solved from
                                       # Split/6 ≈ 4x, Fig. 4)
    blind_bytes_per_s: float = 6e6 / 4e-3   # 1.5 GB/s (§VI-C, 4ms/6MB)
    # enclave elementwise/copy bandwidth (EPC-bound ReLU, quantize, ECALL
    # copies) — solved from Slalom = enclave/10 (Fig. 9)
    enclave_mem_bytes_per_s: float = 0.9e9
    # lazy-load paging of >8MB dense layers — solved from enclave = 6.4x CPU
    paging_bytes_per_s: float = 1.47e9
    epc_init_bytes_per_s: float = 86e6 / 0.190     # Table II: ~201ms/86MB
    recovery_base_s: float = 0.012
    runtime_overhead_mb: float = 4.0
    # per-offloaded-op host dispatch overhead (ECALL/OCALL transition +
    # host-side fan-out). The paper folds this into its throughputs, so
    # the calibrated default is 0.0 — keeping every Fig 9/10 number
    # bit-identical; CalibratedCostModel fits a measured value from the
    # profiler's dispatch_wait phase.
    dispatch_overhead_s: float = 0.0

    @property
    def gpu_flops(self) -> float:
        return self.cpu_flops * self.gpu_speedup

    @property
    def sgx_flops(self) -> float:
        return self.cpu_flops / self.sgx_slowdown


@dataclass
class LayerProfile:
    name: str
    flops: int                 # linear-op FLOPs
    param_bytes: int
    out_bytes: int             # output feature-map bytes (batch 1, fp32)
    linear: bool               # offloadable under blinding?


def vgg_layer_profiles(cfg: ModelConfig) -> List[LayerProfile]:
    from repro.models.vgg import _parse
    h = w = cfg.image_size
    c = cfg.image_channels
    out: List[LayerProfile] = []
    flat = None
    for spec in cfg.cnn_layers:
        kind, n = _parse(spec)
        if kind == "conv":
            flops = 2 * h * w * 9 * c * n
            pbytes = (9 * c * n + n) * 4
            c = n
            obytes = h * w * c * 4
            out.append(LayerProfile(spec, flops, pbytes, obytes, True))
        elif kind == "pool":
            h, w = h // 2, w // 2
            obytes = h * w * c * 4
            out.append(LayerProfile(spec, h * w * c * 4 // 4, 0, obytes,
                                    False))
        else:
            d_in = flat if flat is not None else h * w * c
            d_out = n if kind == "fc" else cfg.num_classes
            flops = 2 * d_in * d_out
            out.append(LayerProfile(spec, flops, (d_in * d_out + d_out) * 4,
                                    d_out * 4, True))
            flat = d_out
    return out


@dataclass
class StrategyCost:
    name: str
    runtime_s: float
    enclave_resident_mb: float
    recovery_s: float
    breakdown: Dict[str, float]


class EnclaveSim:
    """Prices an execution strategy for a CNN model on (SGX + device)."""

    def __init__(self, cfg: ModelConfig, params: EnclaveParams = None,
                 device: str = "gpu"):
        self.cfg = cfg
        self.p = params or EnclaveParams()
        self.device_flops = (self.p.gpu_flops if device == "gpu"
                             else self.p.cpu_flops)
        self.layers = vgg_layer_profiles(cfg)

    # -- residency (Table I) ------------------------------------------------
    def residency_bytes(self, mode: str, partition: int) -> float:
        L = self.layers
        p = self.p
        act = max(l.out_bytes for l in L)                  # working buffer
        overhead = p.runtime_overhead_mb * 2 ** 20
        if mode == "enclave":
            # baseline 2: convs resident; >8MB FC layers lazy-load in slices
            conv_params = sum(l.param_bytes for l in L
                              if not l.name.startswith(("fc", "logits")))
            return conv_params + 8 * 2 ** 20 + act + overhead
        if mode == "split":
            return (sum(l.param_bytes for l in L[:partition]) + 2 * act
                    + overhead)
        if mode in ("slalom", "origami"):
            blind_layers = L[:partition] if mode == "origami" else L
            feat = max((l.out_bytes for l in blind_layers), default=act)
            # blinding-factor buffer (paper: ~12MB) + quantized feature + act
            return feat + 12 * 2 ** 20 + act + overhead
        return 0.0

    # -- runtime (Figs 9/10/12/13) -------------------------------------------
    def runtime(self, mode: str, partition: int) -> StrategyCost:
        p = self.p
        L = self.layers
        t_enclave = t_device = t_blind = t_page = t_disp = 0.0
        resident = self.residency_bytes(mode, partition)

        for i, l in enumerate(L):
            in_tier1 = i < partition
            if mode == "open":
                t_device += l.flops / self.device_flops
            elif mode == "enclave":
                t_enclave += l.flops / p.sgx_flops
                if (l.name.startswith(("fc", "logits"))
                        and l.param_bytes > 8 * 2 ** 20):   # lazy-loaded FC
                    t_page += l.param_bytes / p.paging_bytes_per_s
            elif mode == "split":
                if in_tier1:
                    t_enclave += l.flops / p.sgx_flops
                else:
                    t_device += l.flops / self.device_flops
            elif mode in ("slalom", "origami"):
                blinded = (mode == "slalom") or in_tier1
                if blinded and l.linear:
                    t_device += l.flops / self.device_flops
                    # blind+unblind passes and the EPC-bound elementwise /
                    # copy work (quantize, ReLU, ECALL buffers)
                    t_blind += 2 * l.out_bytes / p.blind_bytes_per_s
                    t_enclave += 2 * l.out_bytes / p.enclave_mem_bytes_per_s
                    t_disp += p.dispatch_overhead_s
                elif blinded:                       # pool etc. in enclave
                    t_enclave += l.out_bytes / p.enclave_mem_bytes_per_s
                else:
                    t_device += l.flops / self.device_flops
        total = t_enclave + t_device + t_blind + t_page + t_disp
        return StrategyCost(
            name=mode,
            runtime_s=total,
            enclave_resident_mb=resident / 2 ** 20,
            recovery_s=self.recovery_s(resident),
            breakdown={"enclave": t_enclave, "device": t_device,
                       "blind": t_blind, "paging": t_page,
                       "dispatch": t_disp})

    def recovery_s(self, resident_bytes: float) -> float:
        return (self.p.recovery_base_s
                + resident_bytes / self.p.epc_init_bytes_per_s)

    def all_strategies(self, partition: int) -> Dict[str, StrategyCost]:
        return {m: self.runtime(m, partition)
                for m in ("open", "enclave", "split", "slalom", "origami")}

    # -- PlacementPlan pricing (core/plan.py, DESIGN.md §10) -----------------
    def plan_runtime(self, plan) -> StrategyCost:
        """Price an arbitrary PlacementPlan per-step.

        Plans that are exactly a legacy prefix shape delegate to
        ``runtime(mode, p)`` — bit-identical to the paper-calibrated
        per-mode formulas. Mixed plans walk the steps: open → device
        FLOPs (+ quantize/fold elementwise when verified-open); enclave →
        SGX FLOPs (paging for >8MB fc weights); blinded linear → device
        FLOPs + blind traffic + EPC elementwise. Non-linear enclave steps
        are EPC-bandwidth-bound whenever the plan offloads anything (the
        enclave is then a thin elementwise stage between device matmuls),
        FLOPs-bound in a pure-enclave deployment — matching the legacy
        enclave/slalom formulas at both endpoints.
        """
        from repro.core.plan import classify_legacy
        legacy = classify_legacy(plan)
        if legacy is not None:
            mode, p_cut = legacy
            cost = self.runtime(mode, p_cut)
            return StrategyCost(plan.mode_label, cost.runtime_s,
                                cost.enclave_resident_mb, cost.recovery_s,
                                cost.breakdown)
        p = self.p
        L = self.layers
        assert len(L) == plan.n_layers, (len(L), plan.n_layers)
        epc_bound = plan.has_offload
        t_enclave = t_device = t_blind = t_page = t_disp = 0.0
        for st, l in zip(plan.steps, L):
            if st.placement == "blinded" and l.linear:
                t_device += l.flops / self.device_flops
                t_blind += 2 * l.out_bytes / p.blind_bytes_per_s
                t_enclave += 2 * l.out_bytes / p.enclave_mem_bytes_per_s
                t_disp += p.dispatch_overhead_s
            elif st.placement == "enclave" or st.placement == "blinded":
                # enclave-resident (incl. non-linear layers in a blinded
                # tier — pools can't blind)
                if epc_bound and not l.linear:
                    t_enclave += l.out_bytes / p.enclave_mem_bytes_per_s
                else:
                    t_enclave += l.flops / p.sgx_flops
                    if (l.name.startswith(("fc", "logits"))
                            and l.param_bytes > 8 * 2 ** 20):
                        t_page += l.param_bytes / p.paging_bytes_per_s
            else:                                   # open
                t_device += l.flops / self.device_flops
                if st.verified_open:
                    # quantize + Freivalds fold are enclave elementwise
                    t_enclave += 2 * l.out_bytes / p.enclave_mem_bytes_per_s
                    t_disp += p.dispatch_overhead_s
        resident = self.plan_residency(plan)
        total = t_enclave + t_device + t_blind + t_page + t_disp
        return StrategyCost(
            name=plan.mode_label, runtime_s=total,
            enclave_resident_mb=resident / 2 ** 20,
            recovery_s=self.recovery_s(resident),
            breakdown={"enclave": t_enclave, "device": t_device,
                       "blind": t_blind, "paging": t_page,
                       "dispatch": t_disp})

    def _plan_quantities(self, plan) -> Dict[str, float]:
        """The cost-model feature quantities a plan moves per inference —
        the same features CalibratedCostModel fits unit costs for, so a
        calibrated prediction is literally ``sum(c_f * q_f)``."""
        p = self.p  # noqa: F841 — quantities are params-independent
        L = self.layers
        q = {"device_flops": 0.0, "enclave_flops": 0.0, "blind_bytes": 0.0,
             "unblind_bytes": 0.0, "dispatches": 0.0}
        epc_bound = plan.has_offload
        for st, l in zip(plan.steps, L):
            if st.placement == "blinded" and l.linear:
                q["device_flops"] += l.flops
                q["blind_bytes"] += 2 * l.out_bytes
                q["unblind_bytes"] += 2 * l.out_bytes
                q["dispatches"] += 1
            elif st.placement in ("enclave", "blinded"):
                if not (epc_bound and not l.linear):
                    q["enclave_flops"] += l.flops
            else:
                q["device_flops"] += l.flops
                if st.verified_open:
                    q["unblind_bytes"] += 2 * l.out_bytes
                    q["dispatches"] += 1
        return q

    def plan_residency(self, plan) -> float:
        """EPC residency of a mixed plan: enclave-placed weights (fc
        lazy-loads in 8MB slices), the blinding-factor buffer + widest
        offloaded feature when anything offloads, working activations and
        runtime overhead."""
        p = self.p
        L = self.layers
        act = max(l.out_bytes for l in L)
        total = act + p.runtime_overhead_mb * 2 ** 20
        enclave_params = sum(
            min(l.param_bytes, 8 * 2 ** 20)
            if l.name.startswith(("fc", "logits")) else l.param_bytes
            for st, l in zip(plan.steps, L) if st.placement == "enclave")
        total += enclave_params
        offl = [l.out_bytes for st, l in zip(plan.steps, L) if st.offloaded]
        if offl:
            total += max(offl) + 12 * 2 ** 20
        return total


# -- measured calibration (runtime/profiling.py feedback loop) --------------

class CalibratedCostModel:
    """Fits per-phase unit costs from measured phase profiles.

    The paper-constant ``EnclaveParams`` were transcribed from §VI SGX
    measurements this container has never validated; the profiler
    (runtime/profiling.CriticalPathProfiler) measures what each phase
    *actually* costs here. Each observation pairs feature quantities
    (FLOPs moved, bytes blinded/unblinded, dispatch count — from executor
    telemetry stamped onto infer spans) with measured phase seconds; the
    per-feature unit cost is the 1-D least-squares slope through the
    origin, ``c = sum(q*t) / sum(q^2)`` — exact for one observation,
    noise-averaging for many. Only warm observations enter (first-call
    trees carry compile time, which has its own phase, not a unit cost).

    Timing threat-model note (DESIGN.md §14): observations are per-tree
    *aggregates* of shape-dependent phases — the same counts/timings the
    redacted trace already exposes; no payload-dependent value enters.
    """

    # phase -> the feature quantity whose unit cost it measures
    PHASE_FEATURES = {
        "device_compute": "device_flops",
        "blind": "blind_bytes",
        "unblind": "unblind_bytes",
        "dispatch_wait": "dispatches",
        "seal": "seal_bytes",
        "unseal": "seal_bytes",
    }

    def __init__(self, base: EnclaveParams = None, device: str = "gpu"):
        self.base = base or EnclaveParams()
        self.device = device
        self.n_observations = 0
        self._sqt: Dict[str, float] = {}     # feature -> sum(q * t)
        self._sqq: Dict[str, float] = {}     # feature -> sum(q^2)

    def observe(self, quantities: Dict[str, float],
                seconds: Dict[str, float]) -> None:
        """One measured tree: feature quantities + per-phase seconds."""
        self.n_observations += 1
        for phase, feat in self.PHASE_FEATURES.items():
            q = float(quantities.get(feat, 0.0))
            t = float(seconds.get(phase, 0.0))
            if q > 0.0 and t > 0.0:
                self._sqt[feat] = self._sqt.get(feat, 0.0) + q * t
                self._sqq[feat] = self._sqq.get(feat, 0.0) + q * q

    def observe_all(self, observations) -> None:
        """Bulk-feed ``CriticalPathProfiler.cost_observations()``."""
        for quantities, seconds in observations:
            self.observe(quantities, seconds)

    @property
    def unit_costs(self) -> Dict[str, float]:
        """Fitted seconds-per-unit for every feature with data."""
        return {f: self._sqt[f] / self._sqq[f]
                for f in self._sqt if self._sqq.get(f, 0.0) > 0.0}

    def fit(self) -> EnclaveParams:
        """Measured ``EnclaveParams``: every parameter a unit cost pins is
        replaced; everything unmeasured keeps its paper value. The SGX
        compute ratio (``sgx_slowdown``) is a paper relation, not a local
        observable (this container has no SGX part) — it is held fixed
        and ``cpu_flops`` moves instead, so enclave-mode pricing scales
        with the measured hardware while Fig 2's ratio structure holds."""
        import dataclasses as _dc
        c = self.unit_costs
        kw = {}
        if "device_flops" in c:
            device_flops = 1.0 / c["device_flops"]
            if self.device == "gpu":
                # keep the paper's CPU:GPU ratio, move the absolute scale
                kw["cpu_flops"] = device_flops / self.base.gpu_speedup
            else:
                kw["cpu_flops"] = device_flops
        if "blind_bytes" in c:
            kw["blind_bytes_per_s"] = 1.0 / c["blind_bytes"]
        if "unblind_bytes" in c:
            kw["enclave_mem_bytes_per_s"] = 1.0 / c["unblind_bytes"]
        if "dispatches" in c:
            kw["dispatch_overhead_s"] = c["dispatches"]
        return _dc.replace(self.base, **kw)

    def gauges(self, prefix: str = "costmodel") -> Dict[str, float]:
        """Fitted unit costs + observation count as registry gauges."""
        out = {f"{prefix}.observations": float(self.n_observations)}
        for feat, cost in self.unit_costs.items():
            out[f"{prefix}.unit_s.{feat}"] = cost
        return out

    def predict_plan_s(self, sim: "EnclaveSim", plan) -> float:
        """Plan runtime under the *fitted* params (convenience: rebuilds
        the sim's pricing with ``fit()`` applied)."""
        cal = EnclaveSim(sim.cfg, params=self.fit(),
                         device=self.device)
        return cal.plan_runtime(plan).runtime_s
