"""PlacementPlan IR: per-layer placement compiled once, interpreted once.

The paper's Origami/Slalom spectrum is a *per-layer placement decision* —
each block either runs on the untrusted device in the clear (``open``),
inside the enclave (``enclave``), or blinded-offloaded to the device
(``blinded``). The seed encoded that spectrum as five mode strings plus a
single prefix-partition integer, and re-derived the decision logic in four
places (the executor's tier bounds, the planner, the precompute recorder,
the cost model). This module makes the decision an explicit artifact,
YerbaBuena-style (Gu et al.: ternary model partitioning) with
Privado-style declarative per-model compilation:

- ``LayerStep(layer_id, placement, integrity, precompute_slot)`` — one
  decision per block. ``integrity`` is an optional per-step Freivalds
  policy override (``None`` inherits the executor's policy); an *open*
  step with an enabled policy is a **verified-open offload**: the device
  computes the field matmul unblinded (zero pad, no privacy) but the
  enclave still Freivalds-checks the result — the Slalom/Integrity point
  of the design space, previously inexpressible. ``precompute_slot`` is
  the op's index into the BlindedLayerCache (assigned statically for CNNs;
  ``None`` for ops traced under ``lax.scan``, which stay uncacheable).
- ``PlacementPlan`` — the ordered steps plus the ``boundary`` index: the
  layer count after which the activation is revealed to the adversary
  (what ``OrigamiResult.boundary`` captures). ``compile_mode`` compiles
  every legacy mode string; ``make_plan``/``from_string`` build arbitrary
  custom placements (e.g. mixed enclave/blinded tier-1).
- ``segments()`` — maximal runs of equal execution regime
  (``plain`` | ``blinded`` | ``verified``), split at the boundary; the
  executor walks these with one loop for every model family
  (``program_for`` dispatches to the per-family layer iterators in
  models/vgg.py / models/model.py).
- ``digest`` — a stable hash of the whole plan; the serving layer keys
  layer caches and prefetch rings on it (DESIGN.md §10).

Execution-regime note: non-linear layers (pools) inside a blinded segment
simply never hit the dense/conv intercept — they run enclave-resident, as
in the paper. The cost model (core/trust.py) prices them with the
EPC-bandwidth formula whenever the plan has blinded steps.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from functools import cached_property
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core import integrity as IG

PLACEMENTS = ("open", "enclave", "blinded")
LEGACY_MODES = ("open", "enclave", "split", "slalom", "origami")
SHARD_MODES = ("rows", "shares")

# families whose decode walk is per-op addressable (models/model.py
# decode_range_unrolled): every block is a uniform stack of static-weight
# linear ops, so a decode plan can bind per-(token, layer) factor slots and
# per-step Freivalds folds. Everything else raises ScanExclusion with the
# documented reason (make_decode_plan).
DECODE_FAMILIES = ("dense",)

_DECODE_EXCLUSIONS = {
    "cnn": "feed-forward family: no autoregressive decode loop exists",
    "moe": "expert weights are data-dependent gathers (top-k routing), so "
           "per-op unblinding factors u = r @ W cannot be precomputed — "
           "run MoE decode enclave-resident or blinded-unverified",
    "hybrid": "decode walks grouped mamba super-blocks under lax.scan; the "
              "recurrent state update is not a static-weight linear map",
    "ssm": "decode walks grouped m/sLSTM super-blocks under lax.scan; the "
           "recurrent state update is not a static-weight linear map",
    "audio": "decoder blocks carry cross-attention against the encoder "
             "memory and decode under lax.scan (grouped super-blocks)",
    "vlm": "decoder blocks carry cross-attention against the vision "
           "memory and decode under lax.scan (grouped super-blocks)",
}


class ScanExclusion(ValueError):
    """A placement/decode feature is structurally unavailable for this
    family — the typed form of the former "scanned families fall back"
    branches. Subclasses ValueError so legacy callers keep working; the
    message always names the documented reason (DESIGN.md §16)."""

# placement-string alphabet (``from_string`` / ``placement_string``):
# o = open, e = enclave, b = blinded, v = verified-open (open + Freivalds)
_CHAR_PLACEMENT = {"o": "open", "e": "enclave", "b": "blinded", "v": "open"}
_PLACEMENT_CHAR = {"open": "o", "enclave": "e", "blinded": "b"}


def num_blocks(cfg: ModelConfig) -> int:
    """Plan length for a config: CNN layer specs or transformer blocks."""
    return len(cfg.cnn_layers) if cfg.family == "cnn" else cfg.num_layers


@dataclass(frozen=True)
class ShardPolicy:
    """Per-step multi-device offload policy (parallel/offload_sharding.py).

    ``mode``: "rows" (row-shard the blinded operand over the batch/token
    dim) | "shares" (additive secret shares — no single device holds the
    full blinded tensor). ``devices``: optional device-group restriction —
    slot indices of the executor's DevicePool this step may dispatch to
    (``None`` = the whole pool). ``None`` on a step inherits the
    executor-wide plane default; a ShardPolicy on a step without a plane
    is inert (the plan stays executable on a single device)."""
    mode: str = "rows"
    devices: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        assert self.mode in SHARD_MODES, self.mode


def _shard_key(s: Optional[ShardPolicy]):
    return None if s is None else (s.mode, s.devices)


@dataclass(frozen=True)
class LayerStep:
    """One per-layer placement decision.

    ``integrity``: per-step Freivalds policy. ``None`` inherits the
    executor's policy (for blinded steps) / means unverified (for open
    steps); an explicit ``IntegrityPolicy.off()`` on a blinded step opts
    that step out of an executor-wide policy. ``precompute_slot``: index
    of this step's blinded op in the BlindedLayerCache (``None``:
    uncacheable — non-linear layer, non-offloaded, or scanned family).
    ``shard``: per-step multi-device ShardPolicy (``None`` inherits the
    executor's offload plane default).
    """
    layer_id: int
    placement: str
    integrity: Optional[IG.IntegrityPolicy] = None
    precompute_slot: Optional[int] = None
    shard: Optional[ShardPolicy] = None

    def __post_init__(self):
        assert self.placement in PLACEMENTS, self.placement

    @property
    def verified_open(self) -> bool:
        return (self.placement == "open" and self.integrity is not None
                and self.integrity.enabled)

    @property
    def offloaded(self) -> bool:
        """Does the untrusted device execute this step's linear ops?"""
        return self.placement == "blinded" or self.verified_open


@dataclass(frozen=True)
class Segment:
    """A maximal run of plan steps sharing one execution regime.

    ``regime``: "plain" (fp, no device protocol — open or enclave),
    "blinded" (Slalom protocol), "verified" (unblinded offload +
    Freivalds). ``policy``/``shard`` are the per-segment
    IntegrityPolicy/ShardPolicy overrides (``None`` = inherit the
    executor's)."""
    lo: int
    hi: int
    regime: str
    policy: Optional[IG.IntegrityPolicy] = None
    shard: Optional[ShardPolicy] = None


def _policy_key(p: Optional[IG.IntegrityPolicy]):
    return None if p is None else (p.mode, p.rate, p.k)


@dataclass(frozen=True)
class PlacementPlan:
    """Ordered per-layer placements + the revealed-boundary index."""
    model: str
    family: str
    steps: Tuple[LayerStep, ...]
    boundary: int
    mode_label: str = "custom"

    def __post_init__(self):
        n = len(self.steps)
        assert 0 <= self.boundary <= n, (self.boundary, n)
        for i, st in enumerate(self.steps):
            assert st.layer_id == i, (st.layer_id, i)

    # -- derived structure ---------------------------------------------------
    def _regime(self, st: LayerStep):
        if st.placement == "blinded":
            return "blinded", st.integrity
        if st.verified_open:
            return "verified", st.integrity
        return "plain", None

    @cached_property
    def segments(self) -> Tuple[Segment, ...]:
        """Maximal equal-regime runs, always split at ``boundary`` so the
        executor can capture the revealed activation between segments."""
        segs = []
        for i, st in enumerate(self.steps):
            regime, policy = self._regime(st)
            shard = st.shard if regime != "plain" else None
            if (segs and segs[-1].regime == regime
                    and _policy_key(segs[-1].policy) == _policy_key(policy)
                    and _shard_key(segs[-1].shard) == _shard_key(shard)
                    and i != self.boundary):
                segs[-1] = Segment(segs[-1].lo, i + 1, regime, policy, shard)
            else:
                segs.append(Segment(i, i + 1, regime, policy, shard))
        return tuple(segs)

    @cached_property
    def digest(self) -> str:
        body = {
            "model": self.model, "family": self.family,
            "boundary": self.boundary,
            "steps": [(s.layer_id, s.placement, _policy_key(s.integrity))
                      for s in self.steps],
        }
        if any(s.shard is not None for s in self.steps):
            # appended only when present so shard-free plans keep their
            # pre-sharding digests (cache keys, attested measurements)
            body["shards"] = [(s.layer_id, _shard_key(s.shard))
                              for s in self.steps if s.shard is not None]
        return hashlib.sha256(
            json.dumps(body, sort_keys=True).encode()).hexdigest()

    @property
    def n_layers(self) -> int:
        return len(self.steps)

    @property
    def num_blinded(self) -> int:
        return sum(s.placement == "blinded" for s in self.steps)

    @property
    def has_blinded(self) -> bool:
        return any(s.placement == "blinded" for s in self.steps)

    @property
    def has_offload(self) -> bool:
        """Any step running the device protocol (blinded or verified-open):
        gates the precompute pipeline and the session-factor machinery."""
        return any(s.offloaded for s in self.steps)

    @property
    def has_step_policies(self) -> bool:
        """Any step carrying its own enabled Freivalds policy (verified
        even when the executor-wide policy is off)."""
        return any(s.integrity is not None and s.integrity.enabled
                   for s in self.steps)

    @property
    def cache_ops(self) -> Tuple[LayerStep, ...]:
        """Steps with a static precompute slot, in slot (= trace) order."""
        ops = [s for s in self.steps if s.precompute_slot is not None]
        return tuple(sorted(ops, key=lambda s: s.precompute_slot))

    @property
    def placement_string(self) -> str:
        return "".join("v" if s.verified_open
                       else _PLACEMENT_CHAR[s.placement] for s in self.steps)

    def exposed_boundaries(self) -> Tuple[int, ...]:
        """Every boundary index the untrusted device observes in the
        clear: the declared ``boundary`` plus the input and output of
        every open step (open layers compute on device in plain fp, so
        both sides of them leak). Index 0 is the RAW INPUT — exposed when
        the first layer is open (or the boundary is 0); core/planner.py's
        fail-closed rule scores it as total leakage (1.0). The final
        index n (the logits) is inherently public and never listed."""
        n = len(self.steps)
        exposed = set()
        if self.boundary <= n - 1:
            exposed.add(self.boundary)
        if self.steps and self.steps[0].placement == "open":
            exposed.add(0)
        for p in range(1, n):
            if (self.steps[p - 1].placement == "open"
                    or self.steps[p].placement == "open"):
                exposed.add(p)
        return tuple(sorted(exposed))

    def summary(self) -> str:
        return (f"{self.model}[{self.mode_label}] "
                f"{self.placement_string} boundary={self.boundary} "
                f"plan={self.digest[:12]}")


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def linear_layers(cfg: ModelConfig) -> Optional[Tuple[bool, ...]]:
    """Per-layer "carries an individually-addressable linear op" mask.

    ``None`` for families whose blinded ops trace under ``lax.scan`` in
    the FORWARD/prefill trace (one traced call stands for many runtime
    layers): those ops can be blinded but neither positionally cached NOR
    per-op verified there — the DESIGN.md §4/§9 restriction. This is the
    single source of truth both the slot assigner and the verified-open
    constructors consult. It is a statement about the forward trace only:
    the DECODE walk of ``DECODE_FAMILIES`` is per-op addressable
    (``make_decode_plan`` / DESIGN.md §16), which is where per-step
    integrity for LM families lives."""
    if cfg.family != "cnn":
        return None
    from repro.models import vgg as V
    return tuple(V.layer_kind(cfg, i)[0] in ("conv", "fc", "logits")
                 for i in range(len(cfg.cnn_layers)))


def _assign_slots(cfg: ModelConfig,
                  steps: Sequence[LayerStep]) -> Tuple[LayerStep, ...]:
    linear = linear_layers(cfg)
    out, slot = [], 0
    for st in steps:
        ps = None
        if linear is not None and st.offloaded and linear[st.layer_id]:
            ps, slot = slot, slot + 1
        out.append(LayerStep(st.layer_id, st.placement, st.integrity, ps,
                             st.shard))
    return tuple(out)


def make_plan(cfg: ModelConfig, placements: Sequence[str], *,
              integrity: Optional[Dict[int, IG.IntegrityPolicy]] = None,
              boundary: Optional[int] = None,
              shard: Optional[Dict[int, ShardPolicy]] = None,
              label: str = "custom") -> PlacementPlan:
    """Build a plan from per-layer placement names.

    ``integrity``: {layer_id: policy} per-step overrides. ``shard``:
    {layer_id: ShardPolicy} per-step multi-device overrides. ``boundary``
    defaults to the start of the trailing open suffix — the deepest
    activation the plan actually reveals wholesale (0 for an all-open
    plan, n when the last layer is protected)."""
    n = num_blocks(cfg)
    placements = list(placements)
    assert len(placements) == n, (len(placements), n)
    integrity = integrity or {}
    shard = shard or {}
    if linear_layers(cfg) is None and any(
            p is not None and p.enabled for p in integrity.values()):
        # scanned families (LM/audio/vlm) trace many runtime layers
        # through one call in the FORWARD trace — per-op verification
        # cannot bind there, so an enabled per-step policy would be
        # silently unenforced. For an open step that is catastrophic: the
        # op would run UNBLINDED and UNCHECKED while the plan digest (and
        # the attestation quote) advertises verified offload. Fail at
        # compile time instead; token-wise per-step integrity for decode
        # is expressed through make_decode_plan's ScanSegments (§16).
        raise ScanExclusion(
            f"{cfg.name} ({cfg.family}): per-step integrity policies "
            "(verified-open 'v' placements) need per-op verification, "
            "which is unavailable for families whose ops trace under "
            "lax.scan in the forward trace — use 'blinded' placements "
            "with an executor-wide policy, or a decode plan "
            "(make_decode_plan) for token-wise verification "
            "(DESIGN.md §9/§10/§16)")
    if boundary is None:
        boundary = n
        while boundary > 0 and placements[boundary - 1] == "open":
            boundary -= 1
    steps = [LayerStep(i, p, integrity.get(i), shard=shard.get(i))
             for i, p in enumerate(placements)]
    return PlacementPlan(cfg.name, cfg.family, _assign_slots(cfg, steps),
                         boundary, label)


def compile_mode(cfg: ModelConfig, mode: str,
                 partition: Optional[int] = None) -> PlacementPlan:
    """Compile a legacy mode string (+ prefix partition) to a plan.

        open     all open                     boundary 0
        enclave  all enclave                  boundary n
        split    enclave^p + open^(n-p)       boundary p
        slalom   blinded everywhere           boundary n
        origami  blinded^p + open^(n-p)       boundary p
    """
    assert mode in LEGACY_MODES, mode
    n = num_blocks(cfg)
    p = partition if partition is not None else cfg.origami.tier1_layers
    if mode == "open":
        placements, boundary = ["open"] * n, 0
    elif mode == "enclave":
        placements, boundary = ["enclave"] * n, n
    elif mode == "slalom":
        placements, boundary = ["blinded"] * n, n
    elif mode == "split":
        placements, boundary = ["enclave"] * p + ["open"] * (n - p), p
    else:                                   # origami
        placements, boundary = ["blinded"] * p + ["open"] * (n - p), p
    return make_plan(cfg, placements, boundary=boundary, label=mode)


def from_string(cfg: ModelConfig, spec: str, *,
                verify: Optional[IG.IntegrityPolicy] = None,
                boundary: Optional[int] = None,
                label: Optional[str] = None) -> PlacementPlan:
    """Compact per-layer spec: one char per layer from ``oebv``
    (v = verified-open; its policy is ``verify`` or full(k=1))."""
    spec = spec.strip().lower()
    n = num_blocks(cfg)
    assert len(spec) == n, f"spec {spec!r} has {len(spec)} chars, want {n}"
    placements, integrity = [], {}
    for i, ch in enumerate(spec):
        assert ch in _CHAR_PLACEMENT, ch
        placements.append(_CHAR_PLACEMENT[ch])
        if ch == "v":
            integrity[i] = verify or IG.IntegrityPolicy.full(1)
    return make_plan(cfg, placements, integrity=integrity, boundary=boundary,
                     label=label or spec)


def make_mixed(cfg: ModelConfig, boundary: Optional[int] = None,
               blinded_prefix: Optional[int] = None,
               label: str = "mixed") -> PlacementPlan:
    """Mixed enclave/blinded tier-1 (inexpressible as a mode string):
    layers [0, blinded_prefix) blinded, [blinded_prefix, boundary)
    enclave-resident, the rest open. Default splits tier-1 in half."""
    n = num_blocks(cfg)
    p = boundary if boundary is not None else cfg.origami.tier1_layers
    b = blinded_prefix if blinded_prefix is not None else max(p // 2, 1)
    assert 0 <= b <= p <= n, (b, p, n)
    return make_plan(cfg, ["blinded"] * b + ["enclave"] * (p - b)
                     + ["open"] * (n - p), boundary=p, label=label)


def make_vopen(cfg: ModelConfig, boundary: Optional[int] = None,
               verify: Optional[IG.IntegrityPolicy] = None,
               label: str = "vopen") -> PlacementPlan:
    """Verified-open tier-2 (inexpressible as a mode string): blinded
    prefix up to ``boundary``, then every linear layer offloads unblinded
    under the ``verify`` Freivalds policy (default full(k=1)). Raises for
    scanned families — per-op verification cannot bind there
    (``linear_layers``), and unverified + unblinded is the worst of both
    worlds."""
    n = num_blocks(cfg)
    p = boundary if boundary is not None else cfg.origami.tier1_layers
    pol = verify or IG.IntegrityPolicy.full(1)
    linear = linear_layers(cfg)
    if linear is None:
        raise ScanExclusion(
            f"{cfg.name}: verified-open needs per-op verification in the "
            "forward trace (see linear_layers); for LM decode use "
            "make_decode_plan's verified scan segments (DESIGN.md §16)")
    integ = {i: pol for i in range(p, n) if linear[i]}
    return make_plan(cfg, ["blinded"] * p + ["open"] * (n - p),
                     integrity=integ, boundary=p, label=label)


def classify_legacy(plan: PlacementPlan) -> Optional[Tuple[str, int]]:
    """(mode, partition) iff the plan is exactly a legacy prefix shape
    with no per-step integrity overrides — lets the cost model delegate
    to the paper-calibrated per-mode formulas bit-for-bit."""
    if any(s.integrity is not None for s in plan.steps):
        return None
    ps = [s.placement for s in plan.steps]
    n, b = len(ps), plan.boundary
    if ps == ["open"] * n and b == 0:
        return "open", 0
    if ps == ["enclave"] * n and b == n:
        return "enclave", n
    if ps == ["blinded"] * n and b == n:
        return "slalom", n
    if ps == ["enclave"] * b + ["open"] * (n - b):
        return "split", b
    if ps == ["blinded"] * b + ["open"] * (n - b):
        return "origami", b
    return None


# ---------------------------------------------------------------------------
# decode plans: scan segments + token-slot binding (DESIGN.md §16)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScanSegment:
    """One decode-time segment: the per-token walk of blocks [lo, hi)
    under one execution regime, repeated for decode steps
    [steps[0], steps[1]).

    ``regime``/``policy``/``shard`` mirror ``Segment``, but the policy is
    *per step*: each token step re-derives its Freivalds fold vectors and
    (in sampled mode) its check/skip decisions from
    ``(session, op, token)``, so verification coverage is token-wise.
    ``slot_binding``: how the segment's blinded ops obtain factors —
    ``"token"`` (each step consumes the per-(session, token, layer) slot
    of a streaming TokenSlotRing; blinded and verified regimes) or
    ``"none"`` (plain segments touch no factor material)."""
    lo: int
    hi: int
    regime: str
    steps: Tuple[int, int]
    policy: Optional[IG.IntegrityPolicy] = None
    shard: Optional[ShardPolicy] = None
    slot_binding: str = "token"

    def __post_init__(self):
        assert self.regime in ("plain", "blinded", "verified"), self.regime
        assert self.slot_binding in ("token", "none"), self.slot_binding
        assert 0 <= self.steps[0] <= self.steps[1], self.steps


@dataclass(frozen=True)
class DecodePlan:
    """A PlacementPlan applied token-wise: the decode loop walks ``scan``
    once per token, carrying the KV caches and the token-slot cursor.

    ``digest`` extends the base plan's digest with the scan-segment
    structure and the step range, so the attestation quote and the AOT
    executable cache key a *decode* plan distinctly from the forward plan
    it was derived from (same property the forward digest has had since
    PR 4)."""
    base: PlacementPlan
    scan: Tuple[ScanSegment, ...]
    max_steps: int

    @cached_property
    def digest(self) -> str:
        body = {
            "base": self.base.digest,
            "max_steps": self.max_steps,
            "scan": [(s.lo, s.hi, s.regime, list(s.steps),
                      _policy_key(s.policy), _shard_key(s.shard),
                      s.slot_binding) for s in self.scan],
        }
        return hashlib.sha256(
            json.dumps(body, sort_keys=True).encode()).hexdigest()

    @property
    def has_offload(self) -> bool:
        return any(s.regime != "plain" for s in self.scan)

    @property
    def has_verification(self) -> bool:
        return any(s.regime != "plain" and s.policy is not None
                   and s.policy.enabled for s in self.scan)

    def summary(self) -> str:
        segs = " ".join(f"[{s.lo},{s.hi}){s.regime[0]}" for s in self.scan)
        return (f"{self.base.model}[decode] {segs} steps={self.max_steps} "
                f"plan={self.digest[:12]}")


def make_decode_plan(cfg: ModelConfig, plan: Optional[PlacementPlan] = None,
                     *, max_steps: int,
                     partition: Optional[int] = None,
                     integrity: Optional[IG.IntegrityPolicy] = None
                     ) -> DecodePlan:
    """Compile a decode plan: the base plan's segments applied token-wise.

    ``plan`` defaults to ``compile_mode(cfg, "origami", partition)``.
    ``integrity`` attaches a per-step Freivalds policy to every offloaded
    scan segment that has no per-step override of its own — legal here
    (unlike ``make_plan`` for scanned forward traces) because the decode
    walk is per-op addressable. Raises ScanExclusion for families outside
    DECODE_FAMILIES, with the documented structural reason."""
    if cfg.family not in DECODE_FAMILIES:
        reason = _DECODE_EXCLUSIONS.get(cfg.family, "no decode walk")
        raise ScanExclusion(
            f"{cfg.name} ({cfg.family}): private decode unavailable — "
            f"{reason} (DESIGN.md §16)")
    assert max_steps >= 1, max_steps
    if plan is None:
        plan = compile_mode(cfg, "origami", partition)
    scan = []
    for seg in plan.segments:
        policy = seg.policy
        if policy is None and seg.regime != "plain":
            policy = integrity
        scan.append(ScanSegment(
            seg.lo, seg.hi, seg.regime, (0, max_steps), policy, seg.shard,
            slot_binding="none" if seg.regime == "plain" else "token"))
    return DecodePlan(plan, tuple(scan), max_steps)


# ---------------------------------------------------------------------------
# per-family layer programs (the iterators the plan interpreter walks)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PlanProgram:
    """Family-specific walk: ``prologue(params, batch) -> (x, memory)``,
    ``segment(params, x, lo, hi, memory) -> x`` over blocks [lo, hi),
    ``epilogue(params, x, batch, memory) -> logits``. ``blind_convs``:
    whether the conv intercept applies inside blinded segments (CNNs)."""
    n_layers: int
    blind_convs: bool
    prologue: Callable
    segment: Callable
    epilogue: Callable


def program_for(cfg: ModelConfig) -> PlanProgram:
    if cfg.family == "cnn":
        from repro.models import vgg as V
        pro, seg, epi = V.layer_program(cfg)
        return PlanProgram(len(cfg.cnn_layers), True, pro, seg, epi)
    from repro.models import model as M
    pro, seg, epi = M.layer_program(cfg)
    return PlanProgram(cfg.num_layers, False, pro, seg, epi)
