"""Origami core: blinding, Slalom protocol, precompute, executor, trust,
partition planner."""
from repro.core.blinding import BlindingSpec
from repro.core.origami import MODES, OrigamiExecutor, OrigamiResult
from repro.core.planner import PartitionPlan, PartitionPlanner
from repro.core.precompute import BlindedLayerCache
from repro.core.slalom import SlalomContext, Telemetry, blinded_dense
from repro.core.trust import EnclaveParams, EnclaveSim

__all__ = ["BlindingSpec", "BlindedLayerCache", "MODES", "OrigamiExecutor",
           "OrigamiResult", "PartitionPlan", "PartitionPlanner",
           "SlalomContext", "Telemetry", "blinded_dense",
           "EnclaveParams", "EnclaveSim"]
