"""Origami core: blinding, Slalom protocol, two-tier executor, trust model."""
from repro.core.blinding import BlindingSpec
from repro.core.origami import MODES, OrigamiExecutor, OrigamiResult
from repro.core.slalom import SlalomContext, Telemetry, blinded_dense
from repro.core.trust import EnclaveParams, EnclaveSim

__all__ = ["BlindingSpec", "MODES", "OrigamiExecutor", "OrigamiResult",
           "SlalomContext", "Telemetry", "blinded_dense", "EnclaveParams",
           "EnclaveSim"]
