"""Slalom protocol: per-linear-op blinded offload (the tier-1 inner loop).

``blinded_dense(p, x, ...)`` is a drop-in for models.layers.dense:

    enclave:   x_q = Quant(x);  x_b = (x_q + r) mod p
    device:    y_b = (x_b @ W_q) mod p            <- limb_matmul kernel
    enclave:   y   = Dequant((y_b - r@W_q) mod p) (+ bias, fp)

The protocol only applies to *static-weight* linear maps (unblinding needs
the precomputable r·W) — exactly Slalom's constraint; attention cores,
recurrences and non-linearities stay in the enclave during tier-1
(DESIGN.md §3, §5).

Two data-path implementations (``SlalomContext.impl``):

- ``"fused"`` (default): one Pallas pass blinds + limb-encodes the
  activations, the limb matmul's epilogue unblinds + dequantizes
  in-register — the blinded operand makes exactly one HBM round trip
  (DESIGN.md §6).
- ``"unfused"``: the seed path (separate blind, limb-decompose, matmul,
  unblind passes), kept selectable for benchmarks/blinding_micro.py.

When ``SlalomContext.factors`` is set (core/precompute.py), the weight
quantization/limb encoding and the unblinding-factor matmul ``u = r @ W_q``
are *precomputed off the request path* — the traced request performs exactly
one device field-matmul per blinded op, mirroring the paper's offline
enclave precomputation. ``Telemetry.device_matmuls``/``enclave_matmuls``
count both kinds so tests can verify the claim.

Integrity (PR 3, DESIGN.md §9): the device result is *verified*, not just
trusted — ``ctx.integrity`` threads a Freivalds policy (core/integrity.py)
through every blinded op, ``ctx.fault`` injects a dishonest device
(runtime/faults.py) underneath it, and ``ctx.trusted`` switches the op to
an enclave-resident field matmul (the recovery path: bit-identical output,
no device, no blinding needed).

A trace-time ``Telemetry`` recorder accumulates blinded bytes / offloaded
FLOPs / enclave FLOPs per protocol call — shapes are static under jit, so
this is exact and free; core/trust.py turns it into the paper's cost model.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field as dfield
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.core import blinding as B
from repro.core import integrity as IG
from repro.core import tracing
from repro.kernels.blind.ref import quantize as quantize_act
from repro.kernels.limb_matmul.ops import (encode_weight_planes, field_matmul,
                                           fused_blinded_matmul)
from repro.kernels.limb_matmul.ref import P, from_signed, to_signed

# fault keys live in their own fold_in domain, disjoint from both the
# blinding streams and the verify keys (core/integrity.py)
FAULT_DOMAIN = 0xFA17


@dataclass
class Telemetry:
    """Static-shape accounting gathered while tracing (bytes, FLOPs)."""
    blinded_bytes: int = 0          # enclave->device blinded traffic
    returned_bytes: int = 0         # device->enclave results
    offloaded_flops: int = 0        # linear-op FLOPs run untrusted
    enclave_flops: int = 0          # blinding/unblinding elementwise work
    enclave_peak_feature_bytes: int = 0
    calls: int = 0
    device_matmuls: int = 0         # field matmuls in the request trace
    enclave_matmuls: int = 0        # r@W_q factor matmuls in the trace
                                    # (0 when the precompute cache is active)
    verify_ops: int = 0             # blinded ops with verification in-trace
    verify_flops: int = 0           # fold-check work (enclave-side)
    fold_matmuls: int = 0           # on-request W_q@s folds (0 when the
                                    # precompute cache carries the vectors)
    trusted_matmuls: int = 0        # enclave-recompute field matmuls

    def record_verify(self, t: int, d_in: int, d_out: int, k: int):
        self.verify_ops += 1
        self.verify_flops += 2 * k * t * (d_in + d_out)

    def record_trusted(self, t: int, d_in: int, d_out: int):
        self.trusted_matmuls += 1
        self.enclave_flops += 2 * t * d_in * d_out

    def record_offload(self, t: int, d_in: int, d_out: int):
        self.blinded_bytes += t * d_in * 4
        self.returned_bytes += t * d_out * 4
        self.offloaded_flops += 2 * t * d_in * d_out
        # blind + unblind touch every element once each
        self.enclave_flops += 2 * t * (d_in + d_out)
        self.enclave_peak_feature_bytes = max(
            self.enclave_peak_feature_bytes, t * max(d_in, d_out) * 4)
        self.calls += 1


@dataclass
class SlalomContext:
    """Session state for one private-inference request.

    ``factors``: per-layer precomputed blinding material from
    ``BlindedLayerCache.session_factors`` (consumed positionally, in call
    order). ``integrity``/``fault``: Freivalds policy and dishonest-device
    injector (core/integrity.py, runtime/faults.py); ``integrity_log``
    collects one (checked, failed, corrupted) bool triple per blinded op.
    ``trusted``: enclave-recompute mode — no device, no blinding, no
    verification. ``unblinded``: verified-open offload (core/plan.py) —
    the device gets the quantized operand with a ZERO pad (no privacy) and
    the factor matmul vanishes (u = 0·W); verification still applies.
    ``plane``: a parallel/offload_sharding.OffloadPlane — when set, the
    device field matmul of every per-op-addressable blinded op shards
    across the plane's DevicePool (shard-local Freivalds, per-device
    health); ``shard`` is the per-segment ShardPolicy override.
    ``integrity``/``unblinded``/``shard`` are per-plan-segment state: the
    plan interpreter scopes them with ``segment_overrides`` while tracing.
    """
    session_key: jax.Array
    spec: B.BlindingSpec = dfield(default_factory=B.BlindingSpec)
    telemetry: Telemetry = dfield(default_factory=Telemetry)
    # stream-key step component. An int for single-shot traces; the decode
    # interpreter (core/origami.py) sets it to the TRACED token position so
    # one compiled token-step executable draws fresh per-token pads, fold
    # vectors and sampling decisions (fold_in accepts traced ints).
    step: Any = 0
    impl: str = "fused"                       # "fused" | "unfused"
    factors: Optional[List[Any]] = None
    integrity: IG.IntegrityPolicy = dfield(
        default_factory=IG.IntegrityPolicy.off)
    fault: Optional[Any] = None               # runtime/faults.DishonestDevice
    trusted: bool = False
    unblinded: bool = False
    plane: Optional[Any] = None               # offload_sharding.OffloadPlane
    shard: Optional[Any] = None               # plan.ShardPolicy override
    # per-op addressability verdict override. The default (None) infers
    # "scanned" from the weight leaf being a tracer — right for forward
    # traces, where a tracer weight means lax.scan over stacked blocks.
    # The decode interpreter unrolls the block walk at trace time, so its
    # weights are tracers (jit args) yet every op IS individually
    # addressable: it sets per_op=True and verification/injection bind.
    per_op: Optional[bool] = None
    integrity_log: List[Any] = dfield(default_factory=list)
    _layer_counter: int = 0

    @contextmanager
    def segment_overrides(self, integrity: Optional[IG.IntegrityPolicy],
                          unblinded: bool = False, shard: Optional[Any] = None):
        """Scope the effective verification policy / unblinded flag /
        shard policy to one plan segment (trace-time Python state, static
        under jit)."""
        prev = self.integrity, self.unblinded, self.shard
        if integrity is not None:
            self.integrity = integrity
        self.unblinded = unblinded
        if shard is not None:
            self.shard = shard
        try:
            yield self
        finally:
            self.integrity, self.unblinded, self.shard = prev

    def next_layer_key(self) -> jax.Array:
        k = B.stream_key(self.session_key, self._layer_counter, self.step)
        self._layer_counter += 1
        return k

    def fault_key(self, op_index: int) -> jax.Array:
        return B.stream_key(
            jax.random.fold_in(self.session_key, FAULT_DOMAIN),
            op_index, self.step)

    def next_layer_factors(self, t: int, d_in: int, d_out: int, w):
        """Blinding + verification material for the next blinded op.

        Returns (w_q, w_scale, w_limbs_or_None, r, u, s, ws, shard_folds).
        The cached branch issues no field matmul; the on-the-fly branch
        issues one for ``u`` (telemetry.enclave_matmuls) and, when
        verification is on and the cache carries no fold vectors, one
        skinny ``W_q @ s`` fold. ``shard_folds`` is the per-shard
        (s_j, ws_j) list the offload plane consumes (prefetched by the
        cache when its ``shards`` > 1; the plane derives it live otherwise).
        """
        op = self._layer_counter
        sf = None
        if self.factors is not None:
            assert op < len(self.factors), (
                f"precompute cache has {len(self.factors)} layers but the "
                f"trace reached blinded op #{op} — rebuild the cache for "
                f"this batch shape/partition")
            self._layer_counter += 1
            e = self.factors[op]
            w_q, w_scale = e["w_q"], e["w_scale"]
            w_limbs, r, u = e.get("w_limbs"), e["r"], e["u"]
            if r is None:
                # verified-open slot (precompute.py stores no arrays for
                # the zero pad): synthesize it in-trace — a jit constant,
                # not per-session device memory
                r = jnp.zeros((t, d_in), jnp.int32)
                u = jnp.zeros((t, d_out), jnp.int32)
            else:
                assert e["r"].shape == (t, d_in), (
                    f"cached stream shape {e['r'].shape} != ({t}, {d_in}) — "
                    f"cache was built for a different batch shape")
            s, ws = e.get("s"), e.get("ws")
            sf = e.get("shard_folds")
        elif self.unblinded:
            # verified-open offload: zero pad, so u = (0 @ W) = 0 — no
            # factor matmul exists to pay for (or precompute)
            self._layer_counter += 1
            w_q, w_scale = B.quantize_weight(w, self.spec)
            r = jnp.zeros((t, d_in), jnp.int32)
            u = jnp.zeros((t, d_out), jnp.int32)
            w_limbs = s = ws = None
        else:
            key = self.next_layer_key()
            w_q, w_scale = B.quantize_weight(w, self.spec)
            r = B.blinding_stream(key, (t, d_in))
            u = B.unblinding_factor(r, w_q)     # on-request (Slalom does this
            self.telemetry.enclave_matmuls += 1  # offline; see precompute.py)
            w_limbs = s = ws = None
        if self.integrity.enabled and s is None:
            # same derivation as BlindedLayerCache.session_factors, so the
            # cached and live verification traces are bit-identical
            s = IG.fold_stream(self.session_key, op, self.step,
                               d_out, self.integrity.k)
            ws = field_matmul(w_q, s)
            self.telemetry.fold_matmuls += 1    # on the request path — the
            self.telemetry.verify_flops += (    # cache moves these offline
                2 * d_in * d_out * self.integrity.k)
        return w_q, w_scale, w_limbs, r, u, s, ws, sf


def blinded_dense(ctx: SlalomContext, p, x, scanned: Optional[bool] = None):
    """Drop-in for layers.dense running the Slalom protocol.

    p: {"w": (d_in, d_out) float [, "b": (d_out,)]}; x: (..., d_in).
    ``scanned``: whether this op's weight leaf is a lax.scan tracer (one
    traced call standing for many runtime layers); None = infer from ``w``
    itself — callers that transform the weight first (blinded_conv2d's
    im2col reorder turns a concrete leaf into a tracer) must pass the
    verdict on the RAW leaf.
    """
    # per-op trace span — eager traces only (plane path / recoveries);
    # attributes are shapes and placement flags, never operands
    if not isinstance(x, jax.core.Tracer):
        with tracing.maybe_span(
                "op.trusted" if ctx.trusted else "op.blinded", "step",
                layer=ctx._layer_counter, d_in=int(p["w"].shape[0]),
                d_out=int(p["w"].shape[1]),
                verified_open=bool(ctx.unblinded)):
            return _blinded_dense(ctx, p, x, scanned)
    return _blinded_dense(ctx, p, x, scanned)


def _blinded_dense(ctx: SlalomContext, p, x,
                   scanned: Optional[bool] = None):
    w = p["w"]
    d_in, d_out = w.shape
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    xt = x.reshape(t, d_in)

    spec = ctx.spec
    k_out = spec.k_act + spec.k_w
    op_index = ctx._layer_counter

    if ctx.trusted:
        # --- enclave recompute (integrity recovery / quarantined backend):
        # the enclave performs the field matmul itself. Blinding would
        # cancel exactly ((x_b@W − r@W) mod p == (x_q@W) mod p), so it is
        # skipped; the quantized math and float op order match the blinded
        # data path bit-for-bit, which is what makes a recovered response
        # indistinguishable from an honest device's (tests/test_integrity).
        ctx._layer_counter += 1
        w_q, w_scale = B.quantize_weight(w, spec)
        x_scale = jnp.maximum(jnp.max(jnp.abs(xt.astype(jnp.float32))), 1e-9)
        # fused blinds with multiply-by-reciprocal, unfused with division —
        # replicate the active impl so the recompute stays bit-identical
        xs = (xt.astype(jnp.float32) * (1.0 / x_scale) if ctx.impl == "fused"
              else xt.astype(jnp.float32) / x_scale)
        y_field = field_matmul(from_signed(quantize_act(xs, spec.k_act)), w_q)
        y = (to_signed(y_field).astype(jnp.float32)
             * (x_scale * w_scale)) * (2.0 ** -k_out)
        ctx.telemetry.record_trusted(t, d_in, d_out)
        if "b" in p:
            y = y + p["b"].astype(jnp.float32)
        return y.reshape(lead + (d_out,)).astype(x.dtype)

    # --- enclave: weight quantization + blinding material (precomputed when
    # the cache is active, otherwise derived on the request path) ---
    w_q, w_scale, w_limbs, r, u, s, ws, sf = ctx.next_layer_factors(
        t, d_in, d_out, w)
    # verification/injection cannot bind per-op state for ops traced inside
    # lax.scan (one traced call stands for many runtime layers, and traced
    # values appended to integrity_log would leak out of the scan) — same
    # restriction as the precompute cache; such ops stay unverified. The
    # decode interpreter's unrolled walk overrides the verdict via
    # ctx.per_op: its weights are jit-arg tracers but each traced call
    # stands for exactly one runtime op (DESIGN.md §16).
    if scanned is None:
        if ctx.per_op is not None:
            scanned = not ctx.per_op
        else:
            scanned = isinstance(w, jax.core.Tracer)
    # --- enclave: per-request absmax activation scale ---
    x_scale = jnp.maximum(jnp.max(jnp.abs(xt.astype(jnp.float32))), 1e-9)
    if ctx.plane is not None and not scanned:
        # --- multi-device plane: the device matmul shards across the pool
        # (parallel/offload_sharding.py) with shard-local Freivalds checks,
        # single-shard retry and straggler hedging — host-side control
        # flow, so the executor runs this trace eagerly (core/origami.py).
        # Faults are per-device (pool slots), not executor-wide, and every
        # shard is checked, so the op-level log records a verified op with
        # no *unrecovered* failure (the plane's ShardReport carries
        # detection/retry counts and the pool the per-device health).
        k_out = spec.k_act + spec.k_w
        if ctx.impl == "fused":
            # replicate the fused kernel's quantization exactly (multiply
            # by reciprocal; kernels/blind/ref.py is the kernel oracle) so
            # the sharded result is bit-identical to fused_blinded_matmul
            xs = xt.astype(jnp.float32) * (1.0 / x_scale)
        else:
            xs = xt.astype(jnp.float32) / x_scale
        x_b = jnp.mod(from_signed(quantize_act(xs, spec.k_act)) + r, P)
        y_b = ctx.plane.matmul(
            x_b, w_q, session_key=ctx.session_key, op_index=op_index,
            step=ctx.step, k=ctx.integrity.k if ctx.integrity.enabled else 1,
            folds=sf,
            mode=ctx.shard.mode if ctx.shard is not None else None,
            group=ctx.shard.devices if ctx.shard is not None else None)
        if ctx.impl == "fused":
            out_scale = x_scale * w_scale * (2.0 ** -k_out)
            y = (to_signed(jnp.mod(y_b - u + P, P)).astype(jnp.float32)
                 * out_scale)
        else:
            y = B.unblind_result(y_b, u, spec, out_dtype=jnp.float32)
            y = y * (x_scale * w_scale)
        ctx.integrity_log.append((jnp.bool_(True), jnp.bool_(False),
                                  jnp.bool_(False)))
        ctx.telemetry.record_verify(t, d_in, d_out,
                                    ctx.integrity.k
                                    if ctx.integrity.enabled else 1)
        ctx.telemetry.device_matmuls += 1
        if "b" in p:
            y = y + p["b"].astype(jnp.float32)
        ctx.telemetry.record_offload(t, d_in, d_out)
        return y.reshape(lead + (d_out,)).astype(x.dtype)

    verify = ctx.integrity.enabled and not scanned
    inject = ctx.fault is not None and not scanned
    will_check = (IG.decide(ctx.integrity, ctx.session_key, op_index,
                            ctx.step) if verify or inject else None)
    checked = failed = corrupted = None
    if ctx.impl == "fused":
        if w_limbs is None:
            w_limbs = encode_weight_planes(w_q)
        out_scale = x_scale * w_scale * (2.0 ** -k_out)
        y = fused_blinded_matmul(
            xt.astype(jnp.float32), r, w_limbs, u, 1.0 / x_scale, out_scale,
            k_bits=spec.k_act, k_out_bits=k_out)
        if verify or inject:
            # the fused kernel unblinds+dequantizes in-register; recover the
            # signed field result exactly (|y_q| ≤ HALF < 2^22 and the only
            # inexact step is one f32 multiply, so round() inverts it)
            y_q = jnp.round(y / out_scale).astype(jnp.int32)
            y_field = from_signed(y_q)
            if inject:
                y_field, corrupted = ctx.fault.corrupt(
                    y_field, op_index=op_index, key=ctx.fault_key(op_index),
                    will_verify=will_check)
            if verify:
                # post-unblind identity: y_q @ s ≡ x_q @ ws (mod p); x_q is
                # the enclave's own quantization of its own activations
                # (bit-identical to the kernel's: same reciprocal, same
                # round/clip — kernels/blind/ref.py is the kernel oracle)
                x_field = from_signed(quantize_act(
                    xt.astype(jnp.float32) * (1.0 / x_scale), spec.k_act))
                checked, failed = IG.checked_pair(
                    y_field, x_field, s, ws, will_check,
                    always=ctx.integrity.mode == "full")
            y = to_signed(y_field).astype(jnp.float32) * out_scale
    else:
        # --- seed path: blind, device field-matmul, unblind (3 HBM trips) ---
        x_b = B.blind_activations(xt.astype(jnp.float32) / x_scale, r, spec)
        y_b = field_matmul(x_b, w_q)
        if inject:
            y_b, corrupted = ctx.fault.corrupt(
                y_b, op_index=op_index, key=ctx.fault_key(op_index),
                will_verify=will_check)
        if verify:
            # blinded-domain identity: y_b @ s ≡ x_b @ ws (mod p)
            checked, failed = IG.checked_pair(
                y_b, x_b, s, ws, will_check,
                always=ctx.integrity.mode == "full")
        y = B.unblind_result(y_b, u, spec, out_dtype=jnp.float32)
        y = y * (x_scale * w_scale)
    if verify or inject:
        false = jnp.bool_(False)
        ctx.integrity_log.append((
            checked if checked is not None else false,
            failed if failed is not None else false,
            corrupted if corrupted is not None else false))
        if verify:
            ctx.telemetry.record_verify(t, d_in, d_out, ctx.integrity.k)
    ctx.telemetry.device_matmuls += 1
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    ctx.telemetry.record_offload(t, d_in, d_out)
    return y.reshape(lead + (d_out,)).astype(x.dtype)


def extract_patches(x, kh: int, kw: int, stride: int = 1):
    """NHWC SAME patch extraction as one strided-slice XLA op.

    Returns (B·Ho·Wo, cin·kh·kw) with channel-major ordering (c, i, j) —
    pair with ``conv_weight_cols``. Replaces the kh·kw-times-materialized
    Python-loop im2col (which built kh·kw full-size slices and concatenated
    them in HBM before blinding).
    """
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return patches.reshape(-1, patches.shape[-1]), patches.shape[:3]


def conv_weight_cols(w):
    """(kh, kw, cin, cout) -> (cin·kh·kw, cout), matching extract_patches."""
    kh, kw, cin, cout = w.shape
    return jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)


def blinded_conv2d(ctx: SlalomContext, p, x, stride: int = 1):
    """Blinded 3x3 SAME conv via patch extraction -> blinded matmul.

    On TPU convolutions lower to MXU matmuls anyway; im2col + limb matmul is
    the faithful field-arithmetic equivalent. The patch tensor feeds the
    fused blind->limb-encode kernel directly.
    """
    w = p["w"]                                # (kh, kw, cin, cout)
    kh, kw, cin, cout = w.shape
    xcol, out_hw = extract_patches(x, kh, kw, stride)
    y = blinded_dense(ctx, {"w": conv_weight_cols(w), "b": p["b"]}, xcol,
                      scanned=isinstance(w, jax.core.Tracer))
    return y.reshape(out_hw + (cout,))
