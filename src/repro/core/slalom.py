"""Slalom protocol: per-linear-op blinded offload (the tier-1 inner loop).

``blinded_dense(p, x, ...)`` is a drop-in for models.layers.dense:

    enclave:   x_q = Quant(x);  x_b = (x_q + r) mod p
    device:    y_b = (x_b @ W_q) mod p            <- limb_matmul kernel
    enclave:   y   = Dequant((y_b - r@W_q) mod p) (+ bias, fp)

The protocol only applies to *static-weight* linear maps (unblinding needs
the precomputable r·W) — exactly Slalom's constraint; attention cores,
recurrences and non-linearities stay in the enclave during tier-1
(DESIGN.md §3, §5).

Two data-path implementations (``SlalomContext.impl``):

- ``"fused"`` (default): one Pallas pass blinds + limb-encodes the
  activations, the limb matmul's epilogue unblinds + dequantizes
  in-register — the blinded operand makes exactly one HBM round trip
  (DESIGN.md §6).
- ``"unfused"``: the seed path (separate blind, limb-decompose, matmul,
  unblind passes), kept selectable for benchmarks/blinding_micro.py.

When ``SlalomContext.factors`` is set (core/precompute.py), the weight
quantization/limb encoding and the unblinding-factor matmul ``u = r @ W_q``
are *precomputed off the request path* — the traced request performs exactly
one device field-matmul per blinded op, mirroring the paper's offline
enclave precomputation. ``Telemetry.device_matmuls``/``enclave_matmuls``
count both kinds so tests can verify the claim.

A trace-time ``Telemetry`` recorder accumulates blinded bytes / offloaded
FLOPs / enclave FLOPs per protocol call — shapes are static under jit, so
this is exact and free; core/trust.py turns it into the paper's cost model.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dfield
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.core import blinding as B
from repro.kernels.limb_matmul.ops import (encode_weight_planes, field_matmul,
                                           fused_blinded_matmul)


@dataclass
class Telemetry:
    """Static-shape accounting gathered while tracing (bytes, FLOPs)."""
    blinded_bytes: int = 0          # enclave->device blinded traffic
    returned_bytes: int = 0         # device->enclave results
    offloaded_flops: int = 0        # linear-op FLOPs run untrusted
    enclave_flops: int = 0          # blinding/unblinding elementwise work
    enclave_peak_feature_bytes: int = 0
    calls: int = 0
    device_matmuls: int = 0         # field matmuls in the request trace
    enclave_matmuls: int = 0        # r@W_q factor matmuls in the trace
                                    # (0 when the precompute cache is active)

    def record_offload(self, t: int, d_in: int, d_out: int):
        self.blinded_bytes += t * d_in * 4
        self.returned_bytes += t * d_out * 4
        self.offloaded_flops += 2 * t * d_in * d_out
        # blind + unblind touch every element once each
        self.enclave_flops += 2 * t * (d_in + d_out)
        self.enclave_peak_feature_bytes = max(
            self.enclave_peak_feature_bytes, t * max(d_in, d_out) * 4)
        self.calls += 1


@dataclass
class SlalomContext:
    """Session state for one private-inference request.

    ``factors``: per-layer precomputed blinding material from
    ``BlindedLayerCache.session_factors`` (consumed positionally, in call
    order). ``recorder``: when set, blinded ops record their (weight, shape)
    instead of blinding — used by the cache builder under ``jax.eval_shape``.
    """
    session_key: jax.Array
    spec: B.BlindingSpec = dfield(default_factory=B.BlindingSpec)
    telemetry: Telemetry = dfield(default_factory=Telemetry)
    step: int = 0
    impl: str = "fused"                       # "fused" | "unfused"
    factors: Optional[List[Any]] = None
    recorder: Optional[List[Any]] = None
    _layer_counter: int = 0

    def next_layer_key(self) -> jax.Array:
        k = B.stream_key(self.session_key, self._layer_counter, self.step)
        self._layer_counter += 1
        return k

    def next_layer_factors(self, t: int, d_in: int, w):
        """Blinding material for the next blinded op, cached or on-the-fly.

        Returns (w_q, w_scale, w_limbs_or_None, r, u). The cached branch
        issues no field matmul; the on-the-fly branch issues one (counted in
        telemetry.enclave_matmuls).
        """
        if self.factors is not None:
            i = self._layer_counter
            assert i < len(self.factors), (
                f"precompute cache has {len(self.factors)} layers but the "
                f"trace reached blinded op #{i} — rebuild the cache for "
                f"this batch shape/partition")
            self._layer_counter += 1
            e = self.factors[i]
            assert e["r"].shape == (t, d_in), (
                f"cached stream shape {e['r'].shape} != ({t}, {d_in}) — "
                f"cache was built for a different batch shape")
            return e["w_q"], e["w_scale"], e.get("w_limbs"), e["r"], e["u"]
        key = self.next_layer_key()
        w_q, w_scale = B.quantize_weight(w, self.spec)
        r = B.blinding_stream(key, (t, d_in))
        u = B.unblinding_factor(r, w_q)       # on-request (Slalom does this
        self.telemetry.enclave_matmuls += 1   # offline; see precompute.py)
        return w_q, w_scale, None, r, u


def blinded_dense(ctx: SlalomContext, p, x):
    """Drop-in for layers.dense running the Slalom protocol.

    p: {"w": (d_in, d_out) float [, "b": (d_out,)]}; x: (..., d_in).
    """
    w = p["w"]
    d_in, d_out = w.shape
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    xt = x.reshape(t, d_in)

    if ctx.recorder is not None:
        # cache-builder trace: record the concrete weight leaf (a transform
        # of it would be a tracer and leak out of eval_shape), run plain fp.
        # Weights seen through lax.scan are tracers — one traced call stands
        # for many runtime layers, so positional caching can't apply; mark
        # the record and let the executor fall back to on-the-fly factors.
        kind = "scanned" if isinstance(w, jax.core.Tracer) else "dense"
        ctx.recorder.append({"kind": kind, "w": None if kind == "scanned"
                             else w, "t": t, "d_in": d_in, "d_out": d_out})
        y = xt.astype(jnp.float32) @ w.astype(jnp.float32)
        if "b" in p:
            y = y + p["b"].astype(jnp.float32)
        return y.reshape(lead + (d_out,)).astype(x.dtype)

    spec = ctx.spec
    # --- enclave: weight quantization + blinding material (precomputed when
    # the cache is active, otherwise derived on the request path) ---
    w_q, w_scale, w_limbs, r, u = ctx.next_layer_factors(t, d_in, w)
    # --- enclave: per-request absmax activation scale ---
    x_scale = jnp.maximum(jnp.max(jnp.abs(xt.astype(jnp.float32))), 1e-9)
    k_out = spec.k_act + spec.k_w
    if ctx.impl == "fused":
        if w_limbs is None:
            w_limbs = encode_weight_planes(w_q)
        out_scale = x_scale * w_scale * (2.0 ** -k_out)
        y = fused_blinded_matmul(
            xt.astype(jnp.float32), r, w_limbs, u, 1.0 / x_scale, out_scale,
            k_bits=spec.k_act, k_out_bits=k_out)
    else:
        # --- seed path: blind, device field-matmul, unblind (3 HBM trips) ---
        x_b = B.blind_activations(xt.astype(jnp.float32) / x_scale, r, spec)
        y_b = field_matmul(x_b, w_q)
        y = B.unblind_result(y_b, u, spec, out_dtype=jnp.float32)
        y = y * (x_scale * w_scale)
    ctx.telemetry.device_matmuls += 1
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    ctx.telemetry.record_offload(t, d_in, d_out)
    return y.reshape(lead + (d_out,)).astype(x.dtype)


def extract_patches(x, kh: int, kw: int, stride: int = 1):
    """NHWC SAME patch extraction as one strided-slice XLA op.

    Returns (B·Ho·Wo, cin·kh·kw) with channel-major ordering (c, i, j) —
    pair with ``conv_weight_cols``. Replaces the kh·kw-times-materialized
    Python-loop im2col (which built kh·kw full-size slices and concatenated
    them in HBM before blinding).
    """
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return patches.reshape(-1, patches.shape[-1]), patches.shape[:3]


def conv_weight_cols(w):
    """(kh, kw, cin, cout) -> (cin·kh·kw, cout), matching extract_patches."""
    kh, kw, cin, cout = w.shape
    return jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)


def blinded_conv2d(ctx: SlalomContext, p, x, stride: int = 1):
    """Blinded 3x3 SAME conv via patch extraction -> blinded matmul.

    On TPU convolutions lower to MXU matmuls anyway; im2col + limb matmul is
    the faithful field-arithmetic equivalent. The patch tensor feeds the
    fused blind->limb-encode kernel directly.
    """
    w = p["w"]                                # (kh, kw, cin, cout)
    kh, kw, cin, cout = w.shape
    xcol, out_hw = extract_patches(x, kh, kw, stride)
    if ctx.recorder is not None:
        # record the raw (kh,kw,cin,cout) param leaf; the cache builder
        # reorders it to im2col columns outside the trace
        ctx.recorder.append({"kind": "conv", "w": w, "t": xcol.shape[0],
                             "d_in": kh * kw * cin, "d_out": cout})
        y = xcol.astype(jnp.float32) @ conv_weight_cols(w).astype(jnp.float32)
        y = y + p["b"].astype(jnp.float32)
        return y.reshape(out_hw + (cout,)).astype(x.dtype)
    y = blinded_dense(ctx, {"w": conv_weight_cols(w), "b": p["b"]}, xcol)
    return y.reshape(out_hw + (cout,))
