"""Slalom protocol: per-linear-op blinded offload (the tier-1 inner loop).

``blinded_dense(p, x, ...)`` is a drop-in for models.layers.dense:

    enclave:   x_q = Quant(x);  x_b = (x_q + r) mod p
    device:    y_b = (x_b @ W_q) mod p            <- limb_matmul kernel
    enclave:   y   = Dequant((y_b - r@W_q) mod p) (+ bias, fp)

The protocol only applies to *static-weight* linear maps (unblinding needs
the precomputable r·W) — exactly Slalom's constraint; attention cores,
recurrences and non-linearities stay in the enclave during tier-1
(DESIGN.md §3, §5).

A trace-time ``Telemetry`` recorder accumulates blinded bytes / offloaded
FLOPs / enclave FLOPs per protocol call — shapes are static under jit, so
this is exact and free; core/trust.py turns it into the paper's cost model.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dfield
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import blinding as B
from repro.kernels.limb_matmul.ops import field_matmul


@dataclass
class Telemetry:
    """Static-shape accounting gathered while tracing (bytes, FLOPs)."""
    blinded_bytes: int = 0          # enclave->device blinded traffic
    returned_bytes: int = 0         # device->enclave results
    offloaded_flops: int = 0        # linear-op FLOPs run untrusted
    enclave_flops: int = 0          # blinding/unblinding elementwise work
    enclave_peak_feature_bytes: int = 0
    calls: int = 0

    def record_offload(self, t: int, d_in: int, d_out: int):
        self.blinded_bytes += t * d_in * 4
        self.returned_bytes += t * d_out * 4
        self.offloaded_flops += 2 * t * d_in * d_out
        # blind + unblind touch every element once each
        self.enclave_flops += 2 * t * (d_in + d_out)
        self.enclave_peak_feature_bytes = max(
            self.enclave_peak_feature_bytes, t * max(d_in, d_out) * 4)
        self.calls += 1


@dataclass
class SlalomContext:
    """Session state for one private-inference request."""
    session_key: jax.Array
    spec: B.BlindingSpec = dfield(default_factory=B.BlindingSpec)
    telemetry: Telemetry = dfield(default_factory=Telemetry)
    step: int = 0
    _layer_counter: int = 0

    def next_layer_key(self) -> jax.Array:
        k = B.stream_key(self.session_key, self._layer_counter, self.step)
        self._layer_counter += 1
        return k


def blinded_dense(ctx: SlalomContext, p, x):
    """Drop-in for layers.dense running the Slalom protocol.

    p: {"w": (d_in, d_out) float [, "b": (d_out,)]}; x: (..., d_in).
    """
    w = p["w"]
    d_in, d_out = w.shape
    lead = x.shape[:-1]
    t = 1
    for s in lead:
        t *= s
    xt = x.reshape(t, d_in)

    spec = ctx.spec
    # --- enclave: quantize weights (offline in deployment), draw the pad ---
    w_q, w_scale = B.quantize_weight(w, spec)
    r = B.blinding_stream(ctx.next_layer_key(), (t, d_in))
    u = B.unblinding_factor(r, w_q)          # precomputed (Slalom §4)
    # --- enclave: per-request absmax activation scale + blind ---
    x_scale = jnp.maximum(jnp.max(jnp.abs(xt.astype(jnp.float32))), 1e-9)
    x_b = B.blind_activations(xt.astype(jnp.float32) / x_scale, r, spec)
    # --- untrusted device: modular matmul on blinded data ---
    y_b = field_matmul(x_b, w_q)
    # --- enclave: unblind + dequantize (+ fp bias) ---
    y = B.unblind_result(y_b, u, spec, out_dtype=jnp.float32)
    y = y * (x_scale * w_scale)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    ctx.telemetry.record_offload(t, d_in, d_out)
    return y.reshape(lead + (d_out,)).astype(x.dtype)


def blinded_conv2d(ctx: SlalomContext, p, x, stride: int = 1):
    """Blinded 3x3 SAME conv via im2col -> blinded matmul (VGG tier-1).

    On TPU convolutions lower to MXU matmuls anyway; im2col + limb matmul is
    the faithful field-arithmetic equivalent.
    """
    w = p["w"]                                # (kh, kw, cin, cout)
    kh, kw, cin, cout = w.shape
    B_, H, W_, _ = x.shape
    xp = jnp.pad(x, ((0, 0), (kh // 2, kh // 2), (kw // 2, kw // 2), (0, 0)))
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(xp[:, i:i + H:stride, j:j + W_:stride, :])
    xcol = jnp.concatenate(cols, axis=-1).reshape(B_ * H * W_, kh * kw * cin)
    wcol = w.reshape(kh * kw * cin, cout)
    y = blinded_dense(ctx, {"w": wcol, "b": p["b"]}, xcol)
    return y.reshape(B_, H, W_, cout)
