"""Request-scoped span tracing with mandatory privacy redaction.

Origami's pipeline crosses many stages with wildly different costs —
seal -> queue -> batch -> session -> plan step -> shard dispatch -> verify ->
unseal — and the ROADMAP's throughput work needs to *attribute* a request's
latency across them, not guess. ``Tracer`` records a span tree per request:
the engine opens a ``request`` root at submit, every downstream stage
(runtime/serving.py, core/origami.py, core/slalom.py,
parallel/offload_sharding.py, the kernel wrappers) attaches children via
the ambient context, and the whole tree exports as Chrome-trace JSON
(chrome://tracing / Perfetto) or JSONL.

**Telemetry is a threat surface.** In a TEE deployment the trace file
leaves the trust boundary (dashboards, CI artifacts), and Privado-style
attacks reconstruct model internals from input-dependent observables — so
redaction is not a post-processing step here, it is enforced at
*attach time*: a span attribute must be a plain scalar / short string /
small container thereof. Arrays (jax or numpy), bytes, and any object
carrying a buffer are rejected with ``RedactionError`` — blinding factors,
session keys, plaintext activations and raw logits structurally cannot
ride a span. Spans carry shapes, digests, counts and timings only
(DESIGN.md §13 scopes what this does and does not cover: timing itself
still leaks input-dependent control flow, which Origami's pipeline avoids
by construction — per-step work depends on shapes, not values).

Threading model: spans are created/closed on whatever thread runs the
stage; the tracer is lock-protected and parentage is explicit (``parent=``)
or ambient via a contextvar (``activate``). Contextvars do not propagate
into pre-existing worker threads (device slots, refill threads) — stages
that hop threads pass the parent span explicitly, which is also what keeps
a worker thread from paying the tracer lock on its hot path.

Everything is a no-op when no tracer is active: the ambient lookup is one
contextvar read, so instrumented code costs nothing in production serving
(BENCH_trace_overhead.json holds the tracing-ON path under 5%).
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

_ids = itertools.count(1)          # CPython next() is atomic

# span kinds (the taxonomy DESIGN.md §13 tabulates)
KINDS = ("request", "queue", "batch", "session", "crypto", "infer",
         "step", "shard", "verify", "kernel")

_MAX_STR = 512                     # longest attribute string (digests fit)
_MAX_ITEMS = 64                    # longest attribute list/dict


class RedactionError(TypeError):
    """A span attribute carried a disallowed payload (array/bytes/object).

    Raised at attach time — the trace plane fails CLOSED: secret-bearing
    values never reach the span store, let alone an export file."""


def redact(value: Any, _depth: int = 0) -> Any:
    """Validate one attribute value against the allowlist.

    Allowed: None, bool, int, float, str (truncated to ``_MAX_STR``), and
    lists/tuples/dicts of allowed values (bounded). Everything else —
    notably jax/numpy arrays, bytes-likes, and arbitrary objects — raises
    ``RedactionError``. Types are checked *exactly* (no duck-typing): a
    subclass with a buffer would sail through an isinstance check.
    """
    if value is None or type(value) in (bool, int, float):
        return value
    if isinstance(value, str):
        return value if len(value) <= _MAX_STR else value[:_MAX_STR] + "…"
    if isinstance(value, (bytes, bytearray, memoryview)):
        raise RedactionError(
            "span attributes must not carry raw bytes (key material, "
            "ciphertext, array buffers) — attach a digest or a shape")
    if hasattr(value, "__array__") or hasattr(value, "shape"):
        raise RedactionError(
            f"span attributes must not carry arrays ({type(value).__name__})"
            " — blinding factors / activations / logits are secret; attach "
            "the shape tuple or a digest instead")
    if isinstance(value, (list, tuple)):
        if _depth >= 3 or len(value) > _MAX_ITEMS:
            raise RedactionError("span attribute container too large/deep")
        return [redact(v, _depth + 1) for v in value]
    if isinstance(value, dict):
        if _depth >= 3 or len(value) > _MAX_ITEMS:
            raise RedactionError("span attribute container too large/deep")
        return {str(k)[:_MAX_STR]: redact(v, _depth + 1)
                for k, v in value.items()}
    raise RedactionError(
        f"span attribute type {type(value).__name__!r} is not on the "
        "redaction allowlist (scalars, short strings, small containers)")


@dataclass
class Span:
    """One timed stage. ``t0``/``t1`` are perf_counter seconds relative to
    the tracer's epoch; attributes are pre-redacted."""
    trace_id: int
    span_id: int
    parent_id: Optional[int]
    name: str
    kind: str
    t0: float
    t1: Optional[float] = None
    tid: int = 0
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration_s(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0

    def as_dict(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "name": self.name,
                "kind": self.kind, "t0": self.t0, "t1": self.t1,
                "tid": self.tid, "attrs": dict(self.attrs)}


class Tracer:
    """Thread-safe bounded span store with redaction-enforced attributes.

    ``kernel_spans`` gates the block_until_ready-fenced kernel hooks
    (``profiled_kernel``) — the only instrumentation that *changes* device
    scheduling (a fence serializes async dispatch), so it is opt-outable
    independently of the request/stage spans.
    """

    MAX_SPANS = 200_000

    def __init__(self, *, enabled: bool = True, kernel_spans: bool = True,
                 max_spans: int = MAX_SPANS):
        self.enabled = enabled
        self.kernel_spans = kernel_spans
        self.max_spans = max_spans
        self.epoch = time.perf_counter()
        self.epoch_unix = time.time()
        self.dropped = 0                  # spans past the bound
        self._spans: List[Span] = []
        self._lock = threading.Lock()

    # -- recording ---------------------------------------------------------
    def start_span(self, name: str, kind: str = "step", *,
                   parent: Optional[Span] = None,
                   trace_id: Optional[int] = None,
                   **attrs: Any) -> Span:
        """Open a span. Parent resolution: explicit ``parent``, else the
        ambient current span (same thread), else a new root (fresh
        trace_id unless given)."""
        if parent is None:
            parent = current_span()
        sid = next(_ids)
        tid = (parent.trace_id if parent is not None
               else (trace_id if trace_id is not None else next(_ids)))
        span = Span(trace_id=tid, span_id=sid,
                    parent_id=parent.span_id if parent else None,
                    name=name, kind=kind,
                    t0=time.perf_counter() - self.epoch,
                    tid=threading.get_ident())
        if attrs:
            self.annotate(span, **attrs)
        with self._lock:
            if len(self._spans) < self.max_spans:
                self._spans.append(span)
            else:
                self.dropped += 1
        return span

    def annotate(self, span: Span, **attrs: Any) -> None:
        """Attach attributes (redaction enforced — raises on violations
        BEFORE anything is stored)."""
        clean = {k: redact(v) for k, v in attrs.items()}
        span.attrs.update(clean)

    def end(self, span: Span, **attrs: Any) -> Span:
        if attrs:
            self.annotate(span, **attrs)
        span.t1 = time.perf_counter() - self.epoch
        return span

    @contextmanager
    def span(self, name: str, kind: str = "step", *,
             parent: Optional[Span] = None, **attrs: Any):
        """Open + activate a span for the dynamic extent of the block: any
        span started inside (same thread) parents to it."""
        s = self.start_span(name, kind, parent=parent, **attrs)
        token = _CURRENT.set((self, s))
        try:
            yield s
        finally:
            _CURRENT.reset(token)
            if s.t1 is None:
                self.end(s)

    # -- reading / export --------------------------------------------------
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def by_id(self) -> Dict[int, Span]:
        return {s.span_id: s for s in self.spans()}

    def roots(self) -> List[Span]:
        return [s for s in self.spans() if s.parent_id is None]

    def children(self, span: Span) -> List[Span]:
        return [s for s in self.spans() if s.parent_id == span.span_id]

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace event format (load in chrome://tracing/Perfetto).

        Complete ("X") events in microseconds; unfinished spans export with
        their open duration so a crashed run still renders."""
        now = time.perf_counter() - self.epoch
        events: List[Dict[str, Any]] = [{
            "ph": "M", "name": "process_name", "pid": 0, "tid": 0,
            "args": {"name": "repro-private-inference"}}]
        for s in self.spans():
            t1 = s.t1 if s.t1 is not None else now
            events.append({
                "name": s.name, "cat": s.kind, "ph": "X", "pid": 0,
                "tid": s.tid, "ts": round(s.t0 * 1e6, 3),
                "dur": round((t1 - s.t0) * 1e6, 3),
                "args": {**s.attrs, "trace_id": s.trace_id,
                         "span_id": s.span_id, "parent_id": s.parent_id}})
        # truncation marker: a bounded store drops the NEWEST spans once
        # full (children of stored parents may be missing) — consumers
        # must not read a truncated export as a connected tree
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"epoch_unix": self.epoch_unix,
                              "dropped_spans": self.dropped,
                              "truncated": self.dropped > 0}}

    def dump_chrome(self, path) -> int:
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        return len(doc["traceEvents"])

    def dump_jsonl(self, path) -> int:
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.as_dict()) + "\n")
            if self.dropped:
                # same truncation stamp the Chrome export carries — a
                # trailing marker line, so line-oriented consumers see it
                # without schema changes to the span records
                f.write(json.dumps({"truncated": True,
                                    "dropped_spans": self.dropped}) + "\n")
        return len(spans)


# -- ambient context -------------------------------------------------------
_CURRENT: ContextVar[Optional[Tuple[Tracer, Span]]] = ContextVar(
    "repro_trace_current", default=None)


def current_tracer() -> Optional[Tracer]:
    cur = _CURRENT.get()
    return cur[0] if cur is not None else None


def current_span() -> Optional[Span]:
    cur = _CURRENT.get()
    return cur[1] if cur is not None else None


@contextmanager
def activate(tracer: Optional[Tracer], span: Optional[Span] = None):
    """Install ``tracer`` (and optionally a current parent span) for the
    dynamic extent — the engine wraps each batch dispatch with this so the
    serving/executor/plane stages pick the tracer up ambiently. No-op when
    ``tracer`` is None."""
    if tracer is None or not tracer.enabled:
        yield None
        return
    token = _CURRENT.set((tracer, span))
    try:
        yield span
    finally:
        _CURRENT.reset(token)


@contextmanager
def maybe_span(name: str, kind: str = "step", **attrs: Any):
    """Ambient-span helper for instrumented call sites: records a child of
    the current span when a tracer is active, yields None (one contextvar
    read) otherwise."""
    cur = _CURRENT.get()
    if cur is None or not cur[0].enabled:
        yield None
        return
    with cur[0].span(name, kind, **attrs) as s:
        yield s


def annotate(span: Optional[Span], **attrs: Any) -> None:
    """Attach attributes to a ``maybe_span`` result (None-safe)."""
    if span is None:
        return
    tr = current_tracer()
    if tr is not None:
        tr.annotate(span, **attrs)


def profiled_kernel(name: str, fn, *args, **kw):
    """Wall-time profile one kernel call with block_until_ready fencing.

    Only fires when (a) a tracer with ``kernel_spans`` is ambient and
    (b) every operand is concrete — under a jit trace the call records
    nothing (span timings of abstract tracers would measure *compile*
    time and attach nothing meaningful). Inputs are fenced BEFORE the
    span opens so pending async work upstream is not attributed to this
    kernel, and the output is fenced before it closes so device time is
    attributed instead of hidden in async dispatch.
    """
    cur = _CURRENT.get()
    if cur is None or not (cur[0].enabled and cur[0].kernel_spans):
        return fn(*args, **kw)
    import jax
    leaves = [a for a in args if hasattr(a, "shape")]
    if any(isinstance(a, jax.core.Tracer) for a in leaves):
        return fn(*args, **kw)
    jax.block_until_ready(leaves)
    shapes = [tuple(a.shape) for a in leaves[:3]]
    with cur[0].span(name, "kernel", shapes=shapes):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return out
