"""Origami executor: plan-driven trust-partitioned inference (the paper).

The executor interprets a ``PlacementPlan`` (core/plan.py): an explicit
per-layer placement IR — ``open`` | ``enclave`` | ``blinded`` plus optional
per-step Freivalds policies — compiled once and walked by ONE ``_traced``
for every model family (the per-family layer iterators live in
models/vgg.py / models/model.py). The five legacy mode strings

    "open"         everything on the untrusted device, no privacy
    "enclave"      everything inside the enclave (paper baseline 2)
    "split"        tier-1 in the enclave, tier-2 open (Split/x)
    "slalom"       blinded offload for EVERY layer (Slalom/Privacy)
    "origami"      blinded offload for tier-1 only, tier-2 open (the paper)

remain as thin compatibility constructors over ``plan.compile_mode`` —
there is no mode-string branching in the executor itself, and plans the
mode strings cannot express (mixed enclave/blinded tier-1, verified-open
tier-2 offload) execute through the same interpreter (DESIGN.md §10).

All plans compute the *same function* (up to tier-1 quantization error on
offloaded steps) — tests assert allclose against the open reference. Plans
differ in where work lands, which the trace-time telemetry records and
core/trust.py prices with the paper-calibrated cost model.
"""
from __future__ import annotations

import functools
from contextlib import ExitStack, nullcontext
from dataclasses import dataclass, field as dfield
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import integrity as IG
from repro.core import plan as PL
from repro.core import slalom as SL
from repro.core import tracing
from repro.core.blinding import BlindingSpec
from repro.core.precompute import BlindedLayerCache
from repro.models import layers as L
from repro.models import model as M
from repro.models import vgg as V
# repro.runtime is a namespace package and aot.py imports only jax, so
# this does not create a core <-> runtime import cycle
from repro.runtime import aot as AOT

MODES = PL.LEGACY_MODES


@dataclass
class OrigamiResult:
    logits: jax.Array
    boundary: Optional[jax.Array]       # what the adversary observes
    telemetry: SL.Telemetry
    integrity: IG.IntegrityReport = dfield(
        default_factory=IG.IntegrityReport.empty)
    trusted: bool = False               # enclave-recompute trace (no device)
    sharding: Optional[Any] = None      # offload_sharding.ShardReport


class OrigamiExecutor:
    """Plan-interpreting private inference over any repro model."""

    def __init__(self, cfg: ModelConfig, params, mode: str = "origami",
                 partition: Optional[int] = None,
                 spec: Optional[BlindingSpec] = None,
                 impl: str = "fused", precompute: bool = False,
                 integrity: Optional[IG.IntegrityPolicy] = None,
                 fault: Optional[Any] = None,
                 plan: Optional[PL.PlacementPlan] = None,
                 devices: Optional[Any] = None, shard: str = "rows",
                 hedging: bool = True, liveness: Optional[Any] = None):
        """``plan``: an explicit PlacementPlan; when omitted, the legacy
        ``mode``/``partition`` kwargs compile one (``plan.compile_mode``).
        ``integrity``: Freivalds verification policy inherited by blinded
        steps without their own (core/integrity.py; default off).
        ``fault``: a runtime/faults.DishonestDevice injected under the
        device matmul (single-device path; a pool carries per-slot
        injectors instead). ``devices``: a runtime/devices.DevicePool —
        attaches a sharded multi-device offload plane
        (parallel/offload_sharding.py) with default shard ``shard``
        ("rows" | "shares"), straggler ``hedging`` and a
        parallel/offload_sharding.LivenessConfig ``liveness`` (timeout /
        backoff / breaker knobs, defaults when None); the plane's
        host-side retry/health control flow makes the executor run its
        trace eagerly (bit-identical to the jitted trace). All are static
        — pick them at construction."""
        assert impl in ("fused", "unfused"), impl
        if plan is None:
            plan = PL.compile_mode(cfg, mode, partition)
        assert plan.n_layers == PL.num_blocks(cfg), \
            (plan.n_layers, PL.num_blocks(cfg))
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.mode = plan.mode_label          # compat: legacy name or spec
        self.partition = plan.boundary       # compat: revealed boundary
        self.spec = spec or BlindingSpec()
        self.impl = impl
        self.precompute = precompute
        self.integrity = integrity or IG.IntegrityPolicy.off()
        self.fault = fault
        self.plane = None
        self._plane_live = False
        if devices is not None:
            from repro.parallel.offload_sharding import OffloadPlane
            self.plane = OffloadPlane(devices, mode=shard, hedging=hedging,
                                      liveness=liveness)
            # the plane only ever fires on per-op-addressable offloaded
            # steps (scanned families and offload-free plans have none) —
            # keep jit for executors whose pool can never shard anything,
            # instead of paying op-by-op eager dispatch for zero benefit
            self._plane_live = (PL.linear_layers(cfg) is not None
                                and plan.has_offload)
        self.cache: Optional[BlindedLayerCache] = None
        self._caches: Dict[Any, BlindedLayerCache] = {}  # (digest, shape)
        self._cache_key = None
        self._program = PL.program_for(cfg)
        # per-trace telemetry (each trace gets its OWN recorder; the shared
        # object the seed used let the trusted-recovery trace corrupt the
        # offload counters). ``telemetry`` is the last-trace snapshot.
        self._tele_last = SL.Telemetry()
        self._tele_blinded = SL.Telemetry()
        self._tele_trusted = SL.Telemetry()
        self._jitted = jax.jit(self._traced)
        # the recovery path: same math with the field matmuls run inside
        # the enclave (no device, no blinding, no injector) — bit-identical
        # logits, used after a failed Freivalds check or under quarantine
        self._jitted_trusted = jax.jit(
            functools.partial(self._traced, trusted=True))
        # AOT serving path: executables compiled explicitly (lower+compile)
        # through a CompileCache (runtime/aot.py) instead of first-call jit.
        # The session factors buffer is donated off-CPU — it is per-session
        # material the cache hands over exactly once (take()), never reused
        # after the call. The *batch* is deliberately NOT donated: the §9
        # integrity ladder re-feeds the same batch to the retry and
        # enclave-recompute executables after a failed verify, and a donated
        # input would already be dead by then. (CPU donation is unimplemented
        # in XLA and only warns, but gating keeps the logs clean.)
        donate = () if jax.default_backend() == "cpu" else (2,)
        self._aot_jit = jax.jit(self._traced, donate_argnums=donate)
        self._aot_jit_trusted = jax.jit(
            functools.partial(self._traced, trusted=True))
        self._aot: AOT.CompileCache = AOT.CompileCache(None)  # memo-only
        self._executables: Dict[Any, Any] = {}   # sig -> compiled (COW)
        # first-call signatures already inferred: the first (trace-kind,
        # plan, shapes) call pays jax.jit tracing + compilation, and the
        # profiler (runtime/profiling.py) needs that cold call *named* —
        # its infer span is stamped first_call=True
        self._seen_sigs: set = set()
        # decode plane (attach_decode_plan): scan segments + token-slot
        # factor caches, DESIGN.md §16
        self.dplan: Optional[PL.DecodePlan] = None
        self._decode_caches: Dict[int, BlindedLayerCache] = {}
        self._jit_decode = None
        self._jit_prefill = None

    # -- telemetry snapshots -------------------------------------------------
    @property
    def telemetry(self) -> SL.Telemetry:
        """Snapshot of the most recent trace (blinded or trusted)."""
        return self._tele_last

    @property
    def telemetry_blinded(self) -> SL.Telemetry:
        """Last untrusted (offload) trace — unpolluted by recovery traces."""
        return self._tele_blinded

    @property
    def telemetry_trusted(self) -> SL.Telemetry:
        """Last enclave-recompute trace."""
        return self._tele_trusted

    # -- layer count helpers -------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return self.plan.n_layers

    # -- traced computation --------------------------------------------------
    def _traced(self, batch, session_key, factors=None, trusted=False):
        tele = SL.Telemetry()
        ctx = SL.SlalomContext(
            session_key, self.spec, telemetry=tele,
            impl=self.impl, factors=factors,
            integrity=IG.IntegrityPolicy.off(),  # set per plan segment
            fault=None if trusted else self.fault, trusted=trusted,
            plane=self.plane if self._plane_live and not trusted else None)
        logits, boundary = self._run(batch, ctx)
        if ctx.integrity_log:
            rep = tuple(jnp.stack([entry[i] for entry in ctx.integrity_log])
                        for i in range(3))
        else:
            z = jnp.zeros((0,), jnp.bool_)
            rep = (z, z, z)
        # runs at trace time: expose this trace's counters without letting
        # one trace kind pollute the other's
        if trusted:
            self._tele_trusted = tele
        else:
            self._tele_blinded = tele
        return logits, boundary, rep

    def _run(self, batch, ctx):
        """Walk the plan segments — the ONE interpreter for all families
        and all placements (no mode strings, no family forks)."""
        params, prog, plan = self.params, self._program, self.plan
        x, memory = prog.prologue(params, batch)
        # span per plan segment — EAGER traces only (the pooled plane path
        # and recovery paths): under jit the walk runs once at trace time,
        # so a span would clock compilation, not the step
        eager = not isinstance(x, jax.core.Tracer)
        boundary = x if plan.boundary == 0 else None
        for seg in plan.segments:
            with (tracing.maybe_span("plan.segment", "step", lo=seg.lo,
                                     hi=seg.hi, regime=seg.regime)
                  if eager else nullcontext()):
                if seg.regime == "plain":
                    x = prog.segment(params, x, seg.lo, seg.hi, memory)
                else:
                    policy = (seg.policy if seg.policy is not None
                              else self.integrity)
                    with ExitStack() as stack:
                        stack.enter_context(ctx.segment_overrides(
                            policy, unblinded=(seg.regime == "verified"),
                            shard=seg.shard))
                        stack.enter_context(L.dense_impl(
                            functools.partial(SL.blinded_dense, ctx)))
                        if prog.blind_convs:
                            stack.enter_context(L.conv_impl(
                                functools.partial(SL.blinded_conv2d, ctx)))
                        x = prog.segment(params, x, seg.lo, seg.hi, memory)
            if seg.hi == plan.boundary:
                boundary = x
        return prog.epilogue(params, x, batch, memory), boundary

    # -- decode plans: scan segments + token slots (DESIGN.md §16) -----------
    def attach_decode_plan(self, dplan: Optional[PL.DecodePlan] = None, *,
                           max_steps: int = 256) -> PL.DecodePlan:
        """Adopt a DecodePlan (core/plan.py:make_decode_plan) and stand up
        the decode interpreter: a jitted prompt pass over the BASE plan's
        segments and ONE jitted token step over the scan segments. Raises
        plan.ScanExclusion for families outside plan.DECODE_FAMILIES —
        the typed form of the former "scanned families fall back" branch.

        When ``dplan`` is omitted one is compiled from this executor's own
        plan, inheriting the executor's Freivalds policy as the per-step
        policy of every offloaded scan segment."""
        if dplan is None:
            dplan = PL.make_decode_plan(
                self.cfg, self.plan, max_steps=max_steps,
                integrity=(self.integrity if self.integrity.enabled
                           else None))
        assert dplan.base.digest == self.plan.digest, \
            "decode plan extends a different base plan"
        self.dplan = dplan
        self._jit_decode = jax.jit(self._traced_decode,
                                   static_argnames=("trusted",))
        self._jit_prefill = jax.jit(self._traced_prefill,
                                    static_argnames=("trusted", "max_seq"))
        return dplan

    def decode_cache(self, batch_size: int) -> Optional[BlindedLayerCache]:
        """Quantize-once weight material + per-(session, token, layer)
        factor store for the decode walk — one BlindedLayerCache per batch
        size, memoized. The TokenSlotRing (runtime/sessions.py) streams
        ``session_factors(key, step=token)`` out of it; the ``step`` slot
        of the factor keying IS the token index, so every (session, token,
        layer) triple draws a distinct pad (DESIGN.md §16). Returns None
        when the decode plan has no offloaded scan segments."""
        assert self.dplan is not None, "attach_decode_plan first"
        if not self.dplan.has_offload:
            return None
        cache = self._decode_caches.get(batch_size)
        if cache is None:
            cache = BlindedLayerCache.from_records(
                self._decode_records(batch_size), self.spec,
                integrity=self.integrity)
            # copy-on-write rebind: read by the ring's refill thread
            self._decode_caches = {**self._decode_caches,
                                   batch_size: cache}
        return cache

    def _decode_records(self, batch_size: int):
        """Static per-op descriptors for the decode walk, in trace order —
        captured by running one EAGER token step with a recording dense
        impl (weights are concrete here, unlike inside the jitted decode
        trace). Only offloaded scan segments record; plain segments run
        the scanned fast path and touch no factor material."""
        cfg, params = self.cfg, self.params
        records = []

        def capture(p, xx):
            w = p["w"]
            t = 1
            for s_ in xx.shape[:-1]:
                t *= s_
            records.append({"kind": "dense", "w": w, "t": int(t),
                            "d_in": int(w.shape[0]),
                            "d_out": int(w.shape[1])})
            y = xx @ w.astype(xx.dtype)
            if "b" in p:
                y = y + p["b"].astype(xx.dtype)
            return y

        caches = M.init_caches(cfg, batch_size, 8)
        token = jnp.zeros((batch_size, 1), jnp.int32)
        x = M.embed_tokens_at(params, token, jnp.int32(0), cfg)
        pos = jnp.int32(0)
        for seg in self.dplan.scan:
            if seg.regime == "plain":
                x, caches = M.decode_range(params, x, caches, pos, cfg,
                                           seg.lo, seg.hi)
                continue
            pol = seg.policy if seg.policy is not None else self.integrity
            start = len(records)
            with L.dense_impl(capture):
                x, caches = M.decode_range_unrolled(
                    params, x, caches, pos, cfg, seg.lo, seg.hi)
            for rec in records[start:]:
                rec["unblinded"] = seg.regime == "verified"
                rec["policy"] = pol
        return records

    def _traced_decode(self, token, caches, pos, session_key, factors=None,
                       trusted: bool = False):
        """ONE token step under the decode plan's scan segments.

        ``ctx.step`` is set to the TRACED position, so a single compiled
        executable serves every token of every session while drawing fresh
        per-token pads, fold vectors and sampled-check decisions
        (``fold_in`` accepts traced ints) — and the TokenSlotRing's cached
        factors for ``step == pos`` are bit-identical to this trace's live
        derivation. ``per_op=True`` overrides the scanned-weight inference
        in core/slalom.py: the block walk is unrolled at trace time, so
        each traced dense call stands for exactly one runtime op and
        verification/injection bind per (token, layer)."""
        tele = SL.Telemetry()
        ctx = SL.SlalomContext(
            session_key, self.spec, telemetry=tele, impl=self.impl,
            factors=factors, integrity=IG.IntegrityPolicy.off(),
            fault=None if trusted else self.fault, trusted=trusted,
            step=pos, per_op=True)
        params, cfg = self.params, self.cfg
        x = M.embed_tokens_at(params, token, pos, cfg)
        for seg in self.dplan.scan:
            if seg.regime == "plain":
                x, caches = M.decode_range(params, x, caches, pos, cfg,
                                           seg.lo, seg.hi)
                continue
            policy = (seg.policy if seg.policy is not None
                      else self.integrity)
            with ExitStack() as stack:
                stack.enter_context(ctx.segment_overrides(
                    policy, unblinded=(seg.regime == "verified"),
                    shard=seg.shard))
                stack.enter_context(L.dense_impl(
                    functools.partial(SL.blinded_dense, ctx)))
                x, caches = M.decode_range_unrolled(
                    params, x, caches, pos, cfg, seg.lo, seg.hi)
        logits = M.head(params, x, cfg)
        rep = self._fold_log(ctx)
        if trusted:
            self._tele_trusted = tele
        else:
            self._tele_blinded = tele
        return logits, caches, rep

    def _traced_prefill(self, tokens, session_key, trusted: bool = False,
                        *, max_seq: int):
        """Prompt pass through the BASE plan's segments, returning
        ``(last-position logits, decode caches, integrity log)``.

        Offloaded segments run the block walk UNROLLED — per-op
        addressable even at prefill, so every prompt op gets its own
        blinding key and Freivalds fold (no cross-layer pad sharing) —
        while plain segments keep the scanned fast path. Prefill ops use
        ``step=0``; decode steps use ``step=pos >= 1`` (positions count
        from the prompt length), so the two key domains never collide."""
        tele = SL.Telemetry()
        ctx = SL.SlalomContext(
            session_key, self.spec, telemetry=tele, impl=self.impl,
            factors=None, integrity=IG.IntegrityPolicy.off(),
            fault=None if trusted else self.fault, trusted=trusted,
            step=0, per_op=True)
        params, cfg = self.params, self.cfg
        x = M.embed_tokens(params, tokens, cfg)
        parts = []
        for seg in self.plan.segments:
            if seg.regime == "plain":
                x, c = M.prefill_range(params, x, cfg, seg.lo, seg.hi)
            else:
                policy = (seg.policy if seg.policy is not None
                          else self.integrity)
                with ExitStack() as stack:
                    stack.enter_context(ctx.segment_overrides(
                        policy, unblinded=(seg.regime == "verified"),
                        shard=seg.shard))
                    stack.enter_context(L.dense_impl(
                        functools.partial(SL.blinded_dense, ctx)))
                    x, c = M.prefill_range_unrolled(params, x, cfg,
                                                    seg.lo, seg.hi)
            parts.append(c)
        caches = M.concat_layer_caches(parts, max_seq)
        logits = M.head(params, x[:, -1:], cfg)
        rep = self._fold_log(ctx)
        if trusted:
            self._tele_trusted = tele
        else:
            self._tele_blinded = tele
        return logits, caches, rep

    @staticmethod
    def _fold_log(ctx):
        if ctx.integrity_log:
            return tuple(jnp.stack([e[i] for e in ctx.integrity_log])
                         for i in range(3))
        z = jnp.zeros((0,), jnp.bool_)
        return (z, z, z)

    @staticmethod
    def _cache_seq(caches) -> int:
        for leaf in jax.tree.leaves(caches):
            return int(leaf.shape[2])
        return 0

    def _ensure_decode_exec(self, sig, jfn, traced, kind, args, kw):
        """Decode-plane twin of ``_ensure_executable``: memo -> disk ->
        timed lower+compile, keyed on the DECODE plan digest (distinct
        from the base plan's — DecodePlan.digest covers scan structure)."""
        compiled = self._executables.get(sig)
        if compiled is not None:
            return compiled
        ck = self._aot.entry_key(self.dplan.digest, kind, args)

        def build():
            with tracing.maybe_span("compile.aot", "compile", trace=kind):
                return jfn.lower(*args, **kw).compile()

        def replay_telemetry():
            with tracing.maybe_span("compile.aot", "compile", trace=kind,
                                    disk_hit=1):
                jax.eval_shape(functools.partial(traced, **kw), *args)

        compiled, _ = self._aot.compile_once(ck, build,
                                             on_disk_hit=replay_telemetry)
        self._executables = {**self._executables, sig: compiled}
        return compiled

    def _call_decode_exec(self, sig, compiled, jfn, args, kw):
        try:
            return compiled(*args)
        except Exception:  # noqa: BLE001 — same contract as
            # _call_executable: evict + fall back to the implicit-jit path
            self._aot.record_fallback()
            self._executables = {k: v for k, v in self._executables.items()
                                 if k != sig}
            return jfn(*args, **kw)

    def prefill_session(self, tokens, session_key, *, max_seq: int,
                        trusted: bool = False, jit: bool = True):
        """Public prompt pass: (logits at the last position, decode caches
        padded to ``max_seq``, IntegrityReport over the prefill ops)."""
        assert self.dplan is not None, "attach_decode_plan first"
        kw = {"trusted": trusted, "max_seq": int(max_seq)}
        args = (tokens, session_key)
        if jit:
            sig = ("prefill", bool(trusted), self.dplan.digest,
                   tuple(tokens.shape), int(max_seq))
            ex = self._ensure_decode_exec(
                sig, self._jit_prefill, self._traced_prefill,
                f"prefill{int(max_seq)}" + ("_trusted" if trusted else ""),
                args, kw)
            logits, caches, rep = self._call_decode_exec(
                sig, ex, self._jit_prefill, args, kw)
        else:
            logits, caches, rep = self._traced_prefill(*args, **kw)
        self._tele_last = (self._tele_trusted if trusted
                           else self._tele_blinded)
        return logits, caches, IG.IntegrityReport(*rep)

    def decode_once(self, token, caches, pos, session_key, factors=None,
                    *, trusted: bool = False, jit: bool = True):
        """Public single-token step: (logits, updated caches,
        IntegrityReport for this token's offloaded ops). ``factors`` is
        one TokenSlotRing slot (take(token)) or None for the live /
        trusted derivations."""
        assert self.dplan is not None, "attach_decode_plan first"
        pos = jnp.asarray(pos, jnp.int32)
        kw = {"trusted": trusted}
        args = (token, caches, pos, session_key, factors)
        if jit:
            sig = ("decode", bool(trusted), self.dplan.digest,
                   tuple(token.shape), self._cache_seq(caches),
                   factors is None)
            ex = self._ensure_decode_exec(
                sig, self._jit_decode, self._traced_decode,
                "decode" + ("_trusted" if trusted else ""), args, kw)
            logits, caches, rep = self._call_decode_exec(
                sig, ex, self._jit_decode, args, kw)
        else:
            logits, caches, rep = self._traced_decode(*args, **kw)
        self._tele_last = (self._tele_trusted if trusted
                           else self._tele_blinded)
        return logits, caches, IG.IntegrityReport(*rep)

    def warm_decode_aot(self, batch: int, prompt_len: int, max_seq: int,
                        trusted_too: bool = True) -> int:
        """Compile the prefill + token-step executables (and the trusted
        recovery twins) ahead of the first request — the decode analogue
        of ``warm_aot``. Returns the number of signatures ensured."""
        assert self.dplan is not None, "attach_decode_plan first"
        key0 = jax.random.PRNGKey(0)
        tokens = jnp.zeros((batch, int(prompt_len)), jnp.int32)
        token = jnp.zeros((batch, 1), jnp.int32)
        caches = M.init_caches(self.cfg, batch, int(max_seq))
        cache = self.decode_cache(batch)
        n = 0
        with self._aot.warmup_scope():
            for trusted in ((False, True) if trusted_too else (False,)):
                sig = ("prefill", trusted, self.dplan.digest,
                       tuple(tokens.shape), int(max_seq))
                self._ensure_decode_exec(
                    sig, self._jit_prefill, self._traced_prefill,
                    f"prefill{int(max_seq)}"
                    + ("_trusted" if trusted else ""),
                    (tokens, key0),
                    {"trusted": trusted, "max_seq": int(max_seq)})
                n += 1
                factors = (None if trusted or cache is None
                           else cache.session_factors(key0, 0))
                sig = ("decode", trusted, self.dplan.digest,
                       tuple(token.shape), int(max_seq), factors is None)
                self._ensure_decode_exec(
                    sig, self._jit_decode, self._traced_decode,
                    "decode" + ("_trusted" if trusted else ""),
                    (token, caches, jnp.int32(prompt_len), key0, factors),
                    {"trusted": trusted})
                n += 1
        return n

    # -- precompute pipeline -------------------------------------------------
    def build_cache(self, batch) -> Optional[BlindedLayerCache]:
        """Quantize/limb-encode every offloaded layer's weights once and
        set up the per-session factor store (DESIGN.md §4).

        The blinded-op records come straight from the plan's static layer
        shapes (``plan.cache_ops`` slots + models/vgg.py shape algebra) —
        no eval_shape re-trace. Forward traces of scanned LM families have
        no cache slots (``plan.cache_ops`` is empty — the typed
        ``plan.ScanExclusion`` domain); their DECODE walk gets per-op
        slots through ``decode_cache``/``_decode_records`` instead
        (DESIGN.md §16).
        """
        ops = self.plan.cache_ops
        if not ops:
            self.precompute = False
            self.cache = None
            return None
        batch_size = int(jnp.shape(batch["images"])[0])
        records = V.blinded_op_records(self.params, self.cfg,
                                       [s.layer_id for s in ops], batch_size)
        for rec, step in zip(records, ops):
            rec["unblinded"] = step.verified_open
            rec["policy"] = (step.integrity if step.integrity is not None
                             else self.integrity)
        self.cache = BlindedLayerCache.from_records(records, self.spec,
                                                    integrity=self.integrity)
        if self._plane_live:
            # prefetch per-shard fold vectors alongside (r, u): the
            # SessionPool ring then keeps shard-local verification material
            # off the request path too
            self.cache.shards = self.plane.n_shards
        shapes = tuple(sorted(
            (k, tuple(jnp.shape(v))) for k, v in batch.items()))
        self._cache_key = (self.plan.digest, shapes)
        # copy-on-write: the SessionPool's refill thread snapshots this
        # dict concurrently; rebinding (vs. in-place insert) keeps any
        # iteration over the old dict safe without a lock
        self._caches = {**self._caches, self._cache_key: self.cache}
        return self.cache

    def prepare_session(self, session_key, step: int = 0) -> None:
        """Prefetch the unblinding factors for a future session so the
        factor matmuls overlap current device compute (serving hook)."""
        if self.cache is not None:
            self.cache.prefetch(session_key, step)

    def _session_factors(self, batch, session_key):
        if not (self.precompute and self.plan.has_offload):
            return None
        shapes = tuple(sorted((k, tuple(jnp.shape(v)))
                              for k, v in batch.items()))
        key = (self.plan.digest, shapes)
        if self.cache is None or key != self._cache_key:
            if key in self._caches:     # recurring shape (padding buckets):
                self.cache = self._caches[key]       # no rebuild thrash
                self._cache_key = key
            else:
                self.build_cache(batch)
        if self.cache is None:          # forward trace has no cache slots
            return None                 # (decode slots: decode_cache())
        return self.cache.take(session_key)

    # -- AOT executables -----------------------------------------------------
    def attach_aot(self, cache: AOT.CompileCache) -> None:
        """Adopt a shared (engine-level) compile cache: cross-executor
        memoization, exactly-once compiles under concurrent registration,
        optional on-disk persistence, and counters in the engine's
        MetricsRegistry. Keeps any executables already compiled."""
        for key, compiled in self._aot._memo.items():
            cache._memo.setdefault(key, compiled)
        self._aot = cache

    def _ensure_executable(self, sig, batch, session_key, factors,
                           trusted: bool):
        """The one compile path: memo -> disk -> timed lower+compile."""
        compiled = self._executables.get(sig)
        if compiled is not None:
            return compiled
        kind = "trusted" if trusted else "blinded"
        jfn = self._aot_jit_trusted if trusted else self._aot_jit
        args = (batch, session_key, factors)
        ck = self._aot.entry_key(self.plan.digest, kind, args)

        def build():
            with tracing.maybe_span("compile.aot", "compile",
                                    trusted=int(trusted)):
                return jfn.lower(*args).compile()

        def replay_telemetry():
            # a deserialized executable never runs _traced, so the
            # trace-time telemetry side effects (_tele_blinded/_tele_trusted)
            # would stay stale — replay the trace abstractly (no FLOPs)
            with tracing.maybe_span("compile.aot", "compile",
                                    trusted=int(trusted), disk_hit=1):
                jax.eval_shape(functools.partial(self._traced,
                                                 trusted=trusted), *args)

        compiled, _ = self._aot.compile_once(ck, build,
                                             on_disk_hit=replay_telemetry)
        # copy-on-write rebind: read concurrently by warm (register) and
        # serve (device-stage) threads
        self._executables = {**self._executables, sig: compiled}
        return compiled

    def _call_executable(self, sig, compiled, args, trusted: bool):
        try:
            return compiled(*args)
        except Exception:  # noqa: BLE001 — e.g. a disk-loaded executable
            # incompatible at call time (runtime/toolchain drift the key
            # did not capture): fall back to the plain jit path and evict,
            # never fail the request
            self._aot.record_fallback()
            self._executables = {k: v for k, v in self._executables.items()
                                 if k != sig}
            fn = self._jitted_trusted if trusted else self._jitted
            return fn(*args)

    def warm_aot(self, input_key: str, request_shape, buckets,
                 dtype=None, trusted_too: bool = True) -> int:
        """Compile every (trace kind, shape bucket) executable — and build
        the per-bucket factor caches — ahead of the first request.

        Called by ``ServingEngine.register_model``: after this, a request
        only ever hits already-compiled executables (its infer span is
        stamped ``first_call=False``), and the SessionPool prefetches
        sessions into every bucket's cache. The trusted recovery trace is
        warmed too (``trusted_too``) so the §9 recompute ladder and §12
        degraded mode don't pay a first-call compile mid-incident.
        Returns the number of signatures ensured. No-op for offload-plane
        executors (their trace runs eagerly)."""
        if self._plane_live:
            return 0
        key0 = jax.random.PRNGKey(0)
        n = 0
        with self._aot.warmup_scope():
            for b in buckets:
                x = jnp.zeros((int(b),) + tuple(request_shape),
                              dtype if dtype is not None else jnp.float32)
                batch = {input_key: x}
                shapes = tuple(sorted((k, tuple(jnp.shape(v)))
                                      for k, v in batch.items()))
                for trusted in ((False, True) if trusted_too else (False,)):
                    sig = (trusted, self.plan.digest, shapes)
                    factors = (None if trusted
                               else self._session_factors(batch, key0))
                    self._ensure_executable(sig, batch, key0, factors,
                                            trusted)
                    self._seen_sigs.add(sig)
                    n += 1
        return n

    # -- public API ----------------------------------------------------------
    def infer(self, batch: Dict[str, jax.Array],
              session_key: Optional[jax.Array] = None,
              jit: bool = True, trusted: bool = False) -> OrigamiResult:
        """``trusted=True`` runs the enclave-recompute trace: the linear
        ops execute inside the enclave (field matmuls of the enclave's own
        quantized operands), skipping blinding, the untrusted device, the
        fault injector and verification. Bit-identical logits to the honest
        offloaded path — the integrity layer's recovery primitive."""
        key = (session_key if session_key is not None
               else jax.random.PRNGKey(0))
        shapes = tuple(sorted((k, tuple(jnp.shape(v)))
                              for k, v in batch.items()))
        sig = (bool(trusted), self.plan.digest, shapes)
        first_call = sig not in self._seen_sigs
        self._seen_sigs.add(sig)
        shard_report = None
        if trusted:
            ex = self._ensure_executable(sig, batch, key, None, True)
            logits, boundary, rep = self._call_executable(
                sig, ex, (batch, key, None), True)
        else:
            factors = self._session_factors(batch, key)
            # the plane's host-side dispatch (retry, hedging, per-device
            # health) cannot live inside a jit trace — run eagerly. The
            # field kernels are exact either way; the float tier-2 layers
            # stay bit-identical to the jitted trace for batch >= 2 (XLA
            # picks a different conv algorithm at batch 1), which is the
            # regime the cross-checking drills run in
            if self._plane_live:
                self.plane.begin_infer()
                logits, boundary, rep = self._traced(batch, key, factors)
                shard_report = self.plane.report
            elif jit:
                ex = self._ensure_executable(sig, batch, key, factors,
                                             False)
                logits, boundary, rep = self._call_executable(
                    sig, ex, (batch, key, factors), False)
            else:
                logits, boundary, rep = self._traced(batch, key, factors)
        # the jit cache may skip re-tracing; point the public snapshot at
        # the last trace of THIS kind so a recovery trace never masquerades
        # as an offload trace (or vice versa)
        self._tele_last = (self._tele_trusted if trusted
                           else self._tele_blinded)
        # stamp the ambient infer span (runtime/serving.py opens it around
        # this call) with compile provenance + the cost-model feature
        # quantities this trace moved — what the profiler folds and the
        # CalibratedCostModel fits. Plain ints only (redaction allowlist).
        sp = tracing.current_span()
        if sp is not None:
            tele = self._tele_last
            tracing.annotate(
                sp, first_call=first_call,
                device_flops=int(tele.offloaded_flops),
                enclave_flops=int(tele.enclave_flops),
                blind_bytes=int(tele.blinded_bytes),
                unblind_bytes=int(tele.returned_bytes),
                device_matmuls=int(tele.device_matmuls))
        return OrigamiResult(logits=logits, boundary=boundary,
                             telemetry=self.telemetry,
                             integrity=IG.IntegrityReport(*rep),
                             trusted=trusted, sharding=shard_report)

    def reference(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Plain fp forward — the correctness oracle for all plans."""
        if self.cfg.family == "cnn":
            return V.vgg_forward(self.params, batch["images"], self.cfg)
        return M.forward(self.params, batch, self.cfg).logits
