"""Origami executor: two-tier trust-partitioned inference (the paper).

Execution modes (paper §VI baselines):

    "open"         everything on the untrusted device, no privacy
    "enclave"      everything inside the enclave (paper baseline 2)
    "split"        tier-1 in the enclave, tier-2 open (Split/x)
    "slalom"       blinded offload for EVERY layer (Slalom/Privacy)
    "origami"      blinded offload for tier-1 only, tier-2 open (the paper)

All modes compute the *same function* (up to tier-1 quantization error in
blinded modes) — tests assert allclose against the open reference. Modes
differ in where work lands, which the trace-time telemetry records and
core/trust.py prices with the paper-calibrated cost model.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field as dfield
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import integrity as IG
from repro.core import slalom as SL
from repro.core.blinding import BlindingSpec
from repro.core.precompute import BlindedLayerCache
from repro.models import layers as L
from repro.models import model as M
from repro.models import vgg as V

MODES = ("open", "enclave", "split", "slalom", "origami")


@dataclass
class OrigamiResult:
    logits: jax.Array
    boundary: Optional[jax.Array]       # what the adversary observes
    telemetry: SL.Telemetry
    integrity: IG.IntegrityReport = dfield(
        default_factory=IG.IntegrityReport.empty)
    trusted: bool = False               # enclave-recompute trace (no device)


class OrigamiExecutor:
    """Partitioned private inference over any repro model."""

    def __init__(self, cfg: ModelConfig, params, mode: str = "origami",
                 partition: Optional[int] = None,
                 spec: Optional[BlindingSpec] = None,
                 impl: str = "fused", precompute: bool = False,
                 integrity: Optional[IG.IntegrityPolicy] = None,
                 fault: Optional[Any] = None):
        """``integrity``: Freivalds verification policy over the offloaded
        field matmuls (core/integrity.py; default off — trust the device).
        ``fault``: a runtime/faults.DishonestDevice injected under the
        device matmul. Both are static parts of the jit trace — pick them
        at construction."""
        assert mode in MODES, mode
        assert impl in ("fused", "unfused"), impl
        self.cfg = cfg
        self.params = params
        self.mode = mode
        self.partition = (partition if partition is not None
                          else cfg.origami.tier1_layers)
        self.spec = spec or BlindingSpec()
        self.impl = impl
        self.precompute = precompute
        self.integrity = integrity or IG.IntegrityPolicy.off()
        self.fault = fault
        self.cache: Optional[BlindedLayerCache] = None
        self._caches: Dict[Any, BlindedLayerCache] = {}  # per batch-shape
        self._cache_batch_shapes = None
        self.telemetry = SL.Telemetry()
        self._jitted = jax.jit(self._traced)
        # the recovery path: same math with the field matmuls run inside
        # the enclave (no device, no blinding, no injector) — bit-identical
        # logits, used after a failed Freivalds check or under quarantine
        self._jitted_trusted = jax.jit(
            functools.partial(self._traced, trusted=True))

    # -- layer count helpers -------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return (len(self.cfg.cnn_layers) if self.cfg.family == "cnn"
                else self.cfg.num_layers)

    def _tier_bounds(self) -> Tuple[int, int]:
        p = self.partition
        if self.mode == "slalom":
            return self.num_blocks, self.num_blocks   # blind everything
        if self.mode == "open":
            return 0, 0
        if self.mode == "enclave":
            return self.num_blocks, 0                 # all enclave, no blind
        return p, p                                   # split / origami

    # -- traced computation --------------------------------------------------
    def _traced(self, batch, session_key, factors=None, trusted=False):
        ctx = SL.SlalomContext(
            session_key, self.spec, telemetry=self.telemetry,
            impl=self.impl, factors=factors,
            integrity=(IG.IntegrityPolicy.off() if trusted
                       else self.integrity),
            fault=None if trusted else self.fault, trusted=trusted)
        logits, boundary = self._run(batch, ctx)
        if ctx.integrity_log:
            rep = tuple(jnp.stack([entry[i] for entry in ctx.integrity_log])
                        for i in range(3))
        else:
            z = jnp.zeros((0,), jnp.bool_)
            rep = (z, z, z)
        return logits, boundary, rep

    def _run(self, batch, ctx):
        cfg = self.cfg
        blinded = self.mode in ("slalom", "origami")
        tier1_end, _ = self._tier_bounds()

        if cfg.family == "cnn":
            return self._traced_cnn(batch, ctx, blinded, tier1_end)
        return self._traced_lm(batch, ctx, blinded, tier1_end)

    # -- precompute pipeline -------------------------------------------------
    def build_cache(self, batch) -> Optional[BlindedLayerCache]:
        """Quantize/limb-encode every blinded layer's weights once and set up
        the per-session factor store (DESIGN.md §4).

        Discovers the blinded ops by re-tracing the executor under
        ``jax.eval_shape`` with a recording context — no FLOPs, but the
        exact call order, im2col weight views and activation row counts of
        the real trace.
        """
        records = []
        ctx = SL.SlalomContext(jax.random.PRNGKey(0), self.spec,
                               telemetry=SL.Telemetry(), recorder=records)
        shapes = {k: jax.ShapeDtypeStruct(jnp.shape(v), jnp.asarray(v).dtype)
                  for k, v in batch.items()}
        jax.eval_shape(lambda b: self._run(b, ctx), shapes)
        if any(r["kind"] == "scanned" for r in records):
            # blinded ops under lax.scan: one traced call covers many runtime
            # layers, so per-layer factors can't be bound positionally —
            # stay on the on-the-fly path (future: stacked factors as scan xs)
            self.precompute = False
            self.cache = None
            return None
        self.cache = BlindedLayerCache.from_records(records, self.spec,
                                                    integrity=self.integrity)
        self._cache_batch_shapes = tuple(sorted(
            (k, tuple(jnp.shape(v))) for k, v in batch.items()))
        # copy-on-write: the SessionPool's refill thread snapshots this
        # dict concurrently; rebinding (vs. in-place insert) keeps any
        # iteration over the old dict safe without a lock
        self._caches = {**self._caches,
                        self._cache_batch_shapes: self.cache}
        return self.cache

    def prepare_session(self, session_key, step: int = 0) -> None:
        """Prefetch the unblinding factors for a future session so the
        factor matmuls overlap current device compute (serving hook)."""
        if self.cache is not None:
            self.cache.prefetch(session_key, step)

    def _session_factors(self, batch, session_key):
        if not (self.precompute and self.mode in ("slalom", "origami")):
            return None
        shapes = tuple(sorted((k, tuple(jnp.shape(v)))
                              for k, v in batch.items()))
        if self.cache is None or shapes != self._cache_batch_shapes:
            if shapes in self._caches:   # recurring shape (padding buckets):
                self.cache = self._caches[shapes]    # no rebuild thrash
                self._cache_batch_shapes = shapes
            else:
                self.build_cache(batch)
        if self.cache is None:          # precompute unsupported (scanned)
            return None
        return self.cache.take(session_key)

    def _traced_cnn(self, batch, ctx, blinded, tier1_end):
        cfg, params = self.cfg, self.params
        x = batch["images"]
        if blinded and tier1_end > 0:
            with L.dense_impl(functools.partial(SL.blinded_dense, ctx)), \
                 L.conv_impl(functools.partial(SL.blinded_conv2d, ctx)):
                x = V.apply_layer_range(params, x, cfg, 0, tier1_end)
        elif tier1_end > 0:
            x = V.apply_layer_range(params, x, cfg, 0, tier1_end)
        boundary = x
        x = V.apply_layer_range(params, x, cfg, tier1_end,
                                len(cfg.cnn_layers))
        return x, boundary

    def _traced_lm(self, batch, ctx, blinded, tier1_end):
        cfg, params = self.cfg, self.params
        memory = batch.get("patches") if cfg.family == "vlm" else None
        if cfg.family == "audio":
            # tier-1 ⊆ encoder (the private input is the audio); see DESIGN §5
            frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
            x = frames + L.sinusoidal_positions(
                frames.shape[1], cfg.d_model).astype(frames.dtype)
            if blinded and tier1_end > 0:
                with L.dense_impl(functools.partial(SL.blinded_dense, ctx)):
                    x, _ = M.apply_range(params, x, cfg, 0, tier1_end)
            elif tier1_end > 0:
                x, _ = M.apply_range(params, x, cfg, 0, tier1_end)
            boundary = x
            x, _ = M.apply_range(params, x, cfg, tier1_end, cfg.num_layers)
            mem = L.apply_norm(params["enc_norm"], x, cfg.norm)
            out = M.forward_audio_decoder(params, batch, mem, cfg)
            return out, boundary

        x = M.embed_tokens(params, batch["tokens"], cfg)   # enclave
        if blinded and tier1_end > 0:
            with L.dense_impl(functools.partial(SL.blinded_dense, ctx)):
                x, _ = M.apply_range(params, x, cfg, 0, tier1_end,
                                     memory=memory)
        elif tier1_end > 0:
            x, _ = M.apply_range(params, x, cfg, 0, tier1_end, memory=memory)
        boundary = x
        x, _ = M.apply_range(params, x, cfg, tier1_end, cfg.num_layers,
                             memory=memory)
        return M.head(params, x, cfg), boundary

    # -- public API ----------------------------------------------------------
    def infer(self, batch: Dict[str, jax.Array],
              session_key: Optional[jax.Array] = None,
              jit: bool = True, trusted: bool = False) -> OrigamiResult:
        """``trusted=True`` runs the enclave-recompute trace: the linear
        ops execute inside the enclave (field matmuls of the enclave's own
        quantized operands), skipping blinding, the untrusted device, the
        fault injector and verification. Bit-identical logits to the honest
        blinded path — the integrity layer's recovery primitive."""
        key = (session_key if session_key is not None
               else jax.random.PRNGKey(0))
        if trusted:
            logits, boundary, rep = self._jitted_trusted(batch, key, None)
        else:
            factors = self._session_factors(batch, key)
            fn = self._jitted if jit else self._traced
            logits, boundary, rep = fn(batch, key, factors)
        return OrigamiResult(logits=logits, boundary=boundary,
                             telemetry=self.telemetry,
                             integrity=IG.IntegrityReport(*rep),
                             trusted=trusted)

    def reference(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Plain fp forward — the correctness oracle for all modes."""
        if self.cfg.family == "cnn":
            return V.vgg_forward(self.params, batch["images"], self.cfg)
        return M.forward(self.params, batch, self.cfg).logits
