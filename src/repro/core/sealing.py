"""Encrypted request channel: counter-mode stream cipher + keyed MAC.

Models the user->enclave path (paper Fig. 3a: the user encrypts the input;
only the enclave can decrypt). We use a threefry-based CTR stream cipher
over float bit-patterns plus a polynomial MAC — *not* production AES-GCM,
but a faithful functional stand-in with the same interface and the same
cost shape (one pass to decrypt, one to authenticate), suitable for the
serving pipeline and its tests.

MAC verification compares canonical byte encodings with
``hmac.compare_digest`` — a data-dependent early-exit ``==`` would hand a
network attacker a timing oracle over the tag (and the jnp comparison it
replaced also forced a device sync per word).

The cipher/MAC arithmetic is jitted (``_seal_core`` / ``_unseal_core``):
the keystream derivation and the per-word MAC scan are pure integer ops
whose eager dispatch used to cost ~100 ms per 3 K-word request — two
orders of magnitude more than the compiled loop, for bit-identical words.
Only the trust-boundary tag compare stays on the host (a Python bool from
``hmac.compare_digest``), so the security posture is unchanged.
"""
from __future__ import annotations

import hmac
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class SealedBox(NamedTuple):
    ciphertext: jax.Array      # uint32 bit-patterns
    nonce: jax.Array           # (>=2,) uint32 (word 2+: e.g. direction tag)
    mac: jax.Array             # () uint32


def _keystream(key: jax.Array, nonce: jax.Array, n: int) -> jax.Array:
    """Nonce words fold in sequentially, so nonces of different lengths
    live in disjoint key domains (a request's (lo, hi) can never collide
    with a response's (lo, hi, tag))."""
    k = jax.random.wrap_key_data(jnp.asarray(key, jnp.uint32))
    for i in range(nonce.shape[0]):
        k = jax.random.fold_in(k, nonce[i])
    return jax.random.bits(k, (n,), jnp.uint32)


def _mac(key: jax.Array, data_u32: jax.Array) -> jax.Array:
    """Carter-Wegman-style polynomial MAC over u32 words (mod 2^32)."""
    k = jax.random.fold_in(jax.random.wrap_key_data(
        jnp.asarray(key, jnp.uint32)), jnp.uint32(0xA11CE))
    coeff = jax.random.bits(k, (2,), jnp.uint32)
    c0 = coeff[0] | jnp.uint32(1)      # odd => unit mod 2^32 (invertible)

    def step(acc, w):
        return acc * c0 + w + coeff[1], None

    acc, _ = jax.lax.scan(step, jnp.uint32(0x9E3779B9), data_u32)
    return acc


def _authenticated_words(nonce: jax.Array, ct: jax.Array) -> jax.Array:
    """MAC input: length-prefixed nonce || ciphertext. The nonce selects
    the keystream, so it MUST be authenticated — an unauthenticated nonce
    swap would pass verification and decrypt to attacker-chosen garbage.
    The length prefix keeps (nonce, ct) framings of different nonce widths
    (request 2-word vs. response 3-word) from aliasing."""
    n = jnp.asarray(nonce, jnp.uint32).reshape(-1)
    return jnp.concatenate([jnp.asarray([n.size], jnp.uint32), n, ct])


@jax.jit
def _seal_core(key: jax.Array, x: jax.Array,
               nonce: jax.Array) -> Tuple[jax.Array, jax.Array]:
    bits = jax.lax.bitcast_convert_type(
        x.astype(jnp.float32), jnp.uint32).reshape(-1)
    ks = _keystream(key, nonce, bits.size)
    ct = bits ^ ks
    return (ct.reshape(x.shape),
            _mac(key, _authenticated_words(nonce, ct)))


@jax.jit
def _unseal_core(key: jax.Array, ct_flat: jax.Array,
                 nonce: jax.Array) -> Tuple[jax.Array, jax.Array]:
    want = _mac(key, _authenticated_words(nonce, ct_flat))
    ks = _keystream(key, nonce, ct_flat.size)
    return jax.lax.bitcast_convert_type(ct_flat ^ ks, jnp.float32), want


def seal(key: jax.Array, x: jax.Array, nonce: jax.Array) -> SealedBox:
    """Encrypt + authenticate a float tensor under the session key."""
    ct, mac = _seal_core(key, jnp.asarray(x), jnp.asarray(nonce, jnp.uint32))
    return SealedBox(ciphertext=ct, nonce=nonce, mac=mac)


def unseal(key: jax.Array, box: SealedBox,
           shape: Tuple[int, ...]) -> Tuple[jax.Array, bool]:
    """Returns (plaintext, mac_ok). Enclave-side.

    ``mac_ok`` is a Python bool from a constant-time compare over the
    canonical little-endian uint32 encodings of the two tags — the accept
    decision itself is never traced; only the tag/keystream arithmetic is.
    """
    pt, want = _unseal_core(jnp.asarray(key),
                            jnp.asarray(box.ciphertext).reshape(-1),
                            jnp.asarray(box.nonce, jnp.uint32))
    ok = hmac.compare_digest(
        np.asarray(want, np.uint32).tobytes(),
        np.asarray(box.mac, np.uint32).tobytes())
    return pt.reshape(shape), ok
