"""Cryptographic blinding: streams, quantized weights, unblinding factors.

The blinding stream ``r`` is a one-time pad over Z_p: uniform field elements
from a counter-based PRNG (threefry) keyed by (session_key, layer, step).
Because the stream is counter-derived, nothing has to be materialized ahead
of time or communicated between shards — each shard regenerates exactly its
slice (this is what makes blinding commute with pjit sharding, DESIGN.md §3).

Privacy argument (Slalom §4): for any x_q, (x_q + r) mod p with r ~ U(Z_p)
is itself uniform over Z_p, i.e. the untrusted device observes a perfect
one-time pad. Verified distributionally in tests/test_blinding.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.blind.ops import blind, unblind
from repro.kernels.limb_matmul.ops import field_matmul
from repro.kernels.limb_matmul.ref import HALF, P, from_signed


@dataclass(frozen=True)
class BlindingSpec:
    """Quantization scales. Combined dot products must stay within ±HALF:
    K · 2^(k_act + k_w) · |x|·|w| < HALF — callers pick k for their fan-in."""
    k_act: int = 8
    k_w: int = 7


def stream_key(session_key: jax.Array, layer_id: int,
               step: int = 0) -> jax.Array:
    return jax.random.fold_in(jax.random.fold_in(session_key, layer_id), step)


def blinding_stream(key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    """Uniform field elements in [0, p)."""
    return jax.random.randint(key, shape, 0, P, dtype=jnp.int32)


def quantize_weight(w: jax.Array, spec: BlindingSpec):
    """float weight -> (field representation, absmax scale).

    Per-tensor absmax scaling (enclave-side calibration, precomputed): the
    quantized integers use the full 2^k_w range regardless of weight
    magnitude. Returns (W_q in [0,p), scale) with
    W ≈ signed(W_q) · scale · 2^-k_w.
    """
    wf = w.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(wf)), 1e-9)
    q = jnp.clip(jnp.round(wf / scale * (2.0 ** spec.k_w)),
                 -HALF, HALF).astype(jnp.int32)
    return from_signed(q), scale


def unblinding_factor(r: jax.Array, w_q: jax.Array) -> jax.Array:
    """u = (r @ W_q) mod p — precomputed inside the enclave per Slalom.

    (Slalom stores these encrypted outside the enclave and pages slices in;
    our cost model accounts for that in core/trust.py.)
    """
    return field_matmul(r, w_q)


def blind_activations(x: jax.Array, r: jax.Array,
                      spec: BlindingSpec) -> jax.Array:
    return blind(x, r, spec.k_act)


def unblind_result(y_b: jax.Array, u: jax.Array, spec: BlindingSpec,
                   out_dtype=jnp.float32) -> jax.Array:
    return unblind(y_b, u, spec.k_act + spec.k_w, out_dtype)
