"""Precomputed blinding pipeline: weight quantization + unblinding factors
off the request path (DESIGN.md §4).

The paper's enclave precomputes the unblinding factors ``u = (r @ W_q) mod
p`` offline and pages them in during inference — that precomputation is what
makes blinded offload cheaper than enclave-resident compute. The seed
implementation instead re-derived both the quantized weights *and* ``u``
inside every traced request, so each "offloaded" matmul was paid twice
(once blinded on the device, once in the enclave).

``BlindedLayerCache`` fixes both halves:

- **Weights, once per model** (``from_records``): per blinded op, the field
  weights ``w_q``, the absmax scale, and the pre-encoded int8 limb planes
  (padded to the matmul block plan) are computed at executor construction
  and reused by every request.
- **Streams/factors, once per (session, layer, step)**
  (``session_factors``): the blinding stream ``r`` and factor ``u`` are
  generated off the request path. ``prefetch`` enqueues the next session's
  factors while the current batch runs on device (JAX async dispatch
  overlaps them — the double-buffering runtime/serving.py drives); ``take``
  pops a prefetched set or falls back to computing synchronously.

Factor keying is ``stream_key(session_key, layer_index, step)`` — exactly
the stream the on-the-fly path draws, so cached and uncached traces are
bit-identical (tests/test_precompute.py), and distinct (session, layer,
step) triples never reuse a pad.

Integrity (PR 3): when the owning executor runs a Freivalds policy
(``integrity.enabled``), each factor set also carries the fold vectors
``s`` (uniform over Z_p^(d_out × k)) and ``ws = (W_q @ s) mod p`` — the
per-(session, layer) material of the verification layer (core/integrity.py,
DESIGN.md §9). They ride the same prefetch ring, so with the SessionPool
active the skinny fold matmuls are off the request path too.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import blinding as B
from repro.core import integrity as IG
from repro.kernels.limb_matmul.ops import encode_weight_planes, field_matmul


@dataclass(frozen=True)
class CachedLayer:
    """Per-blinded-op static material (weights are static across requests).

    ``unblinded``: verified-open offload slot (core/plan.py) — the pad is
    identically zero (no privacy, no factor matmul), fold vectors still
    apply. ``policy``: this op's Freivalds policy (``None`` inherits the
    cache-wide one), the plan's per-step integrity override."""
    t: int                      # activation rows (batch-shape dependent)
    d_in: int
    d_out: int
    w_q: jax.Array              # (d_in, d_out) int32 field
    w_limbs: jax.Array          # (3, Kp, Np) int8, padded to the block plan
    w_scale: jax.Array          # () float32 absmax scale
    unblinded: bool = False
    policy: Optional[IG.IntegrityPolicy] = None


class BlindedLayerCache:
    """Quantize-once weight cache + per-session blinding-factor store."""

    def __init__(self, layers: List[CachedLayer], spec: B.BlindingSpec,
                 integrity: Optional[IG.IntegrityPolicy] = None):
        self.layers = layers
        self.spec = spec
        self.integrity = integrity or IG.IntegrityPolicy.off()
        # > 1 when the owning executor runs a multi-device offload plane
        # (core/origami.py sets it to the plane's shard count): each factor
        # set then also carries per-shard Freivalds fold vectors, so the
        # SessionPool ring keeps shard-local verification material off the
        # request path alongside (r, u)
        self.shards = 1
        self.factor_matmuls = 0          # r@W_q matmuls issued off-path
        self.fold_matmuls = 0            # W_q@s fold matmuls issued off-path
        self._ready: Dict[Tuple[bytes, int], List[Dict[str, Any]]] = {}
        # prefetch/take race under the serving engine: the SessionPool's
        # refill thread inserts while the batcher thread pops
        self._lock = threading.Lock()

    @classmethod
    def from_records(cls, records: List[Dict[str, Any]],
                     spec: B.BlindingSpec,
                     integrity: Optional[IG.IntegrityPolicy] = None
                     ) -> "BlindedLayerCache":
        """records: static per-op descriptors in trace order — one
        {"kind", "w", "t", "d_in", "d_out"} per offloaded op (derived from
        the PlacementPlan's cache slots by models/vgg.py:
        blinded_op_records; the eval_shape recorder re-trace is gone).
        Optional keys: "unblinded" (verified-open slot) and "policy"
        (per-step Freivalds override). Conv records carry the raw
        (kh, kw, cin, cout) weight; the im2col column reorder happens
        here, outside any trace."""
        from repro.core.slalom import conv_weight_cols
        layers = []
        for rec in records:
            w = (conv_weight_cols(rec["w"]) if rec["kind"] == "conv"
                 else rec["w"])
            w_q, w_scale = B.quantize_weight(w, spec)
            layers.append(CachedLayer(
                t=rec["t"], d_in=rec["d_in"], d_out=rec["d_out"],
                w_q=w_q, w_limbs=encode_weight_planes(w_q),
                w_scale=w_scale,
                unblinded=bool(rec.get("unblinded", False)),
                policy=rec.get("policy")))
        return cls(layers, spec, integrity=integrity)

    # -- per-session factors -----------------------------------------------
    @staticmethod
    def _skey(session_key, step: int) -> Tuple[bytes, int]:
        return np.asarray(session_key).tobytes(), step

    def session_factors(self, session_key, step: int = 0) -> List[Dict]:
        """Generate (r, u) — and, under an integrity policy, the Freivalds
        fold vectors (s, ws) — for every cached layer: the enclave's offline
        work. Returned as a jit-passable pytree (list of dicts of arrays)
        consumed positionally by SlalomContext."""
        factors = []
        for i, lyr in enumerate(self.layers):
            if lyr.unblinded:
                # verified-open slot: zero pad, u = (0 @ W) = 0 — nothing
                # to matmul or store. The entry keeps its positional slot
                # with r/u = None; the consumer synthesizes the zeros
                # inside the trace (core/slalom.py), so a prefetch ring
                # never pins full-size constant-zero arrays per session.
                r = u = None
            else:
                key = B.stream_key(session_key, i, step)
                r = B.blinding_stream(key, (lyr.t, lyr.d_in))
                u = field_matmul(r, lyr.w_q)
                self.factor_matmuls += 1
            entry = {"r": r, "u": u, "w_q": lyr.w_q,
                     "w_limbs": lyr.w_limbs, "w_scale": lyr.w_scale}
            pol = lyr.policy if lyr.policy is not None else self.integrity
            if pol.enabled:
                # same key derivation as the on-the-fly path in
                # core/slalom.py — cached and live verification bit-match
                entry["s"] = IG.fold_stream(session_key, i, step,
                                            lyr.d_out, pol.k)
                entry["ws"] = field_matmul(lyr.w_q, entry["s"])
                self.fold_matmuls += 1
            if self.shards > 1:
                # per-shard fold vectors for the offload plane — shards are
                # ALWAYS checked (k falls back to 1 with the policy off);
                # derivation matches integrity.shard_fold_stream so cached
                # and live shard verification are bit-identical
                k = pol.k if pol.enabled else 1
                folds = []
                for j in range(self.shards):
                    s_j = IG.shard_fold_stream(session_key, i, step, j,
                                               lyr.d_out, k)
                    folds.append((s_j, field_matmul(lyr.w_q, s_j)))
                    self.fold_matmuls += 1
                entry["shard_folds"] = folds
            factors.append(entry)
        return factors

    # prefetched sets a session's r tensors can pin ~100s of MB for large
    # models; double-buffering needs exactly one set in flight — keep 2 for
    # slack and evict FIFO so an abandoned session can't pin factors
    # forever. The serving engine's SessionPool raises this to its pool
    # depth via ``max_prefetched`` (runtime/sessions.py).
    MAX_PREFETCHED = 2

    @property
    def max_prefetched(self) -> int:
        return getattr(self, "_max_prefetched", self.MAX_PREFETCHED)

    @max_prefetched.setter
    def max_prefetched(self, n: int) -> None:
        self._max_prefetched = max(1, int(n))

    def prefetch(self, session_key, step: int = 0) -> None:
        """Enqueue factor generation for a future session (async dispatch:
        returns immediately, compute overlaps whatever runs on device)."""
        k = self._skey(session_key, step)
        with self._lock:
            if k in self._ready:
                return
        factors = self.session_factors(session_key, step)
        with self._lock:
            while len(self._ready) >= self.max_prefetched:
                self._ready.pop(next(iter(self._ready)))
            self._ready.setdefault(k, factors)

    def prefetched(self, session_key, step: int = 0) -> bool:
        with self._lock:
            return self._skey(session_key, step) in self._ready

    def clear_prefetch(self) -> None:
        """Drop all buffered factor sets (e.g. when a server goes idle)."""
        with self._lock:
            self._ready.clear()

    def take(self, session_key, step: int = 0) -> List[Dict]:
        """Pop prefetched factors for this session, or compute them now."""
        with self._lock:
            hit = self._ready.pop(self._skey(session_key, step), None)
        return hit or self.session_factors(session_key, step)

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def weight_bytes(self) -> int:
        """Cache footprint of the static half (w_q + limb planes + scale)."""
        tot = 0
        for lyr in self.layers:
            tot += lyr.w_q.size * 4 + lyr.w_limbs.size + 4
        return tot
