"""Freivalds-verified offload: probabilistic integrity checks over the
untrusted field matmul (DESIGN.md §9).

Blinding (core/blinding.py) hides tier-1 activations from the device but
does nothing to stop a malicious or faulty accelerator from returning a
*wrong* ``y_b = (x_b @ W_q) mod p`` — Slalom pairs blinding with Freivalds'
check for exactly this reason, and DarKnight likewise couples its blinding
with integrity verification. This module is the enclave side of that check:

- **Fold vectors.** Per (session, layer, step) the enclave draws ``s``
  uniform over Z_p^(d_out × k) and precomputes ``ws = (W_q @ s) mod p``
  alongside the blinding factors (core/precompute.py) — O(d_in·d_out·k),
  off the request path, riding the same SessionPool prefetch ring.
- **Check.** For a device result ``y_b`` of the offloaded op
  ``x_b @ W_q``, the enclave verifies ``y_b @ s ≡ x_b @ ws (mod p)`` at
  O(t·(d_in+d_out)·k) instead of re-doing the O(t·d_in·d_out) matmul.
  The fused data path (DESIGN.md §6) never materializes ``y_b``; there the
  equivalent post-unblind identity ``y_q @ s ≡ x_q @ ws (mod p)`` is
  checked instead (the unblinding factor ``u = r @ W_q`` cancels exactly).
- **Soundness.** If the device returns ``y' ≠ y``, some row of
  ``y' − y`` is a nonzero vector ``d`` over Z_p, and
  ``P[d · s_col ≡ 0] = 1/p`` per independent fold column (p prime, s
  uniform); ``k`` columns give detection probability ``1 − p^-k``
  (p = 2^23 − 15: k=1 misses ~1.2e-7, k=2 ~1.4e-14).
- **Policy.** ``off`` (trust the device, the pre-PR-3 behavior),
  ``sampled(rate)`` (per-op Bernoulli decision drawn from the verify key —
  cheap spot-checking, but an *adaptive* adversary that corrupts only
  unverified ops evades it, see runtime/faults.py), ``full`` (every op).

Key separation: everything verification-related derives from
``fold_in(session_key, VERIFY_DOMAIN)`` so fold vectors and sampling
decisions are independent of the blinding streams (and, like them,
unpredictable to the device before it commits to a result).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blinding as B
from repro.kernels.limb_matmul.ops import field_fold

# fold_in domain tags (distinct from any layer index / step used elsewhere)
VERIFY_DOMAIN = 0x5ECC
_SUB_FOLD = 0      # -> fold-vector draw
_SUB_DECIDE = 1    # -> sampled-mode check/skip decision
_SUB_SHARD = 2     # -> per-shard fold-vector draws (offload sharding)

MODES = ("off", "sampled", "full")


@dataclass(frozen=True)
class IntegrityPolicy:
    """Per-executor verification policy (static: part of the jit trace).

    ``mode``: "off" | "sampled" | "full"; ``rate``: per-op check
    probability under "sampled"; ``k``: independent Freivalds repetitions
    (soundness 1 − p^-k).
    """
    mode: str = "off"
    rate: float = 0.25
    k: int = 1

    def __post_init__(self):
        assert self.mode in MODES, self.mode
        assert self.k >= 1, self.k
        assert 0.0 <= self.rate <= 1.0, self.rate

    @property
    def enabled(self) -> bool:
        return self.mode != "off"

    @classmethod
    def off(cls) -> "IntegrityPolicy":
        return cls("off")

    @classmethod
    def full(cls, k: int = 1) -> "IntegrityPolicy":
        return cls("full", k=k)

    @classmethod
    def sampled(cls, rate: float = 0.25, k: int = 1) -> "IntegrityPolicy":
        return cls("sampled", rate=rate, k=k)


def verify_root(session_key: jax.Array) -> jax.Array:
    """Verification key domain, disjoint from the blinding-stream domain."""
    return jax.random.fold_in(session_key, VERIFY_DOMAIN)


def op_key(session_key: jax.Array, layer_id: int, step: int = 0) -> jax.Array:
    return B.stream_key(verify_root(session_key), layer_id, step)


def fold_stream(session_key: jax.Array, layer_id: int, step: int,
                d_out: int, k: int) -> jax.Array:
    """The fold vectors ``s``: (d_out, k) uniform field elements. Same
    derivation in the precompute cache and the on-the-fly trace, so cached
    and live verification are bit-identical."""
    key = jax.random.fold_in(op_key(session_key, layer_id, step), _SUB_FOLD)
    return B.blinding_stream(key, (d_out, k))


def shard_fold_stream(session_key: jax.Array, layer_id: int, step: int,
                      shard: int, d_out: int, k: int) -> jax.Array:
    """Per-shard fold vectors for the multi-device plane
    (parallel/offload_sharding.py): each shard of one offloaded matmul is
    checked with its OWN (d_out, k) draw, so a device can learn nothing
    about another shard's check from its retry/hedge traffic. Same
    derivation in core/precompute.py's prefetch ring and the live path —
    cached and live shard verification are bit-identical."""
    key = jax.random.fold_in(jax.random.fold_in(
        op_key(session_key, layer_id, step), _SUB_SHARD), shard)
    return B.blinding_stream(key, (d_out, k))


def decide(policy: IntegrityPolicy, session_key: jax.Array, layer_id: int,
           step: int = 0) -> jax.Array:
    """Traced per-op check/skip decision (scalar bool).

    "full" is a trace constant (no randomness, no cond); "sampled" draws a
    Bernoulli(rate) from the verify key so distinct (session, op, step)
    triples decide independently — and re-running a session re-decides
    identically (the schedule is a pure function of the key, which is what
    lets tests and the fault injector's adaptive adversary reason about it).
    """
    if policy.mode == "full":
        return jnp.bool_(True)
    if policy.mode == "off":
        return jnp.bool_(False)
    key = jax.random.fold_in(op_key(session_key, layer_id, step), _SUB_DECIDE)
    return jax.random.uniform(key) < policy.rate


def fold_check(y_field: jax.Array, x_field: jax.Array,
               s: jax.Array, ws: jax.Array) -> jax.Array:
    """Freivalds identity: ``y @ s ≡ x @ ws (mod p)`` — scalar bool.

    y_field: (t, d_out) field elements in [0, p) (the device's answer,
    blinded or unblinded form); x_field: (t, d_in) the matching operand the
    enclave holds; s: (d_out, k); ws: (d_in, k) = (W_q @ s) mod p.

    Evaluated as ONE fold: ``[y | x] @ [s; −ws] ≡ 0 (mod p)`` — same
    MAC count as the two-fold form but a single limb-decomposition and
    mod-recombination chain, which is what keeps the honest-path verify
    overhead inside the BENCH_integrity.json budget.
    """
    from repro.kernels.limb_matmul.ref import P
    yx = jnp.concatenate([y_field, x_field], axis=1)
    s_neg = jnp.concatenate([s, jnp.mod(P - ws, P)], axis=0)
    return jnp.all(field_fold(yx, s_neg) == 0)


def checked_pair(y_field: jax.Array, x_field: jax.Array, s: jax.Array,
                 ws: jax.Array, will_check: jax.Array,
                 always: bool) -> Tuple[jax.Array, jax.Array]:
    """Run the fold check under the policy decision.

    Returns (checked, failed) scalar bools. ``always`` (static) skips the
    lax.cond so "full" mode pays no branch; under "sampled" the cond means
    a skipped op costs zero fold work at runtime.
    """
    if always:
        return jnp.bool_(True), ~fold_check(y_field, x_field, s, ws)
    ok = jax.lax.cond(will_check,
                      lambda: fold_check(y_field, x_field, s, ws),
                      lambda: jnp.bool_(True))
    return will_check, will_check & ~ok


@dataclass
class IntegrityReport:
    """Per-infer verification outcome: one slot per blinded op, in call
    order (empty arrays when the policy is off and no injector is
    installed). ``corrupted`` is the fault injector's ground truth —
    all-False on an honest device."""
    checked: jax.Array          # (n_ops,) bool — check actually ran
    failed: jax.Array           # (n_ops,) bool — check ran and mismatched
    corrupted: jax.Array        # (n_ops,) bool — injector changed the result

    @property
    def n_ops(self) -> int:
        return int(self.checked.shape[0])

    @property
    def n_checked(self) -> int:
        import numpy as np
        return int(np.asarray(self.checked).sum())

    @property
    def n_failed(self) -> int:
        import numpy as np
        return int(np.asarray(self.failed).sum())

    @property
    def n_corrupted(self) -> int:
        import numpy as np
        return int(np.asarray(self.corrupted).sum())

    @property
    def ok(self) -> bool:
        return self.n_failed == 0

    @classmethod
    def empty(cls) -> "IntegrityReport":
        z = jnp.zeros((0,), jnp.bool_)
        return cls(checked=z, failed=z, corrupted=z)
