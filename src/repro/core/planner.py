"""Cost-model partition planner: pick the Origami switch layer per model.

DarKnight/Origami-style systems fix the trust partition by hand; the paper
picks it with Algorithm 1 (a c-GAN adversary per candidate layer, minutes of
GPU per layer). Serving needs the same decision *at model-registration
time*, in milliseconds. ``PartitionPlanner`` closes that gap with two
calibrated stand-ins:

- **privacy**: a reconstruction *proxy* built on ``privacy/ssim.py`` —
  SSIM between the (normalized, grayscale) input and the channel-mean
  boundary feature map upsampled back to image resolution. It tracks the
  c-GAN trend (early conv boundaries retain scene geometry, pooled/deep
  boundaries do not) at ~1e-6 of the cost; ``verify_depth`` layers past the
  candidate are checked too, mirroring Algorithm 1's non-monotonicity
  guard. The full c-GAN search (privacy/reconstruct.py) remains the
  offline oracle.
- **cost**: the paper-calibrated ``EnclaveSim.runtime(mode, p)`` from
  core/trust.py prices every feasible partition; the planner returns the
  cheapest one (smallest ``p`` on ties).

Monotonicity invariant (tested): tightening the privacy floor only shrinks
the feasible set, and ``EnclaveSim`` runtime is non-decreasing in the
number of blinded layers (each tier-1 layer adds blind/unblind traffic on
top of the same device FLOPs), so the chosen partition never *shrinks* as
the floor tightens.

LM families have no image-SSIM analogue (their oracle is
``token_recovery_probe``, minutes of training) — for them the planner
honours the config's declared partition and marks the plan's ``source``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import plan as PL
from repro.core.integrity import IntegrityPolicy
from repro.core.trust import CalibratedCostModel, EnclaveParams, EnclaveSim
from repro.privacy.data import make_batch
from repro.privacy.ssim import ssim


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    model: str
    mode: str
    partition: int                      # chosen tier-1 depth p
    source: str                         # "planner" | "config" | "explicit"
    privacy_floor: Optional[float]
    leakage: Dict[int, float]           # boundary layer -> proxy leakage
    runtime_s: Dict[int, float]         # candidate p -> modeled runtime
    feasible: Tuple[int, ...]           # candidates meeting the floor

    def summary(self) -> str:
        leak = self.leakage.get(self.partition)
        leak_s = f"{leak:.3f}" if leak is not None else "n/a"
        rt = self.runtime_s.get(self.partition)
        rt_s = f"{rt * 1e3:.1f}ms" if rt is not None else "n/a"
        return (f"{self.model}: p={self.partition} ({self.source}) "
                f"leakage={leak_s} floor={self.privacy_floor} "
                f"modeled_runtime={rt_s}")

    def to_placement(self, cfg: ModelConfig) -> PL.PlacementPlan:
        """Compile this prefix decision to the per-layer PlacementPlan IR
        (core/plan.py) — what the executor and serving layer consume."""
        return PL.compile_mode(cfg, self.mode, self.partition)


def _grayscale_unit(x: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, C) -> (B, H, W, 1) channel-mean, min-max to [0, 1]."""
    g = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    lo = jnp.min(g, axis=(1, 2, 3), keepdims=True)
    hi = jnp.max(g, axis=(1, 2, 3), keepdims=True)
    return (g - lo) / (hi - lo + 1e-9)


def boundary_leakage(params, cfg: ModelConfig, layer: int,
                     n_images: int = 4) -> Optional[float]:
    """Reconstruction proxy for the boundary after ``layer`` (1-based).

    Channel-mean the boundary feature map, nearest-upsample it back to
    image resolution, and SSIM it against the grayscale input; contrast
    inversions leak as much as the identity, so take ``|SSIM|`` and the max
    over the feature and its negative. fc boundaries carry no spatial grid
    for this proxy to score — returns ``None`` (unmeasurable), which
    ``leakage_profile`` resolves fail-closed.
    """
    from repro.models import vgg as V
    x = jnp.asarray(make_batch(0, n_images, cfg.image_size))
    _, feat = V.vgg_forward(params, x, cfg, capture=layer)
    if feat.ndim != 4:                       # fc features: no spatial layout
        return None
    f = _grayscale_unit(feat)
    rep = cfg.image_size // f.shape[1]
    if rep > 1:
        f = jnp.repeat(jnp.repeat(f, rep, axis=1), rep, axis=2)
    g = _grayscale_unit(x)
    s = max(abs(float(ssim(f, g))), abs(float(ssim(1.0 - f, g))))
    return s


def leakage_profile(params, cfg: ModelConfig, *,
                    n_images: int = 4) -> Dict[int, float]:
    """Proxy leakage for every candidate boundary layer.

    Boundaries the proxy cannot score (fc layers — no spatial grid)
    inherit the last measurable boundary's leakage rather than scoring 0:
    a 0 would make them feasible under *any* floor (fail-open), even
    though feature-inversion attacks reconstruct fc features too. The
    carry-forward is fail-closed — an fc boundary is treated as no safer
    than the features feeding it until the offline c-GAN/probe says
    otherwise (inject its numbers via ``plan(..., leakage=...)``).
    """
    n = len(cfg.cnn_layers)
    profile: Dict[int, float] = {}
    carry = 1.0                              # nothing measured yet: unsafe
    for p in range(1, n):
        v = boundary_leakage(params, cfg, p, n_images)
        if v is None:
            v = carry
        else:
            carry = v
        profile[p] = v
    return profile


def plan_leakage(profile: Dict[int, float], plan: PL.PlacementPlan) -> float:
    """Fail-closed proxy leakage of an arbitrary PlacementPlan.

    The device observes every boundary in ``plan.exposed_boundaries()``
    (the declared boundary plus both sides of every open layer). Exposing
    boundary 0 — the raw input, i.e. the first layer runs open — is total
    leakage (1.0) by definition. Each other exposed boundary scores its
    measured proxy leakage; a boundary the proxy could not measure
    **inherits the worst upstream measured leakage** (1.0 if nothing
    upstream was measured) — so a custom or non-contiguous plan can never
    report lower leakage than the layers feeding its open steps. The
    plan's leakage is the max over all exposed boundaries; a plan
    exposing nothing (all layers protected, boundary at the logits —
    e.g. slalom/enclave) scores 0.0.
    """
    exposed = plan.exposed_boundaries()
    if not exposed:
        return 0.0
    if 0 in exposed:
        return 1.0
    worst = 0.0
    carry: Optional[float] = None            # max of measured boundaries
    n = plan.n_layers
    for p in range(1, n):
        v = profile.get(p)
        if v is not None:
            carry = v if carry is None else max(carry, v)
        if p in exposed:
            worst = max(worst, v if v is not None
                        else (1.0 if carry is None else carry))
    return worst


@dataclasses.dataclass(frozen=True)
class PlacementChoice:
    """One scored candidate from the per-layer placement sweep."""
    plan: PL.PlacementPlan
    leakage: float
    runtime_s: float

    def summary(self) -> str:
        return (f"{self.plan.summary()} leakage={self.leakage:.3f} "
                f"modeled_runtime={self.runtime_s * 1e3:.1f}ms")


class PartitionPlanner:
    """Sweeps ``EnclaveSim.runtime(mode, p)`` under a privacy floor."""

    def __init__(self, privacy_floor: float = 0.35, verify_depth: int = 2,
                 n_images: int = 4, device: str = "gpu"):
        self.privacy_floor = privacy_floor
        self.verify_depth = verify_depth
        self.n_images = n_images
        self.device = device
        # measured cost-model override (calibrate()); None = paper constants
        self.enclave_params: Optional[EnclaveParams] = None

    def _sim(self, cfg: ModelConfig) -> EnclaveSim:
        return EnclaveSim(cfg, params=self.enclave_params,
                          device=self.device)

    def calibrate(self, source) -> EnclaveParams:
        """Re-price future plans with *measured* per-phase unit costs.

        ``source`` may be a runtime/profiling.CriticalPathProfiler (its
        ``cost_observations()`` feed the fit), a pre-fitted
        CalibratedCostModel, or an explicit EnclaveParams. Returns the
        params now in force; every subsequent ``plan()`` /
        ``placement_plan()`` prices with them instead of the paper
        constants (core/trust.py keeps the paper model untouched — this
        only swaps the parameter vector this planner instance uses)."""
        if isinstance(source, EnclaveParams):
            self.enclave_params = source
        elif isinstance(source, CalibratedCostModel):
            self.enclave_params = source.fit()
        else:                      # profiler (anything with observations)
            model = CalibratedCostModel(device=self.device)
            model.observe_all(source.cost_observations())
            self.enclave_params = model.fit()
        return self.enclave_params

    def plan(self, cfg: ModelConfig, params=None, *, mode: str = "origami",
             partition: Optional[int] = None,
             leakage: Optional[Dict[int, float]] = None) -> PartitionPlan:
        """Returns the serving plan for one model.

        ``partition`` pins the choice (source="explicit"); ``leakage``
        injects a precomputed/offline profile (e.g. real c-GAN SSIMs from
        privacy/reconstruct.py) in place of the proxy.
        """
        if partition is not None:
            return PartitionPlan(cfg.name, mode, partition, "explicit",
                                 None, {}, {}, ())
        if cfg.family != "cnn" or mode not in ("origami", "split"):
            # no image-reconstruction metric (LM) or partition-free mode
            # (open/enclave/slalom): honour the config's declared point.
            return PartitionPlan(cfg.name, mode, cfg.origami.tier1_layers,
                                 "config", None, {}, {}, ())
        if leakage is None:
            assert params is not None, "planner needs params for the proxy"
            leakage = leakage_profile(params, cfg, n_images=self.n_images)
        candidates = sorted(leakage)
        n_max = max(candidates)
        n_blind_all = len(cfg.cnn_layers)   # tier-1 covers every layer
        sim = self._sim(cfg)
        runtime_s = {p: sim.runtime(mode, p).runtime_s
                     for p in candidates + [n_blind_all]}

        # Algorithm 1's verify-deeper rule: a candidate is safe only if the
        # next ``verify_depth`` boundaries are also below the floor
        # (max-pool boundaries can be safe while the next conv leaks again).
        def safe(p: int) -> float:
            window = range(p, min(p + self.verify_depth, n_max) + 1)
            return max(leakage[q] for q in window if q in leakage)

        feasible = tuple(p for p in candidates
                         if safe(p) <= self.privacy_floor)
        if not feasible:
            # no boundary is safe to expose: blind every layer (partition =
            # num layers, i.e. the Slalom regime — nothing leaves the
            # blinded tier), not the deepest *candidate*, whose boundary
            # would still be revealed.
            chosen = n_blind_all
        else:
            chosen = min(feasible, key=lambda p: (runtime_s[p], p))
        return PartitionPlan(cfg.name, mode, chosen, "planner",
                             self.privacy_floor, dict(leakage), runtime_s,
                             feasible)

    # -- per-layer placement sweep (beyond prefix cuts) ----------------------
    def placement_candidates(self, cfg: ModelConfig, boundary: int, *,
                             verify: Optional[IntegrityPolicy] = None
                             ) -> List[PL.PlacementPlan]:
        """Candidate plans for one boundary, beyond the pure blinded
        prefix: every mixed enclave/blinded tier-1 split (an enclave
        suffix of tier-1 is cheaper when its blind/unblind traffic
        outweighs SGX compute) and, when ``verify`` is set, a
        verified-open tier-2 variant (tier-2 linear layers offload
        unblinded under a Freivalds policy). All candidates expose
        exactly the same boundaries, so leakage is shared."""
        cands = [PL.compile_mode(cfg, "origami", boundary)]
        for b in range(boundary):            # blinded prefix length
            cands.append(PL.make_mixed(cfg, boundary, b,
                                       label=f"mixed@{boundary}-b{b}"))
        if verify is not None and boundary < PL.num_blocks(cfg):
            cands.append(PL.make_vopen(cfg, boundary, verify,
                                       label=f"vopen@{boundary}"))
        return cands

    def placement_plan(self, cfg: ModelConfig, params=None, *,
                       leakage: Optional[Dict[int, float]] = None,
                       verify: Optional[IntegrityPolicy] = None
                       ) -> PlacementChoice:
        """Per-layer sweep under the privacy floor: every feasible prefix
        boundary spawns ``placement_candidates``; each candidate is scored
        fail-closed (``plan_leakage``) and priced per-step
        (``EnclaveSim.plan_runtime``); the cheapest feasible plan wins
        (ties: fewer blinded layers). Falls back to all-blinded (Slalom)
        when no boundary is safe — same fail-closed rule as ``plan``."""
        assert cfg.family == "cnn", "placement sweep needs the SSIM proxy"
        if leakage is None:
            assert params is not None, "planner needs params for the proxy"
            leakage = leakage_profile(params, cfg, n_images=self.n_images)
        n = len(cfg.cnn_layers)
        sim = self._sim(cfg)
        scored: List[PlacementChoice] = []
        for boundary in sorted(leakage):
            for cand in self.placement_candidates(cfg, boundary,
                                                  verify=verify):
                leak = plan_leakage(leakage, cand)
                if leak > self.privacy_floor:
                    continue
                scored.append(PlacementChoice(
                    cand, leak, sim.plan_runtime(cand).runtime_s))
        if not scored:
            slalom = PL.compile_mode(cfg, "slalom", n)
            return PlacementChoice(slalom, 0.0,
                                   sim.plan_runtime(slalom).runtime_s)
        return min(scored, key=lambda c: (c.runtime_s,
                                          c.plan.num_blinded))
