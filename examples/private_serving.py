"""Private serving end-to-end: attestation -> sealed requests -> blinded
two-tier inference -> sealed responses (paper Fig. 3a).

    PYTHONPATH=src python examples/private_serving.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import model as M
from repro.privacy.data import make_batch
from repro.runtime.serving import PrivateInferenceServer, Request


def main():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = PrivateInferenceServer(cfg, params, mode="origami", max_batch=4)

    # client side: verify WHAT will process the data before sending keys
    quote = server.attest()
    print(f"attested: measurement={quote.measurement[:20]}… "
          f"model={quote.config_name} tier1={quote.partition} "
          f"field=Z_{quote.field_p}")

    rng = np.random.default_rng(0)
    requests, keys = [], {}
    for rid in range(10):
        img = make_batch(rid, 1, cfg.image_size)[0]
        key = rng.integers(0, 2**32 - 1, size=(2,), dtype=np.uint32)
        keys[rid] = key
        requests.append(Request(
            rid=rid, box=PrivateInferenceServer.client_seal(key, img, rid),
            shape=img.shape, session_key=key))

    t0 = time.time()
    responses = server.serve(requests)
    dt = time.time() - t0
    ok = [r for r in responses if r.ok]
    print(f"served {len(ok)}/{len(responses)} in {dt:.2f}s "
          f"({dt/len(responses)*1e3:.0f} ms/req, batch={server.max_batch})")

    logits = PrivateInferenceServer.client_open(
        keys[0], ok[0].box, (cfg.num_classes,))
    print(f"request 0 -> class {int(np.argmax(logits))} "
          f"(logits[:4]={np.round(logits[:4], 2)})")
    t = server.executor.telemetry
    print(f"enclave telemetry: {t.calls} blinded offloads, "
          f"{t.blinded_bytes/1e6:.2f} MB blinded")


if __name__ == "__main__":
    main()
