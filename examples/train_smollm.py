"""End-to-end training driver: train SmolLM-135M (reduced or full) on the
synthetic pipeline with checkpoints, resume, and the straggler watchdog.

Smoke (CPU, ~2 min):
    PYTHONPATH=src python examples/train_smollm.py --steps 60

Full-config 135M (slow on CPU; the real target is the pod mesh):
    PYTHONPATH=src python examples/train_smollm.py --full --steps 200 \
        --batch 8 --seq 512
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config, get_smoke
from repro.configs.base import TrainConfig
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_smollm_ckpt")
    args = ap.parse_args()

    cfg = (get_config if args.full else get_smoke)("smollm_135m")
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10,
                       total_steps=args.steps)
    _, _, losses = train(cfg, tcfg, batch=args.batch, seq=args.seq,
                         steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=25, log_every=10)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps (synthetic Zipf+motif stream)")
    print(f"checkpoints in {args.ckpt_dir} (resume by re-running)")


if __name__ == "__main__":
    main()
