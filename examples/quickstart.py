"""Quickstart: private inference on a VGG-16 (smoke size) in five steps.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.origami import OrigamiExecutor
from repro.core.trust import EnclaveSim
from repro.models import model as M
from repro.privacy.data import make_batch


def main():
    # 1. a pre-trained model (random weights stand in for the checkpoint)
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  layers={len(cfg.cnn_layers)} "
          f"partition p={cfg.origami.tier1_layers} (tier-1 blinded)")

    # 2. the private input
    images = jax.numpy.asarray(make_batch(0, 2, cfg.image_size))

    # 3. Origami execution: tier-1 under blinded offload, tier-2 open
    ex = OrigamiExecutor(cfg, params, mode="origami")
    result = ex.infer({"images": images})
    print(f"origami logits[0,:4] = "
          f"{np.round(np.asarray(result.logits)[0, :4], 3)}")

    # 4. verify against the non-private reference
    ref = np.asarray(ex.reference({"images": images}))
    rel = np.abs(np.asarray(result.logits) - ref).max() / np.abs(ref).max()
    print(f"vs open reference: rel err {rel:.4f} (quantization only)")
    t = result.telemetry
    print(f"telemetry: {t.calls} blinded offloads, "
          f"{t.blinded_bytes/1e6:.2f} MB blinded, "
          f"{t.offloaded_flops/1e9:.2f} GFLOP on untrusted device")

    # 5. what this buys at deployment scale (paper-calibrated cost model)
    print("\nstrategy costs (full VGG-16, calibrated to the paper):")
    from repro.configs import get_config
    sim = EnclaveSim(get_config("vgg16"), device="gpu")
    cs = sim.all_strategies(6)
    base = cs["enclave"].runtime_s
    for mode, c in cs.items():
        print(f"  {mode:8s} {c.runtime_s*1e3:8.1f} ms  "
              f"({base/c.runtime_s:5.1f}x vs full-enclave)  "
              f"enclave {c.enclave_resident_mb:.0f} MB")


if __name__ == "__main__":
    main()
