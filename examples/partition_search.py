"""Run the paper's Algorithm 1: train a c-GAN adversary per layer, measure
reconstruction SSIM, pick the earliest safe partition point (with the
paper's verify-deeper rule for non-monotone reconstructability).

    PYTHONPATH=src python examples/partition_search.py [--steps 80]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_smoke
from repro.models import model as M
from repro.privacy.reconstruct import partition_search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--threshold", type=float, default=0.35)
    args = ap.parse_args()

    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    print(f"layers: {cfg.cnn_layers}")
    t0 = time.time()
    p, reports = partition_search(params, cfg, threshold=args.threshold,
                                  steps=args.steps, batch=8, n_eval=32)
    print(f"\nSSIM per evaluated layer ({time.time()-t0:.0f}s):")
    for r in sorted(reports, key=lambda r: r.layer):
        bar = "#" * int(r.ssim * 40)
        safe = "SAFE" if r.ssim < args.threshold else "leaks"
        print(f"  layer {r.layer:2d} ({cfg.cnn_layers[r.layer-1]:7s}) "
              f"ssim={r.ssim:.3f} {bar:40s} {safe}")
    print(f"\nAlgorithm 1 partition point: p = {p} "
          f"(tier-1 = layers 1..{p} blinded, rest open)")


if __name__ == "__main__":
    main()
