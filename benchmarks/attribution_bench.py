"""Performance attribution: phase decomposition + cost-model calibration.

Two acceptance bars from DESIGN.md §14 are measured here and recorded as
``BENCH_attribution.json``:

- **decomposition**: a traced engine round folded by the
  CriticalPathProfiler must attribute every instant of each request's
  wall to exactly one phase — per-profile ``critical_sum_s`` within 10%
  of ``wall_s`` (the fold is exact by construction; the tolerance only
  absorbs float rounding) — with compile isolated in its own phase
  instead of inflating ``device_compute``.

- **calibration**: the paper-constant EnclaveParams were transcribed
  from §VI SGX/TitanXp measurements; this container is neither. A
  CalibratedCostModel fitted from the same profiler's warm observations
  must shrink the predicted-vs-measured error of the linear cost model
  ``t = sum(unit_cost x quantity)`` versus the paper constants — the
  "before/after calibration" table the ISSUE asks for. The fitted params
  then re-price a PartitionPlanner sweep (``calibrate()``), recording how
  the modeled runtime curve moves while the chosen partition stays
  floor-feasible.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict

import jax

ROUNDS = 3
REQS_PER_ROUND = 4
DECOMPOSITION_TOL_PCT = 10.0

BENCH_CONFIG = {
    "model": "vgg16 (smoke)",
    "mode": "origami",
    "rounds": ROUNDS,
    "requests_per_round": REQS_PER_ROUND,
    "decomposition_tol_pct": DECOMPOSITION_TOL_PCT,
}

# the phases the linear cost model prices (queue/seal/other are serving
# overheads outside the offload cost model)
_MODEL_PHASES = ("device_compute", "blind", "unblind", "dispatch_wait")


def _paper_unit_costs(base, device: str = "gpu") -> Dict[str, float]:
    """Per-feature unit costs implied by the paper-constant params —
    the 'before' side of the calibration table."""
    flops = base.cpu_flops * (base.gpu_speedup if device == "gpu" else 1.0)
    return {
        "device_flops": 1.0 / flops,
        "blind_bytes": 1.0 / base.blind_bytes_per_s,
        "unblind_bytes": 1.0 / base.enclave_mem_bytes_per_s,
        "dispatches": base.dispatch_overhead_s,
    }


def _linear_err_pct(costs: Dict[str, float], observations) -> float:
    """Mean relative error of ``t = sum(c x q)`` over the model phases."""
    errs = []
    for quantities, seconds in observations:
        meas = sum(seconds.get(p, 0.0) for p in _MODEL_PHASES)
        if meas <= 0.0:
            continue
        from repro.core.trust import CalibratedCostModel
        pred = sum(costs.get(f, 0.0) * quantities.get(f, 0.0)
                   for f in CalibratedCostModel.PHASE_FEATURES.values())
        errs.append(abs(pred - meas) / meas * 100.0)
    return statistics.mean(errs) if errs else float("nan")


def run_suite(emit: Callable[[str, float, str], None]) -> Dict[str, Dict]:
    from repro.configs import get_smoke
    from repro.core.planner import PartitionPlanner
    from repro.core.tracing import Tracer
    from repro.core.trust import CalibratedCostModel, EnclaveParams, EnclaveSim
    from repro.launch.serve import _sealed_requests
    from repro.models import model as M
    from repro.runtime.engine import EngineConfig, ServingEngine

    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tracer = Tracer(kernel_spans=False)
    engine = ServingEngine(EngineConfig(max_batch=REQS_PER_ROUND,
                                        max_wait_ms=10.0), tracer=tracer)
    entry = engine.register_model("vgg16", cfg, params, mode="origami")
    try:
        walls = []
        for i in range(ROUNDS + 1):        # round 0 is the cold round —
            reqs, _ = _sealed_requests(    # kept: it IS the compile probe
                cfg, REQS_PER_ROUND, rid0=1_000 * i)
            t0 = time.perf_counter()
            futs = [engine.submit("vgg16", r) for r in reqs]
            resps = [f.result(timeout=300) for f in futs]
            walls.append(time.perf_counter() - t0)
            assert all(r.ok for r in resps), \
                [r.error for r in resps if not r.ok]
        snap = engine.snapshot()
    finally:
        engine.close()

    # -- decomposition bar --------------------------------------------------
    phases = snap["phases"]
    max_err = 0.0
    for key, prof in phases["profiles"].items():
        if prof["wall_s"] > 0:
            err = (abs(prof["critical_sum_s"] - prof["wall_s"])
                   / prof["wall_s"] * 100.0)
            max_err = max(max_err, err)
    compile_s = sum(p["compile_s"] for p in phases["profiles"].values())
    decomp_ok = (max_err < DECOMPOSITION_TOL_PCT and compile_s > 0.0
                 and phases["requests"] == (ROUNDS + 1) * REQS_PER_ROUND)
    emit("attribution/decomposition", phases["wall_s"] * 1e6,
         f"requests={phases['requests']} max_err={max_err:.4f}% "
         f"compile={compile_s:.2f}s ({'OK' if decomp_ok else 'FAIL'})")

    # -- calibration bar ----------------------------------------------------
    obs = engine.profiler.cost_observations()
    base = EnclaveParams()
    model = CalibratedCostModel(base=base, device="gpu")
    model.observe_all(obs)
    base_err = _linear_err_pct(_paper_unit_costs(base), obs)
    cal_err = _linear_err_pct(model.unit_costs, obs)
    cal_ok = bool(obs) and cal_err < base_err
    # predicted-vs-measured error lives next to the phase gauges so a
    # metrics scrape sees model quality without parsing the bench JSON
    gauges = {**model.gauges(),
              "costmodel.err_pct.paper": round(base_err, 2),
              "costmodel.err_pct.calibrated": round(cal_err, 2)}
    engine.registry.gauges(gauges)

    # plan-level view: paper vs fitted pricing vs measured warm wall. The
    # executor batches REQS_PER_ROUND images per infer; the sim prices one.
    sim = EnclaveSim(cfg, device="gpu")
    plan = entry.executor.plan
    paper_pred_s = sim.plan_runtime(plan).runtime_s
    cal_pred_s = model.predict_plan_s(sim, plan)
    measured_per_image_s = statistics.median(walls[1:]) / REQS_PER_ROUND

    # planner re-pricing: same sweep, measured params in force
    planner = PartitionPlanner(privacy_floor=0.35)
    before = planner.plan(cfg, params, mode="origami")
    fitted = planner.calibrate(engine.profiler)
    after = planner.plan(cfg, params, mode="origami")
    emit("attribution/calibration", cal_err * 1e3,
         f"obs={len(obs)} base_err={base_err:.1f}% cal_err={cal_err:.1f}% "
         f"({'OK' if cal_ok else 'FAIL'})")
    emit("attribution/planner", after.runtime_s.get(after.partition,
                                                    0.0) * 1e6,
         f"p={before.partition}->{after.partition} "
         f"paper={paper_pred_s * 1e3:.2f}ms "
         f"fitted={after.runtime_s.get(after.partition, 0.0) * 1e3:.2f}ms")

    return {
        "decomposition": {
            "requests": phases["requests"],
            "wall_s": phases["wall_s"],
            "critical_s": phases["critical_s"],
            "compile_s": round(compile_s, 6),
            "max_profile_err_pct": round(max_err, 6),
            "tol_pct": DECOMPOSITION_TOL_PCT,
            "pass": decomp_ok,
        },
        "calibration": {
            "observations": len(obs),
            "unit_costs": {k: float(f"{v:.6g}")
                           for k, v in model.unit_costs.items()},
            "paper_err_pct": round(base_err, 2),
            "calibrated_err_pct": round(cal_err, 2),
            "gauges": {k: float(f"{v:.6g}") for k, v in gauges.items()},
            "improvement_x": round(base_err / cal_err, 2)
            if cal_err > 0 else None,
            "pass": cal_ok,
            "plan": {
                "digest": plan.digest[:12],
                "paper_pred_s": round(paper_pred_s, 6),
                "calibrated_pred_s": round(cal_pred_s, 6),
                "measured_per_image_s": round(measured_per_image_s, 6),
            },
            "planner": {
                "partition_before": before.partition,
                "partition_after": after.partition,
                "fitted_cpu_flops": float(f"{fitted.cpu_flops:.6g}"),
                "modeled_before_s": {
                    str(p): round(v, 6)
                    for p, v in before.runtime_s.items()},
                "modeled_after_s": {
                    str(p): round(v, 6)
                    for p, v in after.runtime_s.items()},
            },
        },
        "rounds": {"wall_s": [round(w, 4) for w in walls],
                   "cold_round_s": round(walls[0], 4),
                   "warm_median_s": round(statistics.median(walls[1:]), 4)},
    }
