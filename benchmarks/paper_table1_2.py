"""Paper Tables I (enclave memory) and II (power-event recovery) for VGG-16."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.trust import EnclaveSim

PAPER_T1 = {"enclave": 86, "split6": 29, "split8": 33, "split10": 35,
            "slalom": 39, "origami": 39}
PAPER_T2 = {"enclave": 201, "split6": 51, "split8": 54, "split10": 59}


def run(emit):
    cfg = get_config("vgg16")
    sim = EnclaveSim(cfg, device="gpu")
    for mode in ("enclave", "slalom", "origami"):
        c = sim.runtime(mode, 6)
        emit(f"table1/{mode}", c.enclave_resident_mb * 1000,
             f"MB={c.enclave_resident_mb:.1f} paper={PAPER_T1[mode]}")
        if mode == "enclave":
            emit(f"table2/{mode}", c.recovery_s * 1e6,
                 f"ms={c.recovery_s*1e3:.0f} paper={PAPER_T2[mode]}")
    for p in (6, 8, 10):
        c = sim.runtime("split", p)
        emit(f"table1/split{p}", c.enclave_resident_mb * 1000,
             f"MB={c.enclave_resident_mb:.1f} paper={PAPER_T1[f'split{p}']}")
        emit(f"table2/split{p}", c.recovery_s * 1e6,
             f"ms={c.recovery_s*1e3:.0f} paper={PAPER_T2[f'split{p}']}")


def main():
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))


if __name__ == "__main__":
    main()
