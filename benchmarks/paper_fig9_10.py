"""Paper Figs 9/10 + 12/13: inference runtime of every strategy, GPU and CPU
offload, for VGG-16/19 — from the calibrated enclave cost model driven by
our models' actual per-layer FLOP/byte profiles (core/trust.py)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.trust import EnclaveSim

PAPER_SPEEDUPS = {  # (arch, device) -> {strategy: paper speedup vs enclave}
    ("vgg16", "gpu"): {"slalom": 10.0, "origami": 12.7},
    ("vgg19", "gpu"): {"slalom": 11.0, "origami": 15.1},
    ("vgg16", "cpu"): {"slalom": 2.9, "origami": 3.9},
    ("vgg19", "cpu"): {"slalom": 2.9, "origami": 3.9},
}


def run(emit):
    for arch in ("vgg16", "vgg19"):
        cfg = get_config(arch)
        for device in ("gpu", "cpu"):
            sim = EnclaveSim(cfg, device=device)
            cs = sim.all_strategies(cfg.origami.tier1_layers)
            base = cs["enclave"].runtime_s
            paper = PAPER_SPEEDUPS.get((arch, device), {})
            for mode, c in cs.items():
                speedup = base / c.runtime_s
                emit(f"fig9_10/{arch}/{device}/{mode}",
                     c.runtime_s * 1e6,
                     f"speedup={speedup:.1f}x"
                     + (f" paper={paper[mode]:.1f}x" if mode in paper
                        else ""))


def main():
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))


if __name__ == "__main__":
    main()
