"""Integrity suite (BENCH_integrity.json): Freivalds verify overhead vs.
policy, and detection rates per dishonest-device fault class.

Two tables over the vgg16 smoke config (DESIGN.md §9):

- **overhead**: honest-device blinded-path latency under ``off`` /
  ``sampled(0.25)`` / ``full`` with k=1..2, plus the verify overhead as a
  percentage of the ``off`` baseline. The acceptance bar is full/k=1
  overhead < 15% of blinded-path latency (the check is O(t·(d_in+d_out)·k)
  against the matmul's O(t·d_in·d_out)).
- **detection**: for each fault class in runtime/faults.py, corrupted vs.
  detected op counts under ``full`` (expect rate 1.0) and ``sampled(0.25)``
  (expect ≈ rate for oblivious faults, ≈ 0 for the adaptive adversary —
  the measured argument for running ``full`` against byzantine backends).
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np


# echoed into BENCH_integrity.json's meta header by benchmarks/run.py
BENCH_CONFIG = {"model": "vgg16 (smoke)", "iters": 12, "sessions": 24}


def _executor(cfg, params, policy, fault=None):
    from repro.core.origami import OrigamiExecutor
    return OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                           integrity=policy, fault=fault)


def _time_policies(executors, batch, iters: int, key0: int):
    """Median per-infer seconds per executor, measured ROUND-ROBIN — one
    lap of every policy per outer iteration — so slow machine drift hits
    every policy equally and the off-vs-verified delta survives the noise.
    Factors are prefetched and materialized up front (the serving posture:
    the SessionPool keeps factor/fold generation off the request path)."""
    keys = [jax.random.PRNGKey(key0 + i) for i in range(iters)]
    for ex in executors:
        ex.infer(batch, session_key=jax.random.PRNGKey(1))  # compile+cache
        if ex.cache is not None:
            # default max_prefetched would FIFO-evict all but the last two
            # sessions and put their factor matmuls back on the timed path
            ex.cache.max_prefetched = iters + 1
        for k in keys:
            ex.prepare_session(k)
        if ex.cache is not None:
            jax.block_until_ready(list(ex.cache._ready.values()))
    laps = [[] for _ in executors]
    for k in keys:
        for j, ex in enumerate(executors):
            t0 = time.perf_counter()
            np.asarray(ex.infer(batch, session_key=k).logits)
            laps[j].append(time.perf_counter() - t0)
    return [float(np.median(lp)) for lp in laps]


def run_suite(emit, iters: int = 12, sessions: int = 24) -> Dict:
    from repro.configs import get_smoke
    from repro.core.integrity import IntegrityPolicy
    from repro.models import model as M
    from repro.runtime.faults import DishonestDevice, FaultSpec

    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    batch = {"images": jax.numpy.asarray(
        rng.normal(size=(4, cfg.image_size, cfg.image_size,
                         cfg.image_channels)) * 0.5, jax.numpy.float32)}

    results: Dict = {"overhead": {}, "detection": {}}

    # -- verify overhead vs. policy (honest device) ------------------------
    policies = [
        ("off", IntegrityPolicy.off()),
        ("sampled25_k1", IntegrityPolicy.sampled(0.25, 1)),
        ("full_k1", IntegrityPolicy.full(1)),
        ("full_k2", IntegrityPolicy.full(2)),
    ]
    executors = [_executor(cfg, params, pol) for _, pol in policies]
    secs = _time_policies(executors, batch, iters, key0=100)
    base_s = secs[0]
    for (name, _), sec in zip(policies, secs):
        pct = 100.0 * (sec - base_s) / base_s
        emit(f"integrity/{name}", sec * 1e6,
             f"{pct:+.1f}% vs off" if name != "off" else "baseline")
        results["overhead"][name] = {
            "us_per_infer": round(sec * 1e6, 1),
            "overhead_pct": round(pct, 2),
        }

    # -- detection rate per fault class ------------------------------------
    for kind in ("bit_flip", "row_swap", "stale", "adaptive"):
        results["detection"][kind] = {}
        for pname, pol in (("full_k1", IntegrityPolicy.full(1)),
                           ("sampled25_k1", IntegrityPolicy.sampled(0.25))):
            ex = _executor(cfg, params, pol,
                           fault=DishonestDevice(FaultSpec(kind)))
            checked = corrupted = detected = 0
            for i in range(sessions):
                rep = ex.infer(
                    batch, session_key=jax.random.PRNGKey(1000 + i)
                ).integrity
                checked += rep.n_checked
                corrupted += rep.n_corrupted
                detected += rep.n_failed
            rate = detected / corrupted if corrupted else None
            # analytic expectation: full catches every corruption (soundness
            # 1-1/p per op); sampled catches oblivious faults at its
            # Bernoulli rate; the adaptive adversary corrupts only
            # unchecked ops, so its detection rate is 0 by construction
            expected = (0.0 if kind == "adaptive"
                        else 1.0 if pname.startswith("full") else pol.rate)
            emit(f"integrity/detect/{kind}/{pname}", 0.0,
                 f"corrupted={corrupted} detected={detected}")
            results["detection"][kind][pname] = {
                "ops_checked": checked, "ops_corrupted": corrupted,
                "ops_detected": detected,
                "detection_rate": None if rate is None else round(rate, 4),
                "expected_rate": expected,
            }
    return results


def run(emit):  # benchmarks.run --suite all entry point
    run_suite(emit, iters=4, sessions=8)
