"""§VI-C microbenchmark: measured blind/unblind throughput on this host,
vs the paper's 4 ms / 6 MB SGX figure, plus the per-inference blinded-byte
totals our implementation produces for VGG-16/19 (paper: 47 MB / 51 MB)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blinding import BlindingSpec, blind_activations, \
    blinding_stream, unblind_result
from repro.configs import get_config
from repro.core.trust import vgg_layer_profiles


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(emit):
    spec = BlindingSpec()
    mb6 = 6 * 2 ** 20 // 4                     # 6 MB of fp32 elements
    x = jnp.asarray(np.random.default_rng(0).normal(size=(mb6,)),
                    jnp.float32).reshape(1536, 1024)
    r = blinding_stream(jax.random.PRNGKey(0), x.shape)
    blind_t = _time(lambda a, b: blind_activations(a, b, spec), x, r)
    u = jnp.zeros_like(r)
    y = blind_activations(x, r, spec)
    unblind_t = _time(lambda a, b: unblind_result(a, b, spec), y, u)
    emit("blinding/blind_6MB", blind_t * 1e6,
         f"GBps={6/1024/blind_t:.2f} paper_sgx=4ms/6MB")
    emit("blinding/unblind_6MB", unblind_t * 1e6,
         f"GBps={6/1024/unblind_t:.2f}")
    # per-inference blinded feature totals (paper §VI-C: 47MB / 51MB)
    for arch, paper_mb in (("vgg16", 47), ("vgg19", 51)):
        cfg = get_config(arch)
        total = sum(l.out_bytes for l in vgg_layer_profiles(cfg)
                    if l.linear)
        emit(f"blinding/features_{arch}", total / 1e3,
             f"MB={total/2**20:.0f} paper={paper_mb}MB")


def main():
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))


if __name__ == "__main__":
    main()
