"""§VI-C microbenchmark: measured blind/unblind throughput on this host,
vs the paper's 4 ms / 6 MB SGX figure, plus the per-inference blinded-byte
totals our implementation produces for VGG-16/19 (paper: 47 MB / 51 MB).

``run_suite`` additionally times the full per-layer blinded-offload call on
the VGG-16 tier-1 shapes under the three protocol data paths:

- ``unfused``    the seed path: per-request weight quantization, on-path
                 u = r@W_q factor matmul, separate blind / limb-decompose /
                 field-matmul / unblind passes;
- ``fused``      one blind->limb-encode Pallas pass + field matmul with the
                 unblind+dequantize epilogue fused in (still on-path u);
- ``fused_pre``  fused data path with all blinding material precomputed by
                 the BlindedLayerCache (the paper's offline enclave work) —
                 exactly one device field-matmul on the request path.

``benchmarks/run.py --suite blinding`` records these as BENCH_blinding.json
so later PRs have a perf trajectory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import slalom as SL
from repro.core.blinding import BlindingSpec, blind_activations, \
    blinding_stream, unblind_result
from repro.core.precompute import BlindedLayerCache
from repro.configs import get_config
from repro.core.trust import vgg_layer_profiles

# im2col dims (t, d_in, d_out) of the four blinded convs in VGG-16 tier-1
# (partition 6, batch 1): conv64 x2 at 224², conv128 x2 at 112².
VGG16_TIER1_SHAPES = (
    (224 * 224, 27, 64),
    (224 * 224, 576, 64),
    (112 * 112, 576, 128),
    (112 * 112, 1152, 128),
)

# echoed into BENCH_blinding.json's meta header by benchmarks/run.py
BENCH_CONFIG = {
    "model": "vgg16 tier-1 (partition 6, batch 1)",
    "shapes": [list(s) for s in VGG16_TIER1_SHAPES],
    "iters": 2,
}


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(emit):
    spec = BlindingSpec()
    mb6 = 6 * 2 ** 20 // 4                     # 6 MB of fp32 elements
    x = jnp.asarray(np.random.default_rng(0).normal(size=(mb6,)),
                    jnp.float32).reshape(1536, 1024)
    r = blinding_stream(jax.random.PRNGKey(0), x.shape)
    blind_t = _time(lambda a, b: blind_activations(a, b, spec), x, r)
    u = jnp.zeros_like(r)
    y = blind_activations(x, r, spec)
    unblind_t = _time(lambda a, b: unblind_result(a, b, spec), y, u)
    emit("blinding/blind_6MB", blind_t * 1e6,
         f"GBps={6/1024/blind_t:.2f} paper_sgx=4ms/6MB")
    emit("blinding/unblind_6MB", unblind_t * 1e6,
         f"GBps={6/1024/unblind_t:.2f}")
    # per-inference blinded feature totals (paper §VI-C: 47MB / 51MB)
    for arch, paper_mb in (("vgg16", 47), ("vgg19", 51)):
        cfg = get_config(arch)
        total = sum(l.out_bytes for l in vgg_layer_profiles(cfg)
                    if l.linear)
        emit(f"blinding/features_{arch}", total / 1e3,
             f"MB={total/2**20:.0f} paper={paper_mb}MB")


def _layer_call(t, d_in, d_out, impl, precompute, seed=0):
    """Build a jitted end-to-end blinded_dense call for one layer shape."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, d_in)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d_in, d_out)) / np.sqrt(d_in),
                    jnp.float32)
    spec = BlindingSpec()
    key = jax.random.PRNGKey(seed)
    factors = None
    if precompute:
        cache = BlindedLayerCache.from_records(
            [{"kind": "dense", "w": w, "t": t,
              "d_in": d_in, "d_out": d_out}], spec)
        factors = cache.session_factors(key)   # offline work, not timed

    @jax.jit
    def call(xx):
        ctx = SL.SlalomContext(key, spec, impl=impl, factors=factors)
        return SL.blinded_dense(ctx, {"w": w}, xx)

    return call, x


def run_suite(emit, iters=2, shapes=VGG16_TIER1_SHAPES):
    """Fused/precompute matrix over the VGG-16 tier-1 layer shapes."""
    paths = (("unfused", "unfused", False),
             ("fused", "fused", False),
             ("fused_pre", "fused", True))
    for li, (t, d_in, d_out) in enumerate(shapes):
        times = {}
        for name, impl, pre in paths:
            call, x = _layer_call(t, d_in, d_out, impl, pre)
            times[name] = _time(call, x, iters=iters)
        base = times["unfused"]
        for name, _, _ in paths:
            emit(f"blinding/vgg16_t1l{li}_{name}", times[name] * 1e6,
                 f"shape={t}x{d_in}x{d_out} speedup_vs_unfused="
                 f"{base / times[name]:.2f}x")


def main():
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
    run_suite(lambda n, us, d: print(f"{n},{us:.1f},{d}"))


if __name__ == "__main__":
    main()
