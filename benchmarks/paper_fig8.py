"""Paper Fig 8: SSIM between real and c-GAN-reconstructed images per
partition layer (smoke-scale VGG on the synthetic dataset).

Full sweep is minutes of CPU; ``--budget`` trades steps for time. The
qualitative target from the paper: high SSIM in the first conv layers, a
dip at the first max-pool, a REBOUND at the following conv (the paper's
"surprising observation"), then low beyond the safe partition point.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_smoke
from repro.models import model as M
from repro.privacy.reconstruct import train_adversary


def run(emit, steps: int = 120, layers=None):
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    layers = layers or range(1, len(cfg.cnn_layers) - 1)
    for layer in layers:
        rep = train_adversary(params, cfg, layer=layer, steps=steps,
                              batch=8, n_eval=32)
        kind = cfg.cnn_layers[layer - 1]
        emit(f"fig8/ssim_layer{layer}", rep.ssim * 1e6,
             f"ssim={rep.ssim:.3f} layer_type={kind}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"), steps=args.steps)


if __name__ == "__main__":
    main()
