"""Sharded-offload suite (BENCH_offload.json): wall time vs. pool width.

Measures the offloaded field matmul through the multi-device plane
(parallel/offload_sharding.py) over 1/2/4 simulated devices, rows vs.
additive shares, and straggler hedging on vs. off:

- **scaling**: each simulated slot models a fixed-throughput accelerator
  (``sim_gflops``: the slot sleeps out its shard's modeled compute time, on
  top of the real CPU matmul), so the measured wall time is the modeled
  multi-device wall clock — rows sharding must DECREASE from 1 -> 2
  devices (the acceptance bar), while shares replicate the full matmul per
  device (the non-collusion guarantee costs n× work, documented in
  DESIGN.md §11) and hold roughly flat.
- **hedging**: one slot is a chronic straggler (large fixed
  ``sim_delay_s``); with hedging on, its shard is duplicated to the fast
  spare once the StepWatchdog deadline passes and the first verified
  result wins — p50 wall time must beat the hedging-off run.

Shard-local Freivalds checks stay ON throughout (they are structural to
the plane), so every number includes verification cost.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

# modeled accelerator throughput: small enough that the modeled compute
# (the slot's sleep) dominates the harness's real CPU matmul at the bench
# shape ~10x — the CI box has 2 cores, so real compute cannot itself scale
# past 2 threads and must not pollute the modeled wall clocks
SIM_GFLOPS = 0.08
SHAPE = (256, 128, 128)                     # (t, d_in, d_out)
ITERS = 8


# echoed into BENCH_offload.json's meta header by benchmarks/run.py
BENCH_CONFIG = {"shape": list(SHAPE), "iters": ITERS,
                "sim_gflops": SIM_GFLOPS}


def _operands(t: int, d_in: int, d_out: int):
    from repro.core.blinding import blinding_stream
    key = jax.random.PRNGKey(0)
    x = blinding_stream(jax.random.fold_in(key, 1), (t, d_in))
    w = blinding_stream(jax.random.fold_in(key, 2), (d_in, d_out))
    return x, w


def _time_plane(plane, x, w, iters: int = ITERS) -> float:
    """Median wall seconds of one sharded offloaded matmul."""
    laps = []
    for i in range(iters):
        key = jax.random.PRNGKey(100 + i)
        t0 = time.perf_counter()
        y = plane.matmul(x, w, session_key=key, op_index=0)
        jax.block_until_ready(y)
        laps.append(time.perf_counter() - t0)
    return float(np.median(laps))


def run_suite(emit, iters: int = ITERS) -> Dict:
    from repro.parallel.offload_sharding import OffloadPlane
    from repro.runtime.devices import DevicePool

    t, d_in, d_out = SHAPE
    x, w = _operands(t, d_in, d_out)
    results: Dict[str, Dict] = {"shape": {"t": t, "d_in": d_in,
                                          "d_out": d_out},
                                "sim_gflops": SIM_GFLOPS,
                                "scaling": {}, "hedging": {}}

    # -- scaling: 1/2/4 devices × rows/shares ------------------------------
    base_us = {}
    for mode in ("rows", "shares"):
        for n in (1, 2, 4):
            pool = DevicePool(n, sim_gflops=SIM_GFLOPS)
            plane = OffloadPlane(pool, mode=mode, hedging=False,
                                 matmul_impl="ref")
            # warm the jit caches off the clock
            jax.block_until_ready(
                plane.matmul(x, w, session_key=jax.random.PRNGKey(9),
                             op_index=0))
            us = _time_plane(plane, x, w, iters) * 1e6
            pool.close()
            base_us[(mode, n)] = us
            speed = base_us[(mode, 1)] / us
            emit(f"offload_{mode}_{n}dev", us, f"x{speed:.2f}_vs_1dev")
            results["scaling"][f"{mode}_{n}dev"] = {
                "us": round(us, 1), "speedup_vs_1dev": round(speed, 3)}
    results["scaling"]["rows_speedup_1to2"] = round(
        base_us[("rows", 1)] / base_us[("rows", 2)], 3)

    # -- hedging: one chronic straggler ------------------------------------
    straggle = 12 * base_us[("rows", 2)] / 2 / 1e6   # ~12x a fair shard
    for hedging in (False, True):
        pool = DevicePool(2, sim_gflops=SIM_GFLOPS,
                          sim_delay_s={1: straggle})
        plane = OffloadPlane(pool, mode="rows", hedging=hedging,
                             matmul_impl="ref")
        jax.block_until_ready(
            plane.matmul(x, w, session_key=jax.random.PRNGKey(9),
                         op_index=0))
        us = _time_plane(plane, x, w, iters) * 1e6
        tag = "on" if hedging else "off"
        emit(f"offload_hedge_{tag}", us,
             f"hedges={plane.totals.hedges}")
        results["hedging"][tag] = {"us": round(us, 1),
                                   "hedges": plane.totals.hedges}
        pool.close()
    results["hedging"]["speedup"] = round(
        results["hedging"]["off"]["us"] / results["hedging"]["on"]["us"], 3)
    return results


def run(emit):
    run_suite(emit, iters=4)
