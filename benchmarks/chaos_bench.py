"""Liveness chaos suite (BENCH_chaos.json): detection -> recovery latency.

Measures the liveness fault-tolerance plane (DESIGN.md §12) per fault
class, plus one full engine degradation cycle:

- **per class** (``crash`` / ``hang`` / ``flaky`` / ``brownout``): a 2-slot
  pool serves sharded blinded matmuls while the class's injector
  (runtime/faults.py ``UnresponsiveDevice``) is armed on device 0.
  Detection = steps (and wall seconds) from arming until device 0's
  circuit breaker OPENs; recovery = steps from disarming until a half-open
  probe CLOSEs it again. Goodput is verified matmuls/s while the fault is
  live — the plane must keep serving on the surviving device. ``brownout``
  never errors and must NOT trip the breaker (its latency inflation is the
  straggler plane's problem); the suite reports its inflation ratio and
  asserts zero breaker opens.
- **engine cycle**: a scripted total blackout (crash dev0 + hang dev1,
  runtime/chaos.py) against the ServingEngine — batches to first degraded
  dispatch (detection), batches from disarm to the recovered flag
  (recovery), end-to-end goodput, and the breaker/degraded transition
  counters from ``engine.snapshot()``.

Shard-local Freivalds checks stay ON throughout, so every number includes
verification cost.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

SHAPE = (64, 64, 64)                    # (t, d_in, d_out) — real CPU cost
STEP_CAP = 24                           # detection/recovery step ceilings


# echoed into BENCH_chaos.json's meta header by benchmarks/run.py
BENCH_CONFIG = {"shape": list(SHAPE), "step_cap": STEP_CAP}


def _operands(t: int, d_in: int, d_out: int):
    from repro.core.blinding import blinding_stream
    key = jax.random.PRNGKey(0)
    x = blinding_stream(jax.random.fold_in(key, 1), (t, d_in))
    w = blinding_stream(jax.random.fold_in(key, 2), (d_in, d_out))
    return x, w


def _steps_until(plane, x, w, op0: int, done, cap: int = STEP_CAP):
    """(steps, wall_s) of sharded matmuls until ``done()`` (None = cap)."""
    t0 = time.perf_counter()
    for i in range(cap):
        y = plane.matmul(x, w, session_key=jax.random.PRNGKey(op0 + i),
                         op_index=op0 + i)
        jax.block_until_ready(y)
        if done():
            return i + 1, time.perf_counter() - t0
    return None, time.perf_counter() - t0


def _class_cycle(kind: str, emit) -> Dict:
    """One arm -> detect -> disarm -> recover cycle for a fault class."""
    from repro.parallel.offload_sharding import LivenessConfig, OffloadPlane
    from repro.runtime.devices import (BREAKER_CLOSED, DeviceHealthConfig,
                                       DevicePool)
    from repro.runtime.faults import LivenessSpec, UnresponsiveDevice

    t, d_in, d_out = SHAPE
    x, w = _operands(t, d_in, d_out)
    pool = DevicePool(2, health=DeviceHealthConfig(breaker_after=2,
                                                   breaker_cooldown=2))
    plane = OffloadPlane(pool, mode="rows", hedging=False,
                         liveness=LivenessConfig(timeout_floor_s=0.1,
                                                 cold_timeout_s=1.0))
    slot = pool.slots[0]

    # healthy baseline (also warms jit + the plane's watchdog)
    laps = []
    for i in range(6):
        t0 = time.perf_counter()
        jax.block_until_ready(
            plane.matmul(x, w, session_key=jax.random.PRNGKey(i),
                         op_index=i))
        laps.append(time.perf_counter() - t0)
    healthy_s = float(np.median(laps))

    # brownout stays under the dispatch timeout: never an error, never a
    # breaker trip — every other class must open the breaker
    slot.liveness = UnresponsiveDevice(
        LivenessSpec(kind=kind, delay_s=0.03), seed=7)
    det_steps, det_s = _steps_until(
        plane, x, w, 100,
        lambda: slot.breaker != BREAKER_CLOSED,
        cap=4 if kind == "brownout" else STEP_CAP)
    if kind == "brownout":
        inflation = (det_s / 4) / healthy_s
        det_steps = None                 # by design: nothing to detect

    # goodput while the fault is live (the surviving device serves)
    n_fault, fault_s = _steps_until(plane, x, w, 200, lambda: False, cap=6)

    slot.liveness = None
    rec_steps, rec_s = _steps_until(
        plane, x, w, 300, lambda: slot.breaker == BREAKER_CLOSED,
        cap=4 if kind == "brownout" else STEP_CAP)
    if kind == "brownout":
        rec_steps = None

    snap = slot.snapshot()
    pool.close()
    out = {
        "detection_steps": det_steps,
        "detection_s": round(det_s, 4),
        "recovery_steps": rec_steps,
        "recovery_s": round(rec_s, 4),
        "goodput_faulted_sps": round(6 / fault_s, 2),
        "goodput_healthy_sps": round(1.0 / healthy_s, 2),
        "crashes": plane.totals.crashes,
        "timeouts": plane.totals.timeouts,
        "backoffs": plane.totals.backoffs,
        "breaker": {k: snap[k] for k in
                    ("breaker", "breaker_opens", "breaker_probes",
                     "breaker_closes", "abandons", "available")},
    }
    if kind == "brownout":
        out["latency_inflation"] = round(inflation, 2)
        assert snap["breaker_opens"] == 0, \
            "brownout must not trip the circuit breaker"
    else:
        assert det_steps is not None, f"{kind} never opened the breaker"
        assert rec_steps is not None, f"{kind} breaker never re-closed"
        assert snap["available"], f"{kind} device not re-admitted"
    emit(f"chaos_{kind}_detect", det_s * 1e6,
         f"steps={det_steps}_rec={rec_steps}")
    return out


def _engine_cycle(emit) -> Dict:
    """Scripted total blackout through the ServingEngine: degradation to
    enclave-only serving, then automatic recovery via breaker probes."""
    from repro.configs import get_smoke
    from repro.launch.serve import _sealed_requests
    from repro.models import model as M
    from repro.parallel.offload_sharding import LivenessConfig
    from repro.runtime.chaos import ChaosController, ChaosSchedule
    from repro.runtime.devices import DeviceHealthConfig, DevicePool
    from repro.runtime.engine import EngineConfig, ServingEngine

    name = "vgg16"
    cfg = get_smoke(name)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    schedule = ChaosSchedule.parse("dev0.crash@1-2,dev1.hang@1-2")
    n_batches = schedule.horizon + 8

    pool = DevicePool(2, health=DeviceHealthConfig(breaker_after=2,
                                                   breaker_cooldown=2))
    chaos = ChaosController(schedule)
    engine = ServingEngine(EngineConfig(max_batch=1, max_wait_ms=10.0))
    engine.register_model(name, cfg, params, mode="origami",
                          devices=pool, shard="rows",
                          liveness=LivenessConfig(cold_timeout_s=2.0),
                          chaos=chaos)
    reqs, _ = _sealed_requests(cfg, n_batches)

    t0 = time.perf_counter()
    first_degraded = first_recovered = None
    ok = 0
    for j in range(n_batches):
        resp = engine.submit(name, reqs[j]).result(timeout=120)
        ok += resp.ok
        degraded = engine.snapshot()["models"][name]["degraded"]
        if degraded and first_degraded is None:
            first_degraded = j
        if (first_degraded is not None and not degraded
                and first_recovered is None):
            first_recovered = j
    dt = time.perf_counter() - t0

    snap = engine.snapshot()
    slots = next(iter(snap["devices"].values()))["pool"]["slots"]
    engine.close()
    fault_start = min(ev.start for ev in schedule.events)
    assert first_degraded is not None, "blackout never degraded the engine"
    assert first_recovered is not None, "engine never recovered"
    assert ok == n_batches, f"only {ok}/{n_batches} served under chaos"
    out = {
        "schedule": str(schedule),
        "batches": n_batches,
        "detection_batches": first_degraded - fault_start,
        "recovery_batches": first_recovered - schedule.horizon,
        "first_degraded_batch": first_degraded,
        "first_recovered_batch": first_recovered,
        "goodput_rps": round(ok / dt, 2),
        "liveness": snap["liveness"],
        "breakers": [{k: s[k] for k in
                      ("name", "breaker", "breaker_opens",
                       "breaker_closes", "available")} for s in slots],
    }
    emit("chaos_engine_cycle", dt * 1e6,
         f"degraded@{first_degraded}_recovered@{first_recovered}")
    return out


def run_suite(emit) -> Dict:
    from repro.runtime.faults import LIVENESS_KINDS
    results: Dict[str, Dict] = {
        "config": {"shape": dict(zip(("t", "d_in", "d_out"), SHAPE)),
                   "breaker_after": 2, "breaker_cooldown": 2},
        "classes": {},
    }
    for kind in LIVENESS_KINDS:
        results["classes"][kind] = _class_cycle(kind, emit)
    results["engine"] = _engine_cycle(emit)
    return results


def run(emit):
    # the aggregate run skips the (slow) engine cycle
    from repro.runtime.faults import LIVENESS_KINDS
    for kind in LIVENESS_KINDS:
        _class_cycle(kind, emit)
