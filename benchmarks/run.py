"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Fast by default; pass --full for
the c-GAN SSIM sweep (paper Fig 8, minutes of CPU) and --roofline to print
the dry-run roofline table (requires artifacts from launch/dryrun.py).

``--suite blinding`` runs only the blinded-path matrix (fused vs. unfused,
with/without precompute, VGG-16 tier-1 shapes) and records it as
``BENCH_blinding.json`` next to this file so successive PRs accumulate a
perf trajectory. ``--suite serving`` sweeps the async ServingEngine over
offered loads (mixed vgg16/vgg19 smoke traffic) into ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import traceback


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


# recorded suites: --suite NAME runs benchmarks/MODULE.run_suite and stamps
# the results into BENCH_*.json through the shared bench_meta envelope
# (schema version + suite name + backend + the module's BENCH_CONFIG echo)
RECORDED_SUITES = {
    "blinding": ("blinding_micro", "BENCH_blinding.json"),
    "serving": ("serving_bench", "BENCH_serving.json"),
    "integrity": ("integrity_bench", "BENCH_integrity.json"),
    "plans": ("plans_bench", "BENCH_plans.json"),
    "offload": ("offload_bench", "BENCH_offload.json"),
    "chaos": ("chaos_bench", "BENCH_chaos.json"),
    "trace": ("trace_overhead_bench", "BENCH_trace_overhead.json"),
    "attribution": ("attribution_bench", "BENCH_attribution.json"),
    "decode": ("decode_bench", "BENCH_decode.json"),
}


def run_recorded_suite(suite: str, out_path: pathlib.Path) -> None:
    import importlib

    from benchmarks import bench_meta
    mod_name, _ = RECORDED_SUITES[suite]
    mod = importlib.import_module(f"benchmarks.{mod_name}")
    recorded = {}

    def record(name: str, us: float, derived: str = ""):
        emit(name, us, derived)
        recorded[name] = {"us": round(us, 1), "derived": derived}

    # suites either return their results dict (and emit() rows as a side
    # effect) or emit rows only — in that case the recorded rows ARE the
    # results (blinding_micro's original contract)
    results = mod.run_suite(record)
    if results is None:
        results = recorded
    bench_meta.write_bench(out_path, suite, results,
                           config=getattr(mod, "BENCH_CONFIG", {}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the c-GAN SSIM layer sweep (slow)")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--suite",
                    choices=["all"] + sorted(RECORDED_SUITES),
                    default="all",
                    help="'blinding' runs the fused/precompute matrix into "
                         "BENCH_blinding.json; 'serving' sweeps the engine "
                         "over offered loads into BENCH_serving.json; "
                         "'integrity' measures Freivalds verify overhead "
                         "and fault detection rates into "
                         "BENCH_integrity.json; 'plans' compares prefix vs "
                         "mixed PlacementPlans (latency/leakage) into "
                         "BENCH_plans.json; 'offload' scales the sharded "
                         "multi-device plane over 1/2/4 simulated devices "
                         "(rows vs shares, hedging on/off) into "
                         "BENCH_offload.json; 'chaos' measures liveness "
                         "detection->recovery latency per fault class and "
                         "one engine degradation cycle into "
                         "BENCH_chaos.json; 'trace' measures span-tracing "
                         "overhead (on vs off, <5%% bar) into "
                         "BENCH_trace_overhead.json; 'attribution' folds "
                         "a traced round into the §14 phase decomposition "
                         "and fits the calibrated cost model into "
                         "BENCH_attribution.json; 'decode' measures "
                         "private vs trusted-only vs open autoregressive "
                         "tokens/sec (§16) into BENCH_decode.json")
    args, _ = ap.parse_known_args()

    root = pathlib.Path(__file__).resolve().parent.parent
    if args.suite in RECORDED_SUITES:
        _, out_name = RECORDED_SUITES[args.suite]
        run_recorded_suite(args.suite, root / out_name)
        return

    from benchmarks import (blinding_micro, exec_micro, integrity_bench,
                            offload_bench, paper_fig2_4_11, paper_fig9_10,
                            paper_table1_2, plans_bench)
    suites = [paper_fig9_10.run, paper_table1_2.run, paper_fig2_4_11.run,
              blinding_micro.run, exec_micro.run, integrity_bench.run,
              plans_bench.run, offload_bench.run]
    if args.full:
        from benchmarks import paper_fig8
        suites.append(lambda e: paper_fig8.run(e, steps=150))
    for suite in suites:
        try:
            suite(emit)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{suite.__module__},0.0,ERROR", file=sys.stderr)

    if args.roofline:
        from benchmarks.roofline import format_table, load_rows
        print(format_table(load_rows()))


if __name__ == "__main__":
    main()
