"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Fast by default; pass --full for
the c-GAN SSIM sweep (paper Fig 8, minutes of CPU) and --roofline to print
the dry-run roofline table (requires artifacts from launch/dryrun.py).

``--suite blinding`` runs only the blinded-path matrix (fused vs. unfused,
with/without precompute, VGG-16 tier-1 shapes) and records it as
``BENCH_blinding.json`` next to this file so successive PRs accumulate a
perf trajectory. ``--suite serving`` sweeps the async ServingEngine over
offered loads (mixed vgg16/vgg19 smoke traffic) into ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def run_blinding_suite(out_path: pathlib.Path) -> None:
    from benchmarks import blinding_micro
    results = {}

    def record(name: str, us: float, derived: str = ""):
        emit(name, us, derived)
        results[name] = {"us": round(us, 1), "derived": derived}

    blinding_micro.run_suite(record)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"wrote {out_path}", file=sys.stderr)


def run_serving_suite(out_path: pathlib.Path) -> None:
    from benchmarks import serving_bench
    results = serving_bench.run_suite(emit)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"wrote {out_path}", file=sys.stderr)


def run_integrity_suite(out_path: pathlib.Path) -> None:
    from benchmarks import integrity_bench
    results = integrity_bench.run_suite(emit)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"wrote {out_path}", file=sys.stderr)


def run_plans_suite(out_path: pathlib.Path) -> None:
    from benchmarks import plans_bench
    results = plans_bench.run_suite(emit)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"wrote {out_path}", file=sys.stderr)


def run_offload_suite(out_path: pathlib.Path) -> None:
    from benchmarks import offload_bench
    results = offload_bench.run_suite(emit)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"wrote {out_path}", file=sys.stderr)


def run_chaos_suite(out_path: pathlib.Path) -> None:
    from benchmarks import chaos_bench
    results = chaos_bench.run_suite(emit)
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(f"wrote {out_path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the c-GAN SSIM layer sweep (slow)")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--suite",
                    choices=["all", "blinding", "serving", "integrity",
                             "plans", "offload", "chaos"],
                    default="all",
                    help="'blinding' runs the fused/precompute matrix into "
                         "BENCH_blinding.json; 'serving' sweeps the engine "
                         "over offered loads into BENCH_serving.json; "
                         "'integrity' measures Freivalds verify overhead "
                         "and fault detection rates into "
                         "BENCH_integrity.json; 'plans' compares prefix vs "
                         "mixed PlacementPlans (latency/leakage) into "
                         "BENCH_plans.json; 'offload' scales the sharded "
                         "multi-device plane over 1/2/4 simulated devices "
                         "(rows vs shares, hedging on/off) into "
                         "BENCH_offload.json; 'chaos' measures liveness "
                         "detection->recovery latency per fault class and "
                         "one engine degradation cycle into "
                         "BENCH_chaos.json")
    args, _ = ap.parse_known_args()

    root = pathlib.Path(__file__).resolve().parent.parent
    if args.suite == "blinding":
        run_blinding_suite(root / "BENCH_blinding.json")
        return
    if args.suite == "serving":
        run_serving_suite(root / "BENCH_serving.json")
        return
    if args.suite == "integrity":
        run_integrity_suite(root / "BENCH_integrity.json")
        return
    if args.suite == "plans":
        run_plans_suite(root / "BENCH_plans.json")
        return
    if args.suite == "offload":
        run_offload_suite(root / "BENCH_offload.json")
        return
    if args.suite == "chaos":
        run_chaos_suite(root / "BENCH_chaos.json")
        return

    from benchmarks import (blinding_micro, exec_micro, integrity_bench,
                            offload_bench, paper_fig2_4_11, paper_fig9_10,
                            paper_table1_2, plans_bench)
    suites = [paper_fig9_10.run, paper_table1_2.run, paper_fig2_4_11.run,
              blinding_micro.run, exec_micro.run, integrity_bench.run,
              plans_bench.run, offload_bench.run]
    if args.full:
        from benchmarks import paper_fig8
        suites.append(lambda e: paper_fig8.run(e, steps=150))
    for suite in suites:
        try:
            suite(emit)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{suite.__module__},0.0,ERROR", file=sys.stderr)

    if args.roofline:
        from benchmarks.roofline import format_table, load_rows
        print(format_table(load_rows()))


if __name__ == "__main__":
    main()
