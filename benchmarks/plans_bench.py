"""Plan-placement benchmark: prefix cuts vs mixed placements.

``--suite plans`` (benchmarks/run.py) measures, on the smoke VGG-16, the
end-to-end executor latency of a spread of PlacementPlans — the five
legacy prefix shapes plus plans only the IR can express (mixed
enclave/blinded tier-1, verified-open tier-2) — alongside their
fail-closed proxy leakage (core/planner.py:plan_leakage) and the
paper-calibrated modeled runtime (core/trust.py:plan_runtime on the full
config). The table lands in BENCH_plans.json so successive PRs accumulate
a latency/leakage trajectory per placement shape.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core import plan as PL
from repro.core.integrity import IntegrityPolicy
from repro.core.origami import OrigamiExecutor
from repro.core.planner import leakage_profile, plan_leakage
from repro.core.trust import EnclaveSim
from repro.models import model as M


# echoed into BENCH_plans.json's meta header by benchmarks/run.py
BENCH_CONFIG = {"model": "vgg16 (smoke timed, full modeled)", "iters": 5,
                "plans": "legacy modes + mixed + vopen"}


def _bench_plans(cfg):
    """The measured spread: every legacy shape + IR-only placements
    (mixed enclave/blinded tier-1, verified-open tier-2)."""
    return ([PL.compile_mode(cfg, m) for m in PL.LEGACY_MODES]
            + [PL.make_mixed(cfg), PL.make_vopen(cfg)])


def run_suite(record, iters: int = 5) -> dict:
    cfg = get_smoke("vgg16")
    full = get_config("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jax.random.normal(
        jax.random.PRNGKey(1),
        (2, cfg.image_size, cfg.image_size, 3)) * 0.5}
    profile = leakage_profile(params, cfg, n_images=2)
    sim = EnclaveSim(full, device="gpu")
    results = {}
    for plan in _bench_plans(cfg):
        ex = OrigamiExecutor(cfg, params, plan=plan, precompute=True,
                             integrity=IntegrityPolicy.full(1)
                             if any(s.integrity for s in plan.steps)
                             else None)
        keys = [jax.random.PRNGKey(100 + i) for i in range(iters + 1)]
        jax.block_until_ready(ex.infer(batch, session_key=keys[0]).logits)
        t0 = time.perf_counter()
        for i in range(iters):
            jax.block_until_ready(
                ex.infer(batch, session_key=keys[1 + i]).logits)
        us = (time.perf_counter() - t0) / iters * 1e6
        leak = plan_leakage(profile, plan)
        # model the FULL config's plan with the same placement shape
        full_plan = _scale_plan(plan, full)
        modeled_ms = (sim.plan_runtime(full_plan).runtime_s * 1e3
                      if full_plan is not None else float("nan"))
        derived = (f"leakage={leak:.3f} modeled_full_ms={modeled_ms:.1f} "
                   f"placements={plan.placement_string}")
        record(f"plan_{plan.mode_label}", us, derived)
        results[plan.mode_label] = {
            "us": round(us, 1), "leakage": round(leak, 4),
            "modeled_full_ms": round(modeled_ms, 2),
            "placements": plan.placement_string,
            "boundary": plan.boundary, "digest": plan.digest[:12],
        }
    return results


def _scale_plan(smoke_plan, full_cfg):
    """Re-express a smoke plan's shape on the full config (same prefix
    fractions) so the cost model prices the paper-scale network."""
    n_full = len(full_cfg.cnn_layers)
    n_smoke = smoke_plan.n_layers
    placements, integrity = [], {}
    for i in range(n_full):
        st = smoke_plan.steps[min(i * n_smoke // n_full, n_smoke - 1)]
        placements.append(st.placement)
        if st.integrity is not None:
            integrity[i] = st.integrity
    boundary = min(smoke_plan.boundary * n_full // n_smoke, n_full)
    try:
        return PL.make_plan(full_cfg, placements, integrity=integrity,
                            boundary=boundary, label=smoke_plan.mode_label)
    except AssertionError:
        return None


def run(emit):
    run_suite(emit, iters=3)
