"""Private decode suite (BENCH_decode.json): tokens/sec across trust modes.

Measures autoregressive generation (DESIGN.md §16) on the smollm smoke
config, same prompt batch through three ladders:

- ``open``     — the plain ``generate()`` reference loop, no protocol:
                 the ceiling any private path is paying against;
- ``trusted``  — ``private_generate(trusted=True)``: every matmul
                 recomputed in the enclave (the §9 recovery rung and the
                 §12 degraded mode), no device traffic;
- ``private``  — blinded ring-fed decode with full per-step Freivalds
                 verification: pads from the token-slot ring, KV-facing
                 matmuls on the device, every step verified.

The suite also records ``parity_bitexact`` — private tokens AND logits
must equal the trusted oracle bit for bit (the gate pins this at
never-regress) — plus the ring's refill counters and the §16
``tier1_cache_bytes`` enclave-residency figure for the measured shape.

Timings are steady-state: every path runs once to compile (prefill +
decode executables land in the AOT cache) before the timed repeats.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

BATCH = 2
PROMPT_LEN = 6
NEW_TOKENS = 12
REPEATS = 3

# echoed into BENCH_decode.json's meta header by benchmarks/run.py
BENCH_CONFIG = {"model": "smollm_135m", "batch": BATCH,
                "prompt_len": PROMPT_LEN, "new_tokens": NEW_TOKENS,
                "repeats": REPEATS, "integrity": "full_k2"}


def _tokens_per_s(fn, n_tokens: int) -> Dict:
    fn()                                    # compile / warm
    laps = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        fn()
        laps.append(time.perf_counter() - t0)
    best = min(laps)
    return {"tokens_per_s": round(n_tokens / best, 2),
            "s_per_seq": round(best, 4)}


def run_suite(emit) -> Dict:
    from repro.configs import get_smoke
    from repro.core import integrity as IG
    from repro.models import model as M
    from repro.runtime import generate as G

    cfg = get_smoke("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (BATCH, PROMPT_LEN), 0, cfg.vocab_size)
    pol = IG.IntegrityPolicy.full(k=2)
    skey = jax.random.PRNGKey(7)
    n_tokens = BATCH * NEW_TOKENS
    max_seq = PROMPT_LEN + NEW_TOKENS

    # one executor so all modes share compiled decode/prefill executables
    ex = G.GenerateExecutor(cfg, params, prompt_len=PROMPT_LEN,
                            max_new_tokens=NEW_TOKENS, integrity=pol)

    def run_open():
        res = G.generate(params, prompt, cfg, max_new_tokens=NEW_TOKENS)
        jax.block_until_ready(res.tokens)
        return res

    def run_trusted():
        res = G.private_generate(params, prompt, cfg,
                                 max_new_tokens=NEW_TOKENS,
                                 session_key=skey, trusted=True,
                                 executor=ex)
        jax.block_until_ready(res.tokens)
        return res

    def run_private():
        res = G.private_generate(params, prompt, cfg,
                                 max_new_tokens=NEW_TOKENS,
                                 session_key=skey, executor=ex)
        jax.block_until_ready(res.tokens)
        return res

    results: Dict[str, Dict] = {
        "open": _tokens_per_s(run_open, n_tokens),
        "trusted": _tokens_per_s(run_trusted, n_tokens),
        "private": _tokens_per_s(run_private, n_tokens),
    }

    # parity + protocol counters from one final instrumented pair
    priv, oracle = run_private(), run_trusted()
    bitexact = (np.array_equal(np.asarray(priv.tokens),
                               np.asarray(oracle.tokens))
                and np.array_equal(np.asarray(priv.logits),
                                   np.asarray(oracle.logits)))
    results["private"].update({
        "parity_bitexact": bool(bitexact),
        "verified_ops": int(priv.integrity.n_checked),
        "integrity_ok": bool(priv.integrity.ok),
        "ring": priv.ring,
    })
    results["private"]["overhead_x"] = round(
        results["open"]["tokens_per_s"]
        / max(results["private"]["tokens_per_s"], 1e-9), 2)
    results["trusted"]["overhead_x"] = round(
        results["open"]["tokens_per_s"]
        / max(results["trusted"]["tokens_per_s"], 1e-9), 2)
    results["cache"] = {
        "tier1_cache_bytes": G.tier1_cache_bytes(cfg, BATCH, max_seq),
        "plan_digest": priv.plan_digest[:16],
    }

    for mode in ("open", "trusted", "private"):
        emit(f"decode/{mode}", results[mode]["s_per_seq"] * 1e6,
             f"{results[mode]['tokens_per_s']} tok/s")
    emit("decode/parity", 0.0, f"bitexact={bitexact}")
    return results
