"""Measured executor microbenchmarks on this host: per-mode wall time of the
smoke VGG under the real OrigamiExecutor (functional path, CPU), plus the
limb-matmul kernel throughput in interpret mode. These are *measured*
numbers complementing the modeled paper tables."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.origami import OrigamiExecutor
from repro.models import model as M


def run(emit):
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jax.random.normal(
        jax.random.PRNGKey(1), (4, cfg.image_size, cfg.image_size, 3)) * 0.5}
    for mode in ("open", "split", "origami", "slalom"):
        ex = OrigamiExecutor(cfg, params, mode=mode)
        ex.infer(batch)                      # compile
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(ex.infer(batch).logits)
        dt = (time.perf_counter() - t0) / 3
        emit(f"exec/{mode}", dt * 1e6,
             f"blinded_MB={ex.telemetry.blinded_bytes/1e6:.2f}")

    from repro.kernels.limb_matmul.ops import field_matmul
    from repro.kernels.limb_matmul.ref import P
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, P, (256, 1024), dtype=np.int32))
    w = jnp.asarray(rng.integers(0, P, (1024, 256), dtype=np.int32))
    field_matmul(x, w).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        field_matmul(x, w).block_until_ready()
    dt = (time.perf_counter() - t0) / 3
    emit("kernel/limb_matmul_256x1024x256", dt * 1e6,
         f"GFLOPs_field={2*256*1024*256/dt/1e9:.2f}")


def main():
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))


if __name__ == "__main__":
    main()
