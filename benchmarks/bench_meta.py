"""Shared metadata header for every BENCH_*.json artifact.

All recorded suites write through :func:`write_bench`, so every artifact
has the same envelope::

    {"meta": {"schema_version": ..., "suite": ..., "backend": ...,
              "config": {...}},
     "results": {...}}

``schema_version`` bumps whenever the envelope shape changes (successive
PRs diff these files as a perf trajectory, so readers need a stable key to
dispatch on); ``suite`` names the generating suite; ``backend`` records
the jax backend the numbers were taken on; ``config`` echoes the suite's
knobs (each suite module's ``BENCH_CONFIG``) so a row is reproducible
without reading the suite source at the generating commit.
"""
from __future__ import annotations

import json
import pathlib
import sys
from typing import Any, Dict, Mapping, Optional

# v1 was the per-suite ad-hoc shapes (bare results dict at top level);
# v2 is the meta/results envelope above.
SCHEMA_VERSION = 2


def bench_doc(suite: str, results: Any,
              config: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
    """The envelope as a dict (split from write_bench for tests)."""
    import jax
    return {
        "meta": {
            "schema_version": SCHEMA_VERSION,
            "suite": suite,
            "backend": jax.default_backend(),
            "config": dict(config or {}),
        },
        "results": results,
    }


def write_bench(out_path, suite: str, results: Any,
                config: Optional[Mapping[str, Any]] = None) -> pathlib.Path:
    out_path = pathlib.Path(out_path)
    out_path.write_text(
        json.dumps(bench_doc(suite, results, config), indent=2) + "\n")
    print(f"wrote {out_path}", file=sys.stderr)
    return out_path
