"""Span-tracing overhead: identical engine workloads, tracing off vs on.

The observability acceptance bar (DESIGN.md §13): full span tracing —
request/queue/batch/session/step/shard/verify spans plus the metrics
registry — must cost <5% wall time on the tier-1 smoke shapes. Kernel
spans are OFF here, as in production: their block_until_ready fences are
a profiling mode, priced separately by the ``span_us`` micro row.

Methodology: two warmed engines over the same smoke vgg16 weights and a
mixed (enclave/blinded) PlacementPlan, one with no tracer and one with a
live Tracer. OFF/ON rounds interleave so machine drift lands on both
sides equally, and medians are compared — a single GC pause or noisy
neighbour can't fake (or mask) a regression.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict

import jax

ROUNDS = 4
REQS_PER_ROUND = 4
THRESHOLD_PCT = 5.0

BENCH_CONFIG = {
    "model": "vgg16 (smoke)",
    "plan": "mixed",
    "rounds": ROUNDS,
    "requests_per_round": REQS_PER_ROUND,
    "kernel_spans": False,
    "threshold_pct": THRESHOLD_PCT,
}


def _build_engine(tracer):
    from repro.configs import get_smoke
    from repro.core import plan as PL
    from repro.models import model as M
    from repro.runtime.engine import EngineConfig, ServingEngine

    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(EngineConfig(max_batch=REQS_PER_ROUND,
                                        max_wait_ms=10.0), tracer=tracer)
    engine.register_model("vgg16", cfg, params,
                          placement=PL.make_mixed(cfg))
    return engine, cfg


def _round(engine, cfg, rid0: int) -> float:
    from repro.launch.serve import _sealed_requests
    reqs, _ = _sealed_requests(cfg, REQS_PER_ROUND, rid0=rid0)
    t0 = time.perf_counter()
    futures = [engine.submit("vgg16", r) for r in reqs]
    resps = [f.result(timeout=300) for f in futures]
    dt = time.perf_counter() - t0
    assert all(r.ok for r in resps), [r.error for r in resps if not r.ok]
    return dt


def run_suite(emit: Callable[[str, float, str], None]) -> Dict[str, Dict]:
    from repro.core.tracing import Tracer

    tracer = Tracer(kernel_spans=False)
    eng_off, cfg = _build_engine(None)
    eng_on, _ = _build_engine(tracer)
    try:
        # warm compiles + caches out of the timings (one round each)
        _round(eng_off, cfg, rid0=90_000)
        _round(eng_on, cfg, rid0=91_000)

        off_s, on_s = [], []
        for i in range(ROUNDS):
            off_s.append(_round(eng_off, cfg, rid0=1_000 * i))
            on_s.append(_round(eng_on, cfg, rid0=50_000 + 1_000 * i))
    finally:
        eng_off.close()
        eng_on.close()

    med_off = statistics.median(off_s)
    med_on = statistics.median(on_s)
    overhead_pct = (med_on - med_off) / med_off * 100.0
    n_spans = len(tracer.spans())

    # micro row: raw span create+end cost, amortized (the per-event price
    # every instrumented site pays, independent of engine wall noise)
    t = Tracer()
    n_micro = 20_000
    t0 = time.perf_counter()
    for _ in range(n_micro):
        with t.span("micro", "step", k=1):
            pass
    span_us = (time.perf_counter() - t0) / n_micro * 1e6

    ok = overhead_pct < THRESHOLD_PCT
    results = {
        "engine_mixed_plan": {
            "off_s": [round(x, 4) for x in off_s],
            "on_s": [round(x, 4) for x in on_s],
            "median_off_s": round(med_off, 4),
            "median_on_s": round(med_on, 4),
            "overhead_pct": round(overhead_pct, 2),
            "threshold_pct": THRESHOLD_PCT,
            "pass": ok,
            "spans_recorded": n_spans,
        },
        "span_micro": {"span_us": round(span_us, 3), "iters": n_micro},
    }
    emit("trace/engine_overhead", med_on * 1e6,
         f"off={med_off:.3f}s on={med_on:.3f}s "
         f"overhead={overhead_pct:+.2f}% ({'OK' if ok else 'FAIL'}) "
         f"spans={n_spans}")
    emit("trace/span_create_end", span_us, f"iters={n_micro}")
    if not ok:
        print(f"trace_overhead: FAIL — {overhead_pct:+.2f}% >= "
              f"{THRESHOLD_PCT}% threshold")
    return results
