"""Three-term roofline per (arch × shape × mesh) from the dry-run artifacts.

    compute    = HLO_dot_FLOPs(device) / peak_FLOPs(chip)
    memory     = HLO_traffic(device)   / HBM_bw(chip)
    collective = collective_bytes(device) / ICI_link_bw

All three use the loop-aware HLO analysis (parallel/hlo_analysis.py): XLA's
cost_analysis counts while-loop bodies once, so raw cost_analysis numbers
are also recorded for reference but the roofline terms come from the
trip-multiplied parse. MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference)
with N = active params; the useful-compute ratio flags remat/redundancy
waste. Hardware: TPU v5e — 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclass
class RooflineRow:
    cell: str
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_global: float
    hlo_flops_global: float
    bound: str
    useful_ratio: float
    temp_gb: float
    arg_gb: float

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization implied by the roofline-limiting term."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops_global
                / (self.chips * PEAK_FLOPS * self.step_s))


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs import SHAPES, get_config
    from repro.models.model import active_params_analytic
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = active_params_analytic(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def load_rows(art_dir: str | Path = "artifacts/dryrun") -> List[RooflineRow]:
    rows = []
    for path in sorted(Path(art_dir).glob("*.json")):
        d = json.loads(path.read_text())
        if d.get("status") != "ok":
            continue
        chips = math.prod(d["mesh"])
        h = d.get("hlo_analysis", {})
        flops_dev = h.get("dot_flops_per_device", 0.0)
        hbm_dev = h.get("hbm_bytes_per_device", 0.0)
        coll_dev = sum(h.get("collective_bytes_per_device", {}).values())
        mf = model_flops(d["arch"], d["shape"])
        compute_s = flops_dev / PEAK_FLOPS
        memory_s = hbm_dev / HBM_BW
        coll_s = coll_dev / ICI_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        bound = max(terms, key=terms.get)
        hlo_global = flops_dev * chips
        ma = d.get("memory_analysis", {})
        rows.append(RooflineRow(
            cell=d["cell"], arch=d["arch"], shape=d["shape"],
            mesh="x".join(map(str, d["mesh"])), chips=chips,
            compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
            model_flops_global=mf, hlo_flops_global=hlo_global,
            bound=bound,
            useful_ratio=mf / hlo_global if hlo_global else 0.0,
            temp_gb=ma.get("temp_size_in_bytes", 0) / 1e9,
            arg_gb=ma.get("argument_size_in_bytes", 0) / 1e9))
    return rows


def format_table(rows: List[RooflineRow], single_pod_only=True) -> str:
    out = ["| cell | chips | compute s | memory s | collective s | bound | "
           "MODEL/HLO | MFU@bound | temp GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if single_pod_only and r.chips != 256:
            continue
        out.append(
            f"| {r.arch}·{r.shape} | {r.chips} | {r.compute_s:.2e} | "
            f"{r.memory_s:.2e} | {r.collective_s:.2e} | {r.bound} | "
            f"{r.useful_ratio:.2f} | {r.mfu*100:.1f}% | {r.temp_gb:.1f} |")
    return "\n".join(out)


def main():
    rows = load_rows()
    print(format_table(rows, single_pod_only=True))
    print()
    worst = sorted((r for r in rows if r.chips == 256),
                   key=lambda r: r.mfu)[:5]
    print("lowest-MFU cells:",
          [(r.cell, f"{r.mfu*100:.1f}%") for r in worst])
    coll = sorted((r for r in rows if r.chips == 256),
                  key=lambda r: -r.collective_s)[:5]
    print("most collective-bound:",
          [(r.cell, f"{r.collective_s:.2e}s") for r in coll])


if __name__ == "__main__":
    main()
