"""Serving-engine throughput vs. offered load (BENCH_serving.json).

Drives the async ServingEngine with a mixed vgg16/vgg19 smoke fleet at
several offered loads (Poisson-ish open-loop arrivals via fixed
inter-arrival sleeps, plus one closed-loop burst) and records achieved
throughput, latency quantiles and batching efficiency. Successive PRs
accumulate the JSON next to BENCH_blinding.json as a perf trajectory.

The engine runs compile-once: every (model, shape bucket) executable is
AOT-compiled at register time (``aot_warm``), so the load points measure
steady-state serving — ``engine.ttfb_warm_s`` and
``engine.aot.request_compile_seconds`` in the JSON prove no compile was
paid on the request path.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np


# echoed into BENCH_serving.json's meta header by benchmarks/run.py
BENCH_CONFIG = {
    "models": ["vgg16", "vgg19"],
    # 50 requests per load point: long enough that the last request's
    # in-flight tail does not dominate the achieved/offered ratio
    "n_per_model": 25,
    "max_batch": 4,
    "max_wait_ms": 10.0,
    "loads": ["burst", "50rps", "25rps", "10rps", "5rps"],
}


def _build_engine(max_batch: int, max_wait_ms: float):
    from repro.configs import get_smoke
    from repro.models import model as M
    from repro.runtime.engine import EngineConfig, ServingEngine

    engine = ServingEngine(EngineConfig(max_batch=max_batch,
                                        max_wait_ms=max_wait_ms,
                                        aot_warm=True))
    cfgs = {}
    for i, name in enumerate(("vgg16", "vgg19")):
        cfg = get_smoke(name)
        params = M.init_params(cfg, jax.random.PRNGKey(i))
        engine.register_model(name, cfg, params)
        cfgs[name] = cfg
    return engine, cfgs


def _requests(cfgs, n_per_model: int):
    # one sealing path for driver, benchmark and server: the launch
    # driver's helper builds (Request, key) streams via client_seal
    from repro.launch.serve import _sealed_requests

    stream = []
    for i, (name, cfg) in enumerate(cfgs.items()):
        reqs, _ = _sealed_requests(cfg, n_per_model, rid0=1000 * i)
        stream.append([(name, r) for r in reqs])
    # interleave the two models round-robin (mixed traffic)
    return [r for pair in zip(*stream) for r in pair]


def _drive(engine, mixed, offered_rps: float) -> Dict[str, float]:
    """Open-loop arrivals at ``offered_rps`` (inf = closed-loop burst)."""
    gap = 0.0 if not np.isfinite(offered_rps) else 1.0 / offered_rps
    t0 = time.monotonic()
    futures = []
    for i, (name, req) in enumerate(mixed):
        if gap:
            # absolute schedule (t0 + i*gap), not sleep-after-submit:
            # per-submit cost would otherwise accumulate as rate drift
            # and understate achieved/offered at the higher load points
            wait = t0 + i * gap - time.monotonic()
            if wait > 0:
                time.sleep(wait)
        futures.append(engine.submit(name, req))
    responses = [f.result(timeout=300) for f in futures]
    dt = time.monotonic() - t0
    ok = sum(r.ok for r in responses)
    lats = sorted(r.latency_s for r in responses if r.ok)
    q = lambda p: lats[min(len(lats) - 1, int(p * len(lats)))] if lats else 0
    return {
        # closed-loop burst has no finite offered rate: null, never a
        # sentinel number (bench_check treats null as "not applicable")
        "offered_rps": offered_rps if np.isfinite(offered_rps) else None,
        "achieved_rps": ok / dt,
        "ok": ok, "n": len(responses), "wall_s": round(dt, 3),
        "p50_ms": round(q(0.50) * 1e3, 1),
        "p95_ms": round(q(0.95) * 1e3, 1),
    }


def run_suite(emit: Callable[[str, float, str], None], *,
              n_per_model: int = 25, max_batch: int = 4,
              max_wait_ms: float = 10.0) -> Dict[str, Dict]:
    engine, cfgs = _build_engine(max_batch, max_wait_ms)
    results: Dict[str, Dict] = {}
    try:
        # register_model already AOT-warmed every shape-bucket executable;
        # one short wave still warms the per-session precompute ring
        warm = _requests(cfgs, max_batch)
        [f.result(timeout=300) for f in
         [engine.submit(m, r) for m, r in warm]]

        # saturation curve: burst + 4 finite offered rates
        loads = [("load_burst", float("inf")), ("load_50rps", 50.0),
                 ("load_25rps", 25.0), ("load_10rps", 10.0),
                 ("load_5rps", 5.0)]
        for name, rps in loads:
            mixed = _requests(cfgs, n_per_model)
            r = _drive(engine, mixed, rps)
            results[name] = r
            emit(f"serving/{name}", r["p50_ms"] * 1e3,
                 f"rps={r['achieved_rps']:.1f} p95_ms={r['p95_ms']}")
        stats = engine.stats.snapshot(engine)
        results["engine"] = {
            "batches": stats["batches"],
            "padded_slots": stats["padded_slots"],
            "batched_requests": stats["batched_requests"],
            "time_to_first_batch_s": stats["time_to_first_batch_s"],
            "ttfb_cold_s": stats["ttfb_cold_s"],
            "ttfb_warm_s": stats["ttfb_warm_s"],
            "sessions": stats["sessions"],
            "matmuls": stats["matmuls"],
            "aot": stats["aot"],
            "buckets": stats["buckets"],
        }
        emit("serving/batches", float(stats["batches"]),
             f"padded={stats['padded_slots']}")
        emit("serving/ttfb_warm_s", stats["ttfb_warm_s"],
             f"cold={stats['ttfb_cold_s']:.3f} "
             f"req_compile_s={stats['aot']['request_compile_seconds']:.2f}")
    finally:
        engine.close()
    return results
