"""Paper Fig 2 (enclave-vs-CPU slowdown), Fig 4 (partition-point sweep) and
Fig 11 (baseline-2 runtime breakdown) from the calibrated cost model."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.trust import EnclaveSim

PAPER_FIG2 = {"vgg16": 6.4, "vgg19": 6.5}       # enclave(JIT) / CPU
PAPER_FIG4_CPU = {"vgg16": {4: 2.5, 6: 3.0, 8: 3.3},
                  "vgg19": {4: 2.3, 6: 2.7, 8: 3.2}}


def run(emit):
    for arch in ("vgg16", "vgg19"):
        cfg = get_config(arch)
        sim_cpu = EnclaveSim(cfg, device="cpu")
        open_t = sim_cpu.runtime("open", 0).runtime_s
        enclave_t = sim_cpu.runtime("enclave", 0).runtime_s
        emit(f"fig2/{arch}/enclave_vs_cpu", enclave_t * 1e6,
             f"slowdown={enclave_t/open_t:.1f}x paper={PAPER_FIG2[arch]}x")
        # Fig 4: split points, offload to CPU — paper reports SLOWDOWN vs CPU
        for p in (4, 6, 8):
            t = sim_cpu.runtime("split", p).runtime_s
            want = PAPER_FIG4_CPU[arch][p]
            emit(f"fig4/{arch}/split{p}", t * 1e6,
                 f"slowdown_vs_cpu={t/open_t:.1f}x paper={want}x")
    # Fig 11: baseline-2 breakdown (dense layers ≈ 40%, half of it paging)
    cfg = get_config("vgg16")
    sim = EnclaveSim(cfg, device="gpu")
    c = sim.runtime("enclave", 0)
    dense_flops = sum(l.flops for l in sim.layers
                      if l.name.startswith(("fc", "logits")))
    dense_t = dense_flops / sim.p.sgx_flops + c.breakdown["paging"]
    frac = dense_t / c.runtime_s
    emit("fig11/dense_fraction", frac * 1e6,
         f"dense_layers={frac*100:.0f}%_of_runtime paper=~40%")
    emit("fig11/paging_fraction_of_dense",
         c.breakdown["paging"] / dense_t * 1e6,
         f"data_movement={c.breakdown['paging']/dense_t*100:.0f}% paper=~50%")


def main():
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))


if __name__ == "__main__":
    main()
