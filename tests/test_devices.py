"""DevicePool health state machine: EWMA placement, per-device
quarantine/probation, and the shard-geometry helpers (no executors here —
end-to-end plane behavior lives in test_offload_sharding.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blinding import blinding_stream
from repro.kernels.limb_matmul.ref import P
from repro.parallel.offload_sharding import additive_shares, row_spans
from repro.runtime.devices import DeviceHealthConfig, DevicePool


def test_row_spans_balanced_and_exhaustive():
    for t in (1, 2, 5, 17, 64):
        for n in (1, 2, 3, 4, 8):
            spans = row_spans(t, n)
            assert len(spans) == n
            assert spans[0][0] == 0 and spans[-1][1] == t
            sizes = [hi - lo for lo, hi in spans]
            assert sum(sizes) == t
            assert max(sizes) - min(sizes) <= 1       # balanced
            assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))


def test_additive_shares_reconstruct_and_hide():
    key = jax.random.PRNGKey(3)
    x = blinding_stream(jax.random.fold_in(key, 9), (6, 8))
    for n in (2, 3, 4):
        shares = additive_shares(x, key, op_index=1, step=0, n=n)
        assert len(shares) == n
        acc = shares[0]
        for s in shares[1:]:
            acc = jnp.mod(acc + s, P)
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(x))
        # no single share equals the blinded tensor (each is masked)
        for s in shares:
            assert not np.array_equal(np.asarray(s), np.asarray(x))
    # deterministic per (session, op, step): a shard retry re-sends the
    # SAME share, never fresh material
    a = additive_shares(x, key, op_index=1, step=0, n=2)
    b = additive_shares(x, key, op_index=1, step=0, n=2)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    c = additive_shares(x, key, op_index=2, step=0, n=2)
    assert not np.array_equal(np.asarray(a[0]), np.asarray(c[0]))


def test_healthy_prefers_fast_ewma_and_unmeasured_first():
    pool = DevicePool(3)
    pool.record_success(pool.slots[0], 0.5)
    pool.record_success(pool.slots[2], 0.1)
    order = [s.index for s in pool.healthy()]
    assert order == [1, 2, 0]      # never-measured first, then fastest
    pool.close()


def test_quarantine_is_per_device_after_consecutive_failures():
    pool = DevicePool(2, health=DeviceHealthConfig(quarantine_after=2))
    bad, good = pool.slots[1], pool.slots[0]
    pool.record_failure(bad)
    assert not bad.quarantined      # one strike
    pool.record_success(good, 0.1)
    pool.record_failure(bad)
    assert bad.quarantined and bad.quarantines == 1
    assert not good.quarantined
    assert [s.index for s in pool.healthy()] == [0]
    assert pool.n_healthy() == 1
    pool.close()


def test_success_resets_consecutive_failures():
    pool = DevicePool(1, health=DeviceHealthConfig(quarantine_after=2))
    s = pool.slots[0]
    pool.record_failure(s)
    pool.record_success(s, 0.1)
    pool.record_failure(s)
    assert not s.quarantined        # never two in a row
    pool.close()


def test_probation_cycle_restore_and_rebench():
    pool = DevicePool(2, health=DeviceHealthConfig(quarantine_after=1,
                                                   probation_after=2))
    bad = pool.slots[1]
    pool.record_failure(bad)
    assert bad.quarantined and not bad.probation
    assert pool.probe_candidate() is None
    pool.begin_dispatch()
    assert pool.probe_candidate() is None     # cooldown not yet aged out
    pool.begin_dispatch()
    assert pool.probe_candidate() is bad      # probe-eligible
    # dirty probe: re-benched, cooldown restarts
    pool.record_probe(bad)
    pool.record_failure(bad)
    assert bad.quarantined and not bad.probation and bad.probes == 1
    pool.begin_dispatch()
    pool.begin_dispatch()
    assert pool.probe_candidate() is bad
    # clean probe: restored to the healthy set
    pool.record_probe(bad)
    pool.record_success(bad, 0.2)
    assert not bad.quarantined and bad.restores == 1
    assert pool.n_healthy() == 2
    pool.close()


def test_record_latency_updates_ewma_only():
    pool = DevicePool(1)
    s = pool.slots[0]
    pool.record_failure(s)
    before = s.consec_failures
    pool.record_latency(s, 0.25)
    assert s.ewma_latency_s == 0.25
    assert s.consec_failures == before        # health untouched
    pool.close()


def test_pool_snapshot_shape():
    pool = DevicePool(2)
    snap = pool.snapshot()
    assert snap["size"] == 2 and snap["healthy"] == 2
    assert len(snap["slots"]) == 2
    assert snap["slots"][0]["name"] == "sim:0"
    pool.close()
