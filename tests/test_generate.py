"""Autoregressive generation incl. the Origami two-tier private decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M
from repro.runtime.generate import (generate, generate_origami,
                                    tier1_cache_bytes)


@pytest.mark.parametrize("arch", ["smollm_135m", "zamba2_1_2b"])
def test_generate_shapes(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                cfg.vocab_size)
    out = generate(params, prompt, cfg, max_new_tokens=4)
    assert out.tokens.shape == (2, 8)
    assert int(out.tokens.max()) < cfg.vocab_size


def test_greedy_generation_deterministic():
    cfg = get_smoke("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                                cfg.vocab_size)
    a = generate(params, prompt, cfg, max_new_tokens=5).tokens
    b = generate(params, prompt, cfg, max_new_tokens=5).tokens
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_origami_tiered_decode_step_close_to_open():
    """One tiered decode step's logits match the open step to quantization
    scale (greedy *tokens* can legitimately diverge on an untrained net —
    autoregressive chaos amplifies sub-1% perturbations)."""
    import functools
    from repro.core import slalom as SL
    from repro.core.blinding import BlindingSpec
    from repro.models import layers as L

    cfg = get_smoke("yi_9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    caches = M.init_caches(cfg, B, S)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                             cfg.vocab_size)
    pos = jnp.int32(0)
    open_logits, _ = M.decode_step(params, tok, caches, pos, cfg)

    ctx = SL.SlalomContext(jax.random.PRNGKey(7), BlindingSpec())
    p = cfg.origami.tier1_layers
    x = M.embed_tokens_at(params, tok, pos, cfg)
    with L.dense_impl(functools.partial(SL.blinded_dense, ctx)):
        x, c2 = M.decode_range(params, x, caches, pos, cfg, 0, p)
    x, c2 = M.decode_range(params, x, c2, pos, cfg, p, cfg.num_layers)
    priv_logits = M.head(params, x, cfg)

    a = np.asarray(open_logits, np.float32)
    b = np.asarray(priv_logits, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 0.15, rel
    assert ctx.telemetry.calls > 0 and ctx.telemetry.blinded_bytes > 0


def test_origami_generation_runs_protocol():
    cfg = get_smoke("yi_9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                                cfg.vocab_size)
    priv = generate_origami(params, prompt, cfg, max_new_tokens=4)
    assert priv.tokens.shape == (1, 8)
    # blinded offloads happened for every step's tier-1 linear ops
    assert priv.telemetry.calls > 0
    assert priv.telemetry.blinded_bytes > 0


def test_tier1_cache_accounting():
    cfg = get_smoke("yi_9b")
    b = tier1_cache_bytes(cfg, batch=2, max_seq=16)
    hd = cfg.resolved_head_dim
    want = cfg.origami.tier1_layers * 2 * 16 * cfg.num_kv_heads * hd * 4
    assert b == want
    mla = get_smoke("minicpm3_4b")
    assert tier1_cache_bytes(mla, 2, 16) \
        == mla.origami.tier1_layers * 2 * 16 * (
            mla.mla.kv_lora_rank + mla.mla.qk_rope_head_dim) * 2
