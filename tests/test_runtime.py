"""Elastic planning, straggler watchdog, gradient compression, pipeline."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.parallel import compression as GC
from repro.runtime.elastic import plan_degraded_mesh, rescale_batch
from repro.runtime.straggler import StepWatchdog, WatchdogConfig


# -- elastic -----------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(1, 600))
def test_degraded_mesh_fits_and_is_maximal_pow2_model(n):
    c = plan_degraded_mesh(n)
    assert c.devices_needed <= n
    data, model = c.shape
    assert data * model == c.devices_needed
    assert model & (model - 1) == 0            # power of two
    assert model <= 16


def test_degraded_mesh_full_pod():
    c = plan_degraded_mesh(256)
    assert c.shape == (16, 16)


def test_rescale_batch_keeps_per_device():
    assert rescale_batch(256, old_data=16, new_data=12) == 192
    assert rescale_batch(8, old_data=8, new_data=4) == 4


# -- straggler ---------------------------------------------------------------

def test_watchdog_flags_slow_steps():
    wd = StepWatchdog(WatchdogConfig(deadline_factor=2.0, warmup_steps=5,
                                     tolerance=3))
    t = 0.0
    for i in range(20):
        wd.start_step(now=t)
        t += 0.1
        assert wd.end_step(now=t) is False
    for i in range(3):
        wd.start_step(now=t)
        t += 0.5                                # 5x p50
        assert wd.end_step(now=t) is True
    assert wd.should_escalate


def test_watchdog_median_is_proper_on_even_windows():
    """The seed's ``sorted(h)[len//2]`` is the UPPER median: on the window
    [0.1, 0.1, 0.3, 0.3] it returns 0.3, inflating the deadline baseline
    by 50% — a 0.45 s step would pass a 2× deadline it should breach. The
    offload plane's hedging deadline keys off this estimate, so the bias
    was load-bearing."""
    wd = StepWatchdog(WatchdogConfig(deadline_factor=2.0, warmup_steps=4,
                                     tolerance=3))
    t = 0.0
    for dt in (0.1, 0.3, 0.1, 0.3):
        wd.start_step(now=t)
        t += dt
        wd.end_step(now=t)
    assert wd.p50 == pytest.approx(0.2)
    wd.start_step(now=t)
    assert wd.end_step(now=t + 0.45) is True      # 0.45 > 2 x 0.2
    # odd-length window now ([0.1, 0.1, 0.3, 0.3, 0.45]): the true middle
    assert wd.p50 == pytest.approx(0.3)


def test_watchdog_resets_on_recovery():
    wd = StepWatchdog(WatchdogConfig(deadline_factor=2.0, warmup_steps=3,
                                     tolerance=3))
    t = 0.0
    for dt in [0.1] * 10 + [0.5, 0.5, 0.1, 0.5, 0.5]:
        wd.start_step(now=t)
        t += dt
        wd.end_step(now=t)
    assert not wd.should_escalate


# -- gradient compression ------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_quantize_int8_bounds(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.01, 100),
                    jnp.float32)
    q, s = GC.quantize_int8(x)
    back = GC.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) / 2 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of EF-compressed grads tracks the sum of raw grads."""
    rng = np.random.default_rng(0)
    g_total = np.zeros(32, np.float32)
    c_total = np.zeros(32, np.float32)
    res = {"g": jnp.zeros(32, jnp.float32)}
    for step in range(50):
        g = rng.normal(size=32).astype(np.float32) * 0.1
        comp, res2 = GC.apply_error_feedback({"g": jnp.asarray(g)}, res)
        res = res2
        g_total += g
        c_total += np.asarray(comp["g"])
    resid = np.abs(np.asarray(res["g"]))
    np.testing.assert_allclose(c_total + np.asarray(res["g"]), g_total,
                               rtol=1e-4, atol=1e-4)
    assert resid.max() < 0.01               # residual stays bounded


# -- data pipeline --------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    np.testing.assert_array_equal(p1.batch(5)["tokens"],
                                  p2.batch(5)["tokens"])
    assert not np.array_equal(p1.batch(5)["tokens"], p1.batch(6)["tokens"])


def test_pipeline_shards_disjoint_and_cover():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    full = TokenPipeline(cfg, shard=0, num_shards=1).batch(3)["tokens"]
    parts = [TokenPipeline(cfg, shard=s, num_shards=4).batch(3)["tokens"]
             for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_pipeline_tokens_in_vocab():
    cfg = DataConfig(vocab_size=50, seq_len=64, global_batch=2)
    t = TokenPipeline(cfg).batch(0)["tokens"]
    assert t.min() >= 0 and t.max() < 50
