"""MoE dispatch: sorted == gshard; capacity behaviour; aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import layers as L
from repro.models import moe


def _setup(dispatch, capacity_factor=8.0, seed=0):
    cfg = get_smoke("qwen3_moe_235b")
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, dispatch=dispatch, capacity_factor=capacity_factor))
    params = L.init_params(jax.random.PRNGKey(seed), moe.moe_defs(cfg),
                           jnp.float32)
    return cfg, params


def test_sorted_equals_gshard_when_no_drops(rng):
    """With capacity >> tokens, both dispatchers are mathematically equal."""
    cfg_g, params = _setup("gshard", capacity_factor=16.0)
    cfg_s, _ = _setup("sorted", capacity_factor=16.0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg_g.d_model)), jnp.float32)
    yg, auxg = moe.moe_forward(params, x, cfg_g)
    ys, auxs = moe.moe_forward(params, x, cfg_s)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(ys),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(auxg), float(auxs), rtol=1e-5)


def test_capacity_drop_reduces_output_norm(rng):
    cfg_full, params = _setup("sorted", capacity_factor=16.0)
    cfg_tight, _ = _setup("sorted", capacity_factor=0.25)
    x = jnp.asarray(rng.normal(size=(1, 64, cfg_full.d_model)), jnp.float32)
    y_full, _ = moe.moe_forward(params, x, cfg_full)
    y_tight, _ = moe.moe_forward(params, x, cfg_tight)
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_router_weights_normalized(rng):
    cfg, params = _setup("gshard")
    x = jnp.asarray(rng.normal(size=(32, cfg.d_model)), jnp.float32)
    w, e, aux = moe._route(params, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-3      # >= 1 at uniformity, by Cauchy-Schwarz


def test_dense_residual_arctic(rng):
    cfg = get_smoke("arctic_480b")
    params = L.init_params(jax.random.PRNGKey(0), moe.moe_defs(cfg),
                           jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y, _ = moe.moe_forward(params, x, cfg)
    # knocking out the dense residual changes the output
    p2 = dict(params)
    p2["dense_residual"] = jax.tree.map(jnp.zeros_like,
                                        params["dense_residual"])
    y2, _ = moe.moe_forward(p2, x, cfg)
    assert not np.allclose(np.asarray(y), np.asarray(y2))


def test_moe_grads_flow_through_router(rng):
    cfg, params = _setup("gshard")
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)

    def loss(p):
        y, aux = moe.moe_forward(p, x, cfg)
        return jnp.sum(y * y) + 0.01 * aux

    g = jax.grad(loss)(params)
    rnorm = float(jnp.linalg.norm(g["router"]["w"]))
    assert np.isfinite(rnorm) and rnorm > 0
