"""PlacementPlan IR: legacy-mode compile equivalence across every model
family, custom-placement round-trips, fail-closed plan leakage, per-step
integrity (verified-open offload), plan pricing, telemetry isolation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, get_smoke
from repro.core import plan as PL
from repro.core.integrity import IntegrityPolicy
from repro.core.origami import MODES, OrigamiExecutor
from repro.core.planner import (PartitionPlanner, leakage_profile,
                                plan_leakage)
from repro.core.trust import EnclaveSim
from repro.models import model as M

FAMILIES = {
    "cnn": "vgg16",
    "lm": "smollm_135m",
    "audio": "whisper_small",
    "vlm": "llama3_2_vision_11b",
}


def _fixture(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    if cfg.family == "cnn":
        batch = {"images": jax.random.normal(
            k, (2, cfg.image_size, cfg.image_size, 3)) * 0.5}
    else:
        batch = {"tokens": jax.random.randint(k, (2, 16), 0,
                                              cfg.vocab_size)}
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                k, (2, cfg.encoder_seq_len, cfg.d_model),
                jnp.float32) * 0.1
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                k, (2, cfg.vision_seq_len, cfg.d_model),
                jnp.float32) * 0.1
    return cfg, params, batch


@pytest.fixture(scope="module", params=sorted(FAMILIES))
def family(request):
    cfg, params, batch = _fixture(FAMILIES[request.param])
    ref = np.asarray(OrigamiExecutor(cfg, params, mode="open")
                     .infer(batch).logits, np.float32)
    return request.param, cfg, params, batch, ref


# ---------------------------------------------------------------------------
# legacy-mode compilation: table + equivalence (the seed-oracle contract)
# ---------------------------------------------------------------------------

def test_compile_table_shapes():
    cfg = get_smoke("vgg16")
    n = len(cfg.cnn_layers)
    p = cfg.origami.tier1_layers
    want = {
        "open": ("o" * n, 0),
        "enclave": ("e" * n, n),
        "split": ("e" * p + "o" * (n - p), p),
        "slalom": ("b" * n, n),
        "origami": ("b" * p + "o" * (n - p), p),
    }
    for mode, (placements, boundary) in want.items():
        plan = PL.compile_mode(cfg, mode)
        assert plan.placement_string == placements, mode
        assert plan.boundary == boundary, mode
        assert plan.mode_label == mode
        assert PL.classify_legacy(plan) is not None


@pytest.mark.parametrize("mode", MODES)
def test_mode_kwargs_and_explicit_plan_bit_identical(family, mode):
    """Every legacy mode string × family: the compat ``mode=`` constructor
    and an explicit ``plan=compile_mode(...)`` must produce bit-identical
    logits, boundary, telemetry counters and integrity report — both are
    the same plan interpreted by the same executor — and both must keep
    the seed semantics vs the open reference (exact for non-blinded
    placements, quantization-level error for blinded ones)."""
    _, cfg, params, batch, ref = family
    key = jax.random.PRNGKey(3)
    a = OrigamiExecutor(cfg, params, mode=mode)
    b = OrigamiExecutor(cfg, params, plan=PL.compile_mode(cfg, mode))
    ra = a.infer(batch, session_key=key)
    rb = b.infer(batch, session_key=key)
    np.testing.assert_array_equal(np.asarray(ra.logits),
                                  np.asarray(rb.logits))
    np.testing.assert_array_equal(np.asarray(ra.boundary),
                                  np.asarray(rb.boundary))
    for f in ("calls", "blinded_bytes", "returned_bytes", "offloaded_flops",
              "enclave_flops", "device_matmuls", "enclave_matmuls",
              "verify_ops", "trusted_matmuls"):
        assert getattr(a.telemetry, f) == getattr(b.telemetry, f), (mode, f)
    np.testing.assert_array_equal(np.asarray(ra.integrity.checked),
                                  np.asarray(rb.integrity.checked))
    assert a.plan.digest == b.plan.digest
    got = np.asarray(ra.logits, np.float32)
    if mode in ("origami", "slalom"):
        rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
        assert rel < 0.25, (mode, rel)
    else:
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_no_mode_branching_left_in_executor():
    """The executor interprets plans — its traced path must not consult
    mode strings (the acceptance criterion's grep)."""
    import inspect
    from repro.core import origami
    for fn in (origami.OrigamiExecutor._traced, origami.OrigamiExecutor._run):
        src = inspect.getsource(fn)
        for m in MODES:
            assert f'"{m}"' not in src, (fn.__name__, m)
    assert not hasattr(origami.OrigamiExecutor, "_tier_bounds")
    assert not hasattr(origami.OrigamiExecutor, "_traced_cnn")
    assert not hasattr(origami.OrigamiExecutor, "_traced_lm")


# ---------------------------------------------------------------------------
# custom placements: compile -> execute round-trip (property)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 3 ** 6 - 1))
def test_custom_placement_roundtrip(code):
    cfg, params, batch = _ROUNDTRIP
    n = len(cfg.cnn_layers)
    # decode a base-3 placement word over the first 6 layers, open tail
    placements = []
    for _ in range(6):
        placements.append(PL.PLACEMENTS[code % 3])
        code //= 3
    placements += ["open"] * (n - 6)
    plan = PL.make_plan(cfg, placements)
    # string round-trip preserves the plan identity
    assert PL.from_string(cfg, plan.placement_string,
                          boundary=plan.boundary).digest == plan.digest
    # segments tile [0, n) in order and split at the boundary
    segs = plan.segments
    assert segs[0].lo == 0 and segs[-1].hi == n
    assert all(a.hi == b.lo for a, b in zip(segs, segs[1:]))
    assert all(seg.hi <= plan.boundary or seg.lo >= plan.boundary
               for seg in segs)
    r = OrigamiExecutor(cfg, params, plan=plan).infer(batch)
    ref = np.asarray(OrigamiExecutor(cfg, params, mode="open")
                     .infer(batch).logits, np.float32)
    got = np.asarray(r.logits, np.float32)
    if plan.has_blinded:
        rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
        assert rel < 0.05, rel
    else:   # enclave/open placements never quantize: exact
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


_ROUNDTRIP = _fixture("vgg16")


def test_boundary_capture_matches_prefix():
    cfg, params, batch = _ROUNDTRIP
    from repro.models import vgg as V
    n = len(cfg.cnn_layers)
    plan = PL.make_plan(cfg, ["enclave"] * 2 + ["open"] * (n - 2),
                        boundary=2)
    r = OrigamiExecutor(cfg, params, plan=plan).infer(batch)
    want = V.apply_layer_range(params, batch["images"], cfg, 0, 2)
    np.testing.assert_allclose(np.asarray(r.boundary, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_mixed_tier1_precompute_bit_exact():
    """Mixed enclave/blinded tier-1 (inexpressible pre-IR): cached factors
    must reproduce the on-the-fly trace bit-for-bit, with cache slots only
    for the blinded ops."""
    cfg, params, batch = _ROUNDTRIP
    n = len(cfg.cnn_layers)
    placements = ["blinded", "enclave"] + ["open"] * (n - 2)
    plan = PL.make_plan(cfg, placements, boundary=2, label="mixed")
    key = jax.random.PRNGKey(11)
    live = OrigamiExecutor(cfg, params, plan=plan).infer(
        batch, session_key=key)
    pre_ex = OrigamiExecutor(cfg, params, plan=plan, precompute=True)
    pre = pre_ex.infer(batch, session_key=key)
    np.testing.assert_array_equal(np.asarray(live.logits),
                                  np.asarray(pre.logits))
    assert pre_ex.cache is not None and pre_ex.cache.num_layers == 1


# ---------------------------------------------------------------------------
# verified-open offload (per-step integrity)
# ---------------------------------------------------------------------------

def _vopen_plan(cfg, policy):
    n = len(cfg.cnn_layers)
    p = cfg.origami.tier1_layers
    from repro.core.trust import vgg_layer_profiles
    linear = [l.linear for l in vgg_layer_profiles(cfg)]
    integ = {i: policy for i in range(p, n) if linear[i]}
    return PL.make_plan(cfg, ["blinded"] * p + ["open"] * (n - p),
                        integrity=integ, boundary=p, label="vopen"), len(integ)


def test_verified_open_checks_and_trusted_recovery():
    cfg, params, batch = _ROUNDTRIP
    plan, n_v = _vopen_plan(cfg, IntegrityPolicy.full(1))
    assert n_v > 0
    ex = OrigamiExecutor(cfg, params, plan=plan,
                         integrity=IntegrityPolicy.full(1))
    key = jax.random.PRNGKey(5)
    r = ex.infer(batch, session_key=key)
    # blinded tier-1 ops + verified-open tier-2 ops all check
    n_blinded_ops = sum(1 for s in plan.steps if s.placement == "blinded"
                        and s.precompute_slot is not None)
    assert r.integrity.n_checked == n_blinded_ops + n_v
    assert r.integrity.ok
    # recovery: the enclave recompute of the SAME plan is bit-identical
    rt = ex.infer(batch, session_key=key, trusted=True)
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  np.asarray(rt.logits))


def test_verified_open_detects_dishonest_device():
    from repro.runtime.faults import DishonestDevice, FaultSpec
    cfg, params, batch = _ROUNDTRIP
    n = len(cfg.cnn_layers)
    # ONLY verified-open steps — no blinding anywhere, integrity still bites
    from repro.core.trust import vgg_layer_profiles
    linear = [l.linear for l in vgg_layer_profiles(cfg)]
    integ = {i: IntegrityPolicy.full(1) for i in range(n) if linear[i]}
    plan = PL.make_plan(cfg, ["open"] * n, integrity=integ, boundary=0)
    ex = OrigamiExecutor(cfg, params, plan=plan,
                         fault=DishonestDevice(FaultSpec("bit_flip")))
    r = ex.infer(batch, session_key=jax.random.PRNGKey(9))
    assert r.integrity.n_checked == len(integ)
    assert r.integrity.n_corrupted > 0
    assert r.integrity.n_failed == r.integrity.n_corrupted


def test_verified_open_rejected_for_scanned_families():
    """Per-op verification cannot bind under lax.scan, so a 'v' placement
    there would run UNBLINDED and UNCHECKED while the plan digest claims
    verified offload — must fail at compile time, not silently at runtime."""
    cfg = get_smoke("smollm_135m")
    with pytest.raises(ValueError):
        PL.make_vopen(cfg)
    n = cfg.num_layers
    with pytest.raises(ValueError):
        PL.from_string(cfg, "b" + "v" * (n - 1), boundary=1)
    # blinded placements (executor-wide policy path) stay allowed
    assert PL.from_string(cfg, "b" * n).num_blinded == n


def test_engine_snapshot_reports_per_step_policy():
    from repro.runtime.engine import EngineConfig, ServingEngine
    cfg, params, _ = _ROUNDTRIP
    plan, _ = _vopen_plan(cfg, IntegrityPolicy.full(1))
    engine = ServingEngine(EngineConfig(max_batch=2))
    engine.register_model("v", cfg, params, placement=plan, integrity=None)
    snap = engine.stats.snapshot(engine)
    engine.close()
    assert snap["models"]["v"]["policy"] == "per-step"


def test_verified_open_cached_bit_exact():
    cfg, params, batch = _ROUNDTRIP
    plan, _ = _vopen_plan(cfg, IntegrityPolicy.full(2))
    key = jax.random.PRNGKey(13)
    live = OrigamiExecutor(cfg, params, plan=plan).infer(
        batch, session_key=key)
    pre_ex = OrigamiExecutor(cfg, params, plan=plan, precompute=True)
    pre_ex.build_cache(batch)
    # verified-open slots store no pad arrays (the zeros are synthesized
    # in-trace) but still carry their fold vectors
    factors = pre_ex.cache.session_factors(key)
    assert any(lyr.unblinded for lyr in pre_ex.cache.layers)
    for lyr, f in zip(pre_ex.cache.layers, factors):
        assert (f["r"] is None) == lyr.unblinded
        # folds ride only where a policy is enabled (here: the v steps —
        # the blinded tier inherits the executor's off() policy)
        assert ("s" in f and "ws" in f) == lyr.unblinded
    pre = pre_ex.infer(batch, session_key=key)
    np.testing.assert_array_equal(np.asarray(live.logits),
                                  np.asarray(pre.logits))
    np.testing.assert_array_equal(np.asarray(live.integrity.checked),
                                  np.asarray(pre.integrity.checked))
    np.testing.assert_array_equal(np.asarray(live.integrity.failed),
                                  np.asarray(pre.integrity.failed))


# ---------------------------------------------------------------------------
# telemetry isolation (satellite: shared-telemetry pollution fix)
# ---------------------------------------------------------------------------

def test_trusted_trace_does_not_pollute_offload_telemetry():
    cfg, params, batch = _ROUNDTRIP
    ex = OrigamiExecutor(cfg, params, mode="origami")
    ex.infer(batch, session_key=jax.random.PRNGKey(1))
    blinded = ex.telemetry_blinded
    calls, dev = blinded.calls, blinded.device_matmuls
    assert calls > 0 and blinded.trusted_matmuls == 0
    ex.infer(batch, session_key=jax.random.PRNGKey(2), trusted=True)
    # offload counters unchanged by the recovery trace
    assert ex.telemetry_blinded.calls == calls
    assert ex.telemetry_blinded.device_matmuls == dev
    assert ex.telemetry_blinded.trusted_matmuls == 0
    assert ex.telemetry_trusted.trusted_matmuls > 0
    assert ex.telemetry_trusted.device_matmuls == 0
    # the public snapshot tracks the last infer's trace kind
    assert ex.telemetry is ex.telemetry_trusted
    ex.infer(batch, session_key=jax.random.PRNGKey(3))
    assert ex.telemetry is ex.telemetry_blinded


# ---------------------------------------------------------------------------
# fail-closed plan leakage
# ---------------------------------------------------------------------------

def test_plan_leakage_fail_closed():
    cfg = get_smoke("vgg16")
    n = len(cfg.cnn_layers)
    profile = {1: 0.9, 2: 0.5, 3: 0.2}          # deeper boundaries unmeasured
    # an open (or verified-open) FIRST layer hands the device the raw
    # input: total leakage by definition, whatever the profile says
    plan = PL.make_plan(cfg, ["open", "blinded", "blinded"]
                        + ["open"] * (n - 3), boundary=3)
    assert 0 in plan.exposed_boundaries()
    assert plan_leakage(profile, plan) == 1.0
    assert plan_leakage(profile, PL.compile_mode(cfg, "open")) == 1.0
    # non-contiguous interior hole: open at layer 1 exposes boundary 1
    hole = PL.make_plan(cfg, ["blinded", "open", "blinded"]
                        + ["open"] * (n - 3), boundary=3)
    assert 0 not in hole.exposed_boundaries()
    assert plan_leakage(profile, hole) >= 0.9
    # prefix plan at p=3: exposes 3 and deeper; unmeasured deep boundaries
    # inherit the worst upstream measurement (0.9), not 0
    pref = PL.compile_mode(cfg, "origami", 3)
    assert plan_leakage(profile, pref) >= 0.9
    # fully protected plans expose nothing
    assert plan_leakage(profile, PL.compile_mode(cfg, "slalom")) == 0.0
    assert plan_leakage(profile, PL.compile_mode(cfg, "enclave")) == 0.0
    # measured boundaries score their measurement when the only exposure
    # is measured: single open step at the last measured layer
    solo = PL.make_plan(cfg, ["blinded"] * (n - 1) + ["open"],
                        boundary=n - 1)
    full_profile = {p: 0.1 for p in range(1, n)}
    assert plan_leakage(full_profile, solo) == pytest.approx(0.1)


def test_planner_placement_sweep_feasible_and_cheapest():
    cfg, params, _ = _ROUNDTRIP
    prof = leakage_profile(params, cfg, n_images=2)
    floor = max(prof.values()) + 0.01            # everything feasible
    planner = PartitionPlanner(privacy_floor=floor, n_images=2)
    choice = planner.placement_plan(cfg, leakage=prof)
    assert plan_leakage(prof, choice.plan) <= floor
    sim = EnclaveSim(cfg, device=planner.device)
    # the chosen plan is no slower than the pure origami prefix at the
    # same boundary (the prefix is always among the candidates)
    base = sim.plan_runtime(
        PL.compile_mode(cfg, "origami", choice.plan.boundary)).runtime_s
    assert choice.runtime_s <= base + 1e-12
    # impossible floor: fail closed to all-blinded
    impossible = PartitionPlanner(privacy_floor=-1.0, n_images=2)
    fallback = impossible.placement_plan(cfg, leakage=prof)
    assert fallback.plan.num_blinded == len(cfg.cnn_layers)


# ---------------------------------------------------------------------------
# plan pricing (trust.py)
# ---------------------------------------------------------------------------

def test_plan_pricing_matches_legacy_exactly():
    cfg = get_config("vgg16")
    sim = EnclaveSim(cfg, device="gpu")
    for mode in MODES:
        plan = PL.compile_mode(cfg, mode, 6)
        assert (sim.plan_runtime(plan).runtime_s
                == sim.runtime(mode, 6).runtime_s), mode


def test_mixed_plan_pricing_between_endpoints():
    cfg = get_config("vgg16")
    sim = EnclaveSim(cfg, device="gpu")
    n = len(cfg.cnn_layers)
    mixed = PL.make_plan(cfg, ["blinded"] * 3 + ["enclave"] * 3
                         + ["open"] * (n - 6), boundary=6, label="mixed")
    rt = sim.plan_runtime(mixed).runtime_s
    assert sim.runtime("origami", 6).runtime_s < rt
    assert rt < sim.runtime("enclave", 6).runtime_s
    assert sim.plan_runtime(mixed).enclave_resident_mb > 0


# ---------------------------------------------------------------------------
# plan digests key the serving caches
# ---------------------------------------------------------------------------

def test_digest_distinguishes_plans_and_policies():
    cfg = get_smoke("vgg16")
    a = PL.compile_mode(cfg, "origami")
    b = PL.compile_mode(cfg, "origami", 2)
    c = PL.compile_mode(cfg, "slalom")
    v, _ = _vopen_plan(cfg, IntegrityPolicy.full(1))
    v2, _ = _vopen_plan(cfg, IntegrityPolicy.full(2))
    digests = {p.digest for p in (a, b, c, v, v2)}
    assert len(digests) == 5
    assert PL.compile_mode(cfg, "origami").digest == a.digest  # stable


def test_executor_caches_keyed_by_plan_digest():
    cfg, params, batch = _ROUNDTRIP
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    ex.infer(batch)
    (key,) = ex._caches
    assert key[0] == ex.plan.digest


# ---------------------------------------------------------------------------
# end-to-end: inexpressible plan through the ServingEngine, with recovery
# ---------------------------------------------------------------------------

def test_engine_serves_mixed_plan_with_recovery_bit_exact():
    """Acceptance: a mixed enclave/blinded tier-1 + verified-open tier-2
    plan (no legacy mode can express it) runs through the ServingEngine
    under a dishonest device; every corruption is detected and recovered,
    and the responses are bit-identical to the same plan's synchronous
    serve_batch on an honest executor."""
    from repro.runtime.engine import EngineConfig, ServingEngine
    from repro.runtime.faults import DishonestDevice, FaultSpec
    from repro.runtime.serving import PrivateInferenceServer, Request

    cfg, params, _ = _ROUNDTRIP
    n = len(cfg.cnn_layers)
    pol = IntegrityPolicy.full(1)
    placements = (["blinded", "enclave", "blinded"]
                  + ["open"] * (n - 3))
    integ = {i: pol for i in range(3, n)
             if cfg.cnn_layers[i].startswith(("conv", "fc", "logits"))}
    plan = PL.make_plan(cfg, placements, integrity=integ, boundary=3,
                        label="mixed-vopen")
    assert PL.classify_legacy(plan) is None      # truly inexpressible

    rng = np.random.default_rng(0)
    reqs, keys = [], []
    for rid in range(4):
        img = rng.normal(size=(cfg.image_size, cfg.image_size, 3)) \
            .astype(np.float32) * 0.5
        key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
        box = PrivateInferenceServer.client_seal(key, img, rid)
        reqs.append(Request(rid=rid, box=box, shape=img.shape,
                            session_key=key))
        keys.append(key)

    honest = PrivateInferenceServer(cfg, params, max_batch=4, plan=plan,
                                    integrity=pol)
    want = honest.serve_batch(reqs)
    assert honest.integrity_totals.failures == 0

    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=10.0))
    engine.register_model("m", cfg, params, placement=plan, integrity=pol,
                          fault=DishonestDevice(FaultSpec("bit_flip")))
    futures = [engine.submit("m", r) for r in reqs]
    engine.flush()
    got = [f.result(timeout=300.0) for f in futures]
    stats = engine.stats.snapshot(engine)
    engine.close()
    assert stats["integrity"]["verify_failures"] > 0
    assert stats["integrity"]["recomputes"] > 0
    assert stats["models"]["m"]["plan"] == plan.digest[:12]
    for w, g in zip(want, got):
        assert g.ok and g.flagged
        lw = PrivateInferenceServer.client_open(w_key := keys[w.rid], w.box,
                                                (cfg.num_classes,))
        lg = PrivateInferenceServer.client_open(w_key, g.box,
                                                (cfg.num_classes,))
        np.testing.assert_array_equal(lw, lg)
