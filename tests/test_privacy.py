"""Privacy evaluation: SSIM properties, c-GAN adversary, Algorithm 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.models import model as M
from repro.privacy import reconstruct as R
from repro.privacy.data import dataset, make_batch, make_image
from repro.privacy.ssim import ssim


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_ssim_identity_is_one(seed):
    x = jnp.asarray(np.random.default_rng(seed).random((2, 16, 16, 3)),
                    jnp.float32)
    assert abs(float(ssim(x, x)) - 1.0) < 1e-5


def test_ssim_symmetric_and_bounded(rng):
    x = jnp.asarray(rng.random((2, 16, 16, 3)), jnp.float32)
    y = jnp.asarray(rng.random((2, 16, 16, 3)), jnp.float32)
    a, b = float(ssim(x, y)), float(ssim(y, x))
    assert abs(a - b) < 1e-6
    assert -1.0 <= a <= 1.0


def test_ssim_orders_by_noise(rng):
    x = jnp.asarray(rng.random((2, 32, 32, 3)), jnp.float32)
    small = x + 0.05 * jnp.asarray(rng.normal(size=x.shape), jnp.float32)
    big = x + 0.5 * jnp.asarray(rng.normal(size=x.shape), jnp.float32)
    assert float(ssim(x, small)) > float(ssim(x, big))


def test_dataset_deterministic():
    np.testing.assert_array_equal(make_image(42), make_image(42))
    assert not np.array_equal(make_image(1), make_image(2))
    d = dataset(4)
    assert d.shape == (4, 32, 32, 3) and d.min() >= 0 and d.max() <= 1


def test_adversary_reconstructs_shallow_layer():
    """Early-layer features permit reconstruction (SSIM well above noise
    floor) — the paper's Fig. 7(c) effect at smoke scale."""
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rep = R.train_adversary(params, cfg, layer=1, steps=60, batch=8,
                            n_eval=32)
    noise_floor = float(ssim(jnp.asarray(make_batch(0, 8)),
                             jnp.asarray(make_batch(500, 8))))
    assert rep.ssim > noise_floor + 0.1, (rep.ssim, noise_floor)


def test_partition_search_runs_algorithm1(monkeypatch):
    """Algorithm 1 control flow incl. the non-monotone verify rule, with a
    stubbed adversary (deterministic SSIM schedule from the paper Fig. 8:
    high, high, low, HIGH again, low, low, low...)."""
    cfg = get_smoke("vgg16")
    schedule = {1: 0.8, 2: 0.7, 3: 0.2, 4: 0.6, 5: 0.2, 6: 0.15, 7: 0.1}

    def fake_train(params, cfg_, layer, **kw):
        return R.AdversaryReport(layer=layer, ssim=schedule.get(layer, 0.05),
                                 g_loss=0, d_loss=0, steps=0)

    monkeypatch.setattr(R, "train_adversary", fake_train)
    p, reports = R.partition_search(None, cfg, threshold=0.35,
                                    max_layer=7)
    # layer 3 is below threshold but layer 4 rebounds -> must pick 5
    assert p == 5
    evaluated = {r.layer for r in reports}
    assert {3, 4, 5, 6, 7} <= evaluated


def test_token_recovery_probe_on_identity():
    """A boundary that IS the embedding must be recoverable; random noise
    must not be."""
    vocab, d = 64, 32
    emb = jax.random.normal(jax.random.PRNGKey(0), (vocab, d))

    acc_id = R.token_recovery_probe(
        lambda t: emb[t], vocab, d, steps=80, batch=8, seq=16)
    acc_noise = R.token_recovery_probe(
        lambda t: jax.random.normal(jax.random.PRNGKey(1),
                                    t.shape + (d,)),
        vocab, d, steps=80, batch=8, seq=16)
    assert acc_id > 0.9
    assert acc_noise < 0.2
