"""Cost-model partition planner: feasibility, monotonicity, fallbacks."""
import jax
import pytest

from repro.configs import get_smoke
from repro.core.planner import PartitionPlanner, leakage_profile
from repro.core.trust import EnclaveSim
from repro.models import model as M


@pytest.fixture(scope="module")
def vgg():
    cfg = get_smoke("vgg16")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def test_leakage_proxy_bounded_and_fc_fail_closed(vgg):
    cfg, params = vgg
    prof = leakage_profile(params, cfg, n_images=2)
    assert set(prof) == set(range(1, len(cfg.cnn_layers)))
    assert all(0.0 <= v <= 1.0 for v in prof.values())
    # fc boundaries are unmeasurable by the spatial proxy; they inherit
    # the last conv/pool boundary's leakage (fail-closed), never 0 —
    # scoring them 0 would make them feasible under any floor
    fc_idx = cfg.cnn_layers.index("fc32") + 1
    assert prof[fc_idx] == prof[fc_idx - 1]


def test_planner_monotone_in_privacy_floor(vgg):
    """Tighter floor => partition never shrinks (feasible-set inclusion +
    runtime non-decreasing in blinded depth)."""
    cfg, params = vgg
    prof = leakage_profile(params, cfg, n_images=2)
    prev = 0
    for floor in (0.95, 0.6, 0.35, 0.2, 0.1, 0.01):
        plan = PartitionPlanner(privacy_floor=floor).plan(
            cfg, params, leakage=prof)
        assert plan.partition >= prev, (floor, plan.partition, prev)
        prev = plan.partition


def test_planner_monotone_on_synthetic_nonmonotone_leakage(vgg):
    """Algorithm 1's verify-deeper rule: a safe boundary followed by a
    leaky one is not feasible, and the floor sweep stays monotone."""
    cfg, params = vgg
    leak = {1: 0.8, 2: 0.2, 3: 0.7, 4: 0.3, 5: 0.2, 6: 0.1, 7: 0.05}
    prev = 0
    for floor in (0.9, 0.6, 0.35, 0.15, 0.06):
        plan = PartitionPlanner(privacy_floor=floor, verify_depth=2).plan(
            cfg, params, leakage=leak)
        assert plan.partition >= prev
        prev = plan.partition
    # floor=0.35: p=2 is below floor but p=3 (0.7) leaks within the
    # verify window, so the first feasible point is p=4
    plan = PartitionPlanner(privacy_floor=0.35, verify_depth=2).plan(
        cfg, params, leakage=leak)
    assert 2 not in plan.feasible
    assert plan.partition == 4


def test_planner_picks_cheapest_feasible(vgg):
    cfg, params = vgg
    leak = {p: 0.0 for p in range(1, len(cfg.cnn_layers))}
    plan = PartitionPlanner(privacy_floor=0.5).plan(cfg, params,
                                                    leakage=leak)
    sim = EnclaveSim(cfg)
    assert plan.partition in plan.feasible
    best = min(plan.feasible,
               key=lambda p: (sim.runtime("origami", p).runtime_s, p))
    assert plan.partition == best


def test_planner_blinds_everything_when_nothing_safe(vgg):
    """No boundary safe to expose => tier-1 covers ALL layers (nothing
    leaves the blinded tier), not just the deepest candidate boundary."""
    cfg, params = vgg
    leak = {p: 0.9 for p in range(1, len(cfg.cnn_layers))}
    plan = PartitionPlanner(privacy_floor=0.1).plan(cfg, params,
                                                    leakage=leak)
    assert plan.feasible == ()
    assert plan.partition == len(cfg.cnn_layers)


def test_planner_fallbacks():
    lm = get_smoke("smollm_135m")
    plan = PartitionPlanner().plan(lm, None)
    assert (plan.source, plan.partition) == ("config",
                                             lm.origami.tier1_layers)
    vgg = get_smoke("vgg16")
    plan = PartitionPlanner().plan(vgg, None, partition=5)
    assert (plan.source, plan.partition) == ("explicit", 5)


def test_runtime_model_nondecreasing_in_partition(vgg):
    """The invariant the monotonicity argument leans on."""
    cfg, _ = vgg
    sim = EnclaveSim(cfg)
    costs = [sim.runtime("origami", p).runtime_s
             for p in range(1, len(cfg.cnn_layers))]
    assert all(b >= a - 1e-12 for a, b in zip(costs, costs[1:]))
