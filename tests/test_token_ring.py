"""Token-slot ring (runtime/sessions.py): per-(session, token, layer)
reuse guard, refill-thread outrun, and bit-exact cached-vs-live factors
across >= 64 decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import blinding as B
from repro.core import integrity as IG
from repro.core.origami import OrigamiExecutor
from repro.core.precompute import BlindedLayerCache
from repro.kernels.limb_matmul.ops import field_matmul
from repro.runtime.sessions import SessionPool, SlotReuseError, TokenSlotRing


def _decode_cache(batch=2, integrity=None):
    cfg = get_smoke("smollm_135m")
    params = None  # set below; keep init in one place
    import repro.models.model as M
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ex = OrigamiExecutor(cfg, params, "origami", integrity=integrity)
    ex.attach_decode_plan(max_steps=256)
    return ex.decode_cache(batch)


def test_reuse_guard_raises_on_token_reissue():
    cache = _decode_cache()
    ring = TokenSlotRing(cache, jax.random.PRNGKey(5), lo=3, depth=4)
    try:
        first = ring.take(3)
        assert first and all("r" in e for e in first)
        with pytest.raises(SlotReuseError):
            ring.take(3)
        # non-contiguous issue is fine; the re-issue is what dies
        ring.take(7)
        with pytest.raises(SlotReuseError):
            ring.take(7)
        assert ring.stats()["consumed"] == 2
    finally:
        ring.close()


def test_take_after_close_refuses():
    cache = _decode_cache()
    ring = TokenSlotRing(cache, jax.random.PRNGKey(5), depth=2)
    ring.close()
    with pytest.raises(RuntimeError):
        ring.take(0)


def test_refill_outrun_falls_back_synchronously():
    """A consumer faster than the refill thread gets counted misses and
    correct factors — never an error, never a stall."""
    cache = _decode_cache()
    ring = TokenSlotRing(cache, jax.random.PRNGKey(6), lo=0, depth=2)
    try:
        for t in range(64):
            got = ring.take(t)
            assert len(got) == cache.num_layers
        st = ring.stats()
        assert st["consumed"] == 64
        assert st["refill_errors"] == 0
        # everything the ring prefetched + everything taken synchronously
        # adds up: no token was silently skipped
        assert st["refilled"] + st["misses"] >= 64 - st["depth"]
    finally:
        ring.close()


def test_refill_fault_contained():
    boom = {"n": 0}

    def fault(token):
        boom["n"] += 1
        raise RuntimeError("chaos")

    cache = _decode_cache()
    ring = TokenSlotRing(cache, jax.random.PRNGKey(8), depth=2,
                         refill_fault=fault)
    try:
        for t in range(8):
            assert ring.take(t)
        st = ring.stats()
        assert st["consumed"] == 8
        assert st["refill_errors"] >= 1 and boom["n"] >= 1
    finally:
        ring.close()


def test_ring_factors_bit_exact_vs_live_derivation():
    """>= 64 decode steps: every ring slot's (r, u, s, ws) must equal the
    live in-trace derivation — stream_key/fold_stream keyed by
    (session, layer, token) — bit for bit. This is the property that lets
    one compiled token-step executable consume either source."""
    pol = IG.IntegrityPolicy.full(k=2)
    cache = _decode_cache(batch=2, integrity=pol)
    key = jax.random.PRNGKey(11)
    ring = TokenSlotRing(cache, key, lo=1, depth=8)
    try:
        for t in range(1, 66):
            slot = ring.take(t)
            for i, (entry, lyr) in enumerate(zip(slot, cache.layers)):
                r_live = B.blinding_stream(B.stream_key(key, i, t),
                                           (lyr.t, lyr.d_in))
                np.testing.assert_array_equal(np.asarray(entry["r"]),
                                              np.asarray(r_live))
                np.testing.assert_array_equal(
                    np.asarray(entry["u"]),
                    np.asarray(field_matmul(r_live, lyr.w_q)))
                s_live = IG.fold_stream(key, i, t, lyr.d_out, pol.k)
                np.testing.assert_array_equal(np.asarray(entry["s"]),
                                              np.asarray(s_live))
                np.testing.assert_array_equal(
                    np.asarray(entry["ws"]),
                    np.asarray(field_matmul(lyr.w_q, s_live)))
    finally:
        ring.close()


def test_pool_acquire_stream_composes_key_and_ring():
    cache = _decode_cache()
    pool = SessionPool(None, depth=2, background=False)
    try:
        k1, r1 = pool.acquire_stream(cache, lo=4, depth=2,
                                     background=False)
        k2, r2 = pool.acquire_stream(cache, lo=4, depth=2,
                                     background=False)
        assert not np.array_equal(np.asarray(k1), np.asarray(k2))
        assert r1.take(4) and r2.take(4)   # same token, different sessions
        with pytest.raises(SlotReuseError):
            r1.take(4)
        kn, rn = pool.acquire_stream(None)
        assert rn is None and kn is not None
    finally:
        pool.close()
