"""Origami executor: mode equivalence, partitioning semantics, trust model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.core.origami import MODES, OrigamiExecutor
from repro.core.trust import EnclaveSim
from repro.models import model as M


@pytest.fixture(scope="module")
def vgg():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jax.random.normal(
        jax.random.PRNGKey(1), (2, cfg.image_size, cfg.image_size, 3)) * 0.5}
    return cfg, params, batch


def test_non_blinded_modes_exact(vgg):
    cfg, params, batch = vgg
    ref = np.asarray(OrigamiExecutor(cfg, params, mode="open")
                     .infer(batch).logits, np.float32)
    for mode in ("enclave", "split"):
        got = np.asarray(OrigamiExecutor(cfg, params, mode=mode)
                         .infer(batch).logits, np.float32)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_blinded_modes_close(vgg):
    cfg, params, batch = vgg
    ref = np.asarray(OrigamiExecutor(cfg, params, mode="open")
                     .infer(batch).logits, np.float32)
    for mode in ("origami", "slalom"):
        got = np.asarray(OrigamiExecutor(cfg, params, mode=mode)
                         .infer(batch).logits, np.float32)
        rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
        assert rel < 0.05, (mode, rel)    # quantization-level error only


def test_origami_blinds_fewer_layers_than_slalom(vgg):
    cfg, params, batch = vgg
    o = OrigamiExecutor(cfg, params, mode="origami")
    s = OrigamiExecutor(cfg, params, mode="slalom")
    o.infer(batch)
    s.infer(batch)
    assert 0 < o.telemetry.calls < s.telemetry.calls
    assert o.telemetry.blinded_bytes < s.telemetry.blinded_bytes


def test_boundary_is_tier1_output(vgg):
    cfg, params, batch = vgg
    from repro.models import vgg as V
    p = cfg.origami.tier1_layers
    r = OrigamiExecutor(cfg, params, mode="split").infer(batch)
    want = V.apply_layer_range(params, batch["images"], cfg, 0, p)
    np.testing.assert_allclose(np.asarray(r.boundary, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_lm_origami_matches_quantization_error():
    cfg = get_smoke("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                          cfg.vocab_size)}
    # "split" runs the same tier-1 prefix in plain fp — its boundary is the
    # oracle for origami's blinded tier-1 boundary.
    ref = OrigamiExecutor(cfg, params, mode="split").infer(batch)
    got = OrigamiExecutor(cfg, params, mode="origami").infer(batch)
    b_ref = np.asarray(ref.boundary, np.float32)
    b_got = np.asarray(got.boundary, np.float32)
    rel = np.abs(b_got - b_ref).max() / (np.abs(b_ref).max() + 1e-9)
    assert rel < 0.25, rel


def test_partition_bounds():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ex = OrigamiExecutor(cfg, params, mode="origami", partition=2)
    assert ex.partition == 2
    batch = {"images": jnp.zeros((1, cfg.image_size, cfg.image_size, 3))}
    ex.infer(batch)
    assert ex.telemetry.calls == 2        # conv8, conv8 before pool


# ---------------------------------------------------------------------------
# cost/residency model vs the paper's published numbers
# ---------------------------------------------------------------------------

PAPER = {
    "vgg16": {"slalom_x": 10.0, "origami_x": 12.7, "resident_baseline": 86,
              "resident_split6": 29, "resident_privacy": 39,
              "recovery_baseline_ms": 201},
    "vgg19": {"slalom_x": 11.0, "origami_x": 15.1},
}


@pytest.mark.parametrize("arch", ["vgg16", "vgg19"])
def test_cost_model_reproduces_paper_speedups(arch):
    cfg = get_config(arch)
    sim = EnclaveSim(cfg, device="gpu")
    cs = sim.all_strategies(6)
    base = cs["enclave"].runtime_s
    slalom_x = base / cs["slalom"].runtime_s
    origami_x = base / cs["origami"].runtime_s
    want = PAPER[arch]
    assert abs(slalom_x - want["slalom_x"]) / want["slalom_x"] < 0.15
    assert abs(origami_x - want["origami_x"]) / want["origami_x"] < 0.15
    assert origami_x > slalom_x > base / cs["split"].runtime_s


def test_cost_model_reproduces_paper_memory():
    cfg = get_config("vgg16")
    sim = EnclaveSim(cfg, device="gpu")
    cs = sim.all_strategies(6)
    want = PAPER["vgg16"]
    assert abs(cs["enclave"].enclave_resident_mb
               - want["resident_baseline"]) < 12
    assert abs(cs["split"].enclave_resident_mb
               - want["resident_split6"]) < 6
    assert abs(cs["origami"].enclave_resident_mb
               - want["resident_privacy"]) < 6
    assert (cs["origami"].enclave_resident_mb
            == cs["slalom"].enclave_resident_mb)   # paper Table I


def test_recovery_time_ordering():
    cfg = get_config("vgg16")
    sim = EnclaveSim(cfg, device="gpu")
    cs = sim.all_strategies(6)
    assert cs["split"].recovery_s < cs["origami"].recovery_s \
        < cs["enclave"].recovery_s
    assert abs(cs["enclave"].recovery_s * 1e3
               - PAPER["vgg16"]["recovery_baseline_ms"]) < 30
