"""Blind/unblind kernels: Pallas(interpret) vs oracle + roundtrip bounds."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.blind import ref
from repro.kernels.blind.ops import blind, unblind
from repro.kernels.limb_matmul.ref import HALF, P


@pytest.mark.parametrize("shape", [(7, 40), (37, 300), (4, 17, 23),
                                   (256, 512)])
@pytest.mark.parametrize("k_bits", [6, 8, 12])
def test_blind_pallas_matches_ref(shape, k_bits, rng):
    x = rng.normal(size=shape).astype(np.float32)
    r = rng.integers(0, P, size=shape, dtype=np.int32)
    b_ref = np.asarray(ref.blind_ref(jnp.asarray(x), jnp.asarray(r), k_bits))
    b_pl = np.asarray(blind(jnp.asarray(x), jnp.asarray(r), k_bits,
                            impl="interpret"))
    np.testing.assert_array_equal(b_ref, b_pl)


@pytest.mark.parametrize("shape,bm,bk", [((256, 512), 128, 256),
                                         ((128, 128), 128, 128),
                                         ((512, 256), 256, 256)])
@pytest.mark.parametrize("k_bits", [6, 8])
def test_blind_encode_pallas_matches_ref(shape, bm, bk, k_bits, rng):
    """Fused scale+quantize+blind+limb-encode kernel vs its jnp oracle."""
    from repro.kernels.blind.blind import blind_encode_pallas
    from repro.kernels.blind.ref import blind_encode_ref
    x = jnp.asarray(rng.normal(size=shape) * 3, jnp.float32)
    r = jnp.asarray(rng.integers(0, P, size=shape), jnp.int32)
    inv = jnp.float32(1.0 / 2.7)
    got = np.asarray(blind_encode_pallas(x, r, inv.reshape(1, 1), k_bits,
                                         bm=bm, bk=bk, interpret=True))
    want = np.asarray(blind_encode_ref(x, r, inv, k_bits))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_unblind_pallas_matches_ref(dtype, rng):
    y = rng.integers(0, P, size=(33, 130), dtype=np.int32)
    u = rng.integers(0, P, size=(33, 130), dtype=np.int32)
    got = np.asarray(unblind(jnp.asarray(y), jnp.asarray(u), 10,
                             out_dtype=dtype, impl="interpret"),
                     np.float32)
    want = np.asarray(ref.unblind_ref(jnp.asarray(y), jnp.asarray(u), 10,
                                      dtype), np.float32)
    np.testing.assert_allclose(got, want)


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 14), st.integers(0, 2 ** 31 - 1))
def test_blind_unblind_roundtrip_bound(k_bits, seed):
    """unblind(blind(x, r), r) recovers x to quantization precision."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(16, 32)) * 0.5).astype(np.float32)
    r = rng.integers(0, P, size=x.shape, dtype=np.int32)
    b = ref.blind_ref(jnp.asarray(x), jnp.asarray(r), k_bits)
    back = np.asarray(ref.unblind_ref(b, jnp.asarray(r), k_bits))
    assert np.abs(back - x).max() <= 2.0 ** (-k_bits - 1) + 1e-7


def test_blinded_values_uniform(rng):
    """One-time-pad property: blinded output is ~uniform over Z_p whatever
    the input (KS-style coarse bin test)."""
    r = rng.integers(0, P, size=(200_000,), dtype=np.int32)
    for x in (np.zeros(200_000, np.float32),
              np.full(200_000, 0.123, np.float32),
              rng.normal(size=200_000).astype(np.float32)):
        b = np.asarray(ref.blind_ref(jnp.asarray(x), jnp.asarray(r), 8),
                       np.int64)
        hist, _ = np.histogram(b, bins=16, range=(0, P))
        expected = len(b) / 16
        chi2 = np.sum((hist - expected) ** 2 / expected)
        assert chi2 < 80, chi2          # 15 dof, generous bound


def test_quantize_clips_to_field():
    x = jnp.asarray([1e9, -1e9, 0.0], jnp.float32)
    q = np.asarray(ref.quantize(x, 8))
    assert q.max() <= HALF and q.min() >= -HALF
