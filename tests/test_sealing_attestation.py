"""Sealed channel + attestation simulation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.core.attestation import measure_enclave, verify_quote
from repro.core.sealing import seal, unseal
from repro.models import model as M


def _key(seed=7):
    return jax.random.key_data(jax.random.PRNGKey(seed))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_seal_roundtrip(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    box = seal(_key(), x, jnp.asarray([seed & 0xFFFF, 2], jnp.uint32))
    pt, ok = unseal(_key(), box, x.shape)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(pt), np.asarray(x))


def test_tamper_detection(rng):
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    box = seal(_key(), x, jnp.asarray([1, 2], jnp.uint32))
    bad = box._replace(ciphertext=box.ciphertext.at[0, 0].add(1))
    _, ok = unseal(_key(), bad, x.shape)
    assert not bool(ok)


def test_nonce_tamper_detected(rng):
    """The nonce selects the keystream, so it must be authenticated: a
    swapped nonce (e.g. another rid's split, or a stripped direction tag)
    must fail the MAC, not decrypt to garbage with ok=True."""
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    box = seal(_key(), x, jnp.asarray([1, 2, 0xEE], jnp.uint32))
    swapped = box._replace(nonce=jnp.asarray([3, 2, 0xEE], jnp.uint32))
    _, ok = unseal(_key(), swapped, x.shape)
    assert not bool(ok)
    stripped = box._replace(nonce=box.nonce[:2])   # drop the direction tag
    _, ok = unseal(_key(), stripped, x.shape)
    assert not bool(ok)


def test_wrong_key_garbles(rng):
    x = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    box = seal(_key(1), x, jnp.asarray([1, 2], jnp.uint32))
    pt, ok = unseal(_key(2), box, x.shape)
    assert not bool(ok)
    assert not np.allclose(np.asarray(pt), np.asarray(x))


def test_nonce_changes_ciphertext(rng):
    x = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    b1 = seal(_key(), x, jnp.asarray([1, 0], jnp.uint32))
    b2 = seal(_key(), x, jnp.asarray([2, 0], jnp.uint32))
    assert not np.array_equal(np.asarray(b1.ciphertext),
                              np.asarray(b2.ciphertext))


def test_quote_stable_and_sensitive():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    q1 = measure_enclave(cfg, params, 3)
    q2 = measure_enclave(cfg, params, 3)
    assert verify_quote(q1, q2)
    q3 = measure_enclave(cfg, params, 4)       # different partition
    assert not verify_quote(q1, q3)
    params2 = M.init_params(cfg, jax.random.PRNGKey(1))
    q4 = measure_enclave(cfg, params2, 3)      # different weights
    assert q4.measurement != q1.measurement
