"""Async serving engine: equivalence vs. the legacy server, out-of-order
completion under mixed traffic, session-pool hygiene, admission control."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M
from repro.privacy.data import make_batch
from repro.runtime.engine import EngineConfig, ServingEngine
from repro.runtime.serving import PrivateInferenceServer, Request
from repro.runtime.sessions import SessionPool, SessionReuseError


@pytest.fixture(scope="module")
def zoo():
    cfg16 = get_smoke("vgg16")
    cfg19 = get_smoke("vgg19")
    return {
        "vgg16": (cfg16, M.init_params(cfg16, jax.random.PRNGKey(0))),
        "vgg19": (cfg19, M.init_params(cfg19, jax.random.PRNGKey(1))),
    }


def _request(cfg, rid, rng):
    img = make_batch(rid, 1, cfg.image_size)[0]
    key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, img, rid)
    return Request(rid=rid, box=box, shape=img.shape, session_key=key), key


def test_engine_bit_identical_to_legacy_server(zoo, rng):
    cfg, params = zoo["vgg16"]
    reqs, keys = zip(*[_request(cfg, i, rng) for i in range(8)])

    legacy = PrivateInferenceServer(cfg, params, mode="origami", max_batch=4)
    want = []
    for i in range(0, 8, 4):
        want += legacy.serve_batch(list(reqs[i:i + 4]))

    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=500.0))
    engine.register_model("vgg16", cfg, params)
    try:
        futures = [engine.submit("vgg16", r) for r in reqs]
        got = [f.result(timeout=180) for f in futures]
    finally:
        engine.close()

    assert all(r.ok for r in got)
    for w, g in zip(want, got):
        lw = PrivateInferenceServer.client_open(keys[w.rid], w.box,
                                                (cfg.num_classes,))
        lg = PrivateInferenceServer.client_open(keys[g.rid], g.box,
                                                (cfg.num_classes,))
        assert np.array_equal(lw, lg), f"rid {w.rid} not bit-identical"


def test_out_of_order_completion_mixed_models(zoo, rng):
    """A later-submitted model's full bucket completes before an earlier
    partial bucket that waits for its max_wait timer."""
    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=2000.0))
    for name, (cfg, params) in zoo.items():
        engine.register_model(name, cfg, params)
    try:
        cfg16, _ = zoo["vgg16"]
        cfg19, _ = zoo["vgg19"]
        # build (and seal) every request up front: only the cheap submit
        # calls sit between the partial bucket opening and the full bucket
        # filling, so the vgg16 flush timer cannot fire in between even on
        # a heavily loaded CPU
        warm16 = [_request(cfg16, 900 + i, rng)[0] for i in range(4)]
        warm19 = [_request(cfg19, 950 + i, rng)[0] for i in range(4)]
        reqs16 = [_request(cfg16, 10 + i, rng)[0] for i in range(2)]
        reqs19 = [_request(cfg19, 20 + i, rng)[0] for i in range(4)]

        # warm both executables so timing reflects batching, not compiles
        [f.result(timeout=300)
         for f in ([engine.submit("vgg16", r) for r in warm16]
                   + [engine.submit("vgg19", r) for r in warm19])]

        mark = len(engine.completion_order)
        # 2 vgg16 (partial bucket -> waits on timer), then 4 vgg19 (full)
        f16 = [engine.submit("vgg16", r) for r in reqs16]
        f19 = [engine.submit("vgg19", r) for r in reqs19]
        got = [f.result(timeout=300) for f in f16 + f19]
        assert all(r.ok for r in got)
        order = list(engine.completion_order)[mark:]
        # vgg19's full bucket dispatched first despite later submission
        assert [m for m, _ in order[:4]] == ["vgg19"] * 4, order
        assert {m for m, _ in order[4:]} == {"vgg16"}, order
    finally:
        engine.close()


def _lm_request(cfg, rid, seq, rng):
    toks = rng.integers(0, cfg.vocab_size, size=(seq,)).astype(np.float32)
    key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, toks, rid)
    return Request(rid=rid, box=box, shape=toks.shape, session_key=key), key


def test_lm_mixed_shape_buckets_complete_independently(rng):
    """A smoke LM in the same registry; two sequence lengths land in two
    (model, shape) buckets that pad and dispatch independently."""
    cfg = get_smoke("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=150.0))
    engine.register_model("lm", cfg, params, input_key="tokens",
                          input_dtype="int32")
    try:
        reqs = ([_lm_request(cfg, 30 + i, 8, rng) for i in range(2)]
                + [_lm_request(cfg, 40, 16, rng)])
        futs = [engine.submit("lm", r) for r, _ in reqs]
        got = [f.result(timeout=300) for f in futs]
        assert all(r.ok for r in got)
        assert engine.stats.batches >= 2       # two buckets, two dispatches
        # logits unseal per request with the right (seq, vocab) shape
        lg = PrivateInferenceServer.client_open(
            reqs[2][1], got[2].box, (16, cfg.padded_vocab))
        assert np.isfinite(lg).all()
    finally:
        engine.close()


def test_admission_control_rejects_over_capacity(zoo, rng):
    cfg, params = zoo["vgg16"]
    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=50.0,
                                        max_queue=2))
    engine.register_model("vgg16", cfg, params)
    try:
        reqs = [_request(cfg, 50 + i, rng)[0] for i in range(6)]
        futs = [engine.submit("vgg16", r) for r in reqs]
        got = [f.result(timeout=300) for f in futs]
        # with max_queue=2 at least the burst tail is shed immediately
        assert engine.stats.rejected >= 1
        rejected = [r for r in got if not r.ok]
        assert all(r.box is None for r in rejected)
    finally:
        engine.close()


def test_unknown_model_rejected(zoo, rng):
    cfg, params = zoo["vgg16"]
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=10.0))
    engine.register_model("vgg16", cfg, params)
    try:
        req, _ = _request(cfg, 60, rng)
        resp = engine.submit("resnet50", req).result(timeout=10)
        assert not resp.ok and engine.stats.rejected == 1
    finally:
        engine.close()


def test_expired_deadline_never_reaches_executor(zoo, rng):
    cfg, params = zoo["vgg16"]
    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=80.0))
    entry = engine.register_model("vgg16", cfg, params)
    try:
        req, _ = _request(cfg, 70, rng)
        fut = engine.submit("vgg16", req, deadline_s=1e-4)
        time.sleep(0.02)                      # let the deadline lapse
        resp = fut.result(timeout=60)
        assert not resp.ok
        assert engine.stats.expired == 1
        assert engine.stats.batches == 0      # nothing was dispatched
        assert entry.pool.consumed == 0
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# quarantine probation (per-model, poolless backends)
# ---------------------------------------------------------------------------

def test_probation_restores_clean_backend(zoo, rng):
    """A quarantined backend that has served its probation gets ONE
    verified offload probe; a clean probe restores offload (the seed
    quarantined forever)."""
    from repro.core.integrity import IntegrityPolicy
    cfg, params = zoo["vgg16"]
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=20.0,
                                        probation_after=2))
    entry = engine.register_model("vgg16", cfg, params,
                                  integrity=IntegrityPolicy.full(1))
    try:
        # manufacture the post-quarantine state on an HONEST backend
        entry.quarantined = True
        entry.trusted_streak = 2               # probation served
        req, key = _request(cfg, 300, rng)
        resp = engine.submit("vgg16", req).result(timeout=300)
        assert resp.ok and not resp.flagged
        assert not entry.quarantined           # probe was clean: restored
        assert entry.probations == 1 and entry.restores == 1
        snap = engine.stats.snapshot(engine)
        assert snap["integrity"]["probations"] == 1
        assert snap["integrity"]["probation_restores"] == 1
        assert snap["models"]["vgg16"]["restores"] == 1
    finally:
        engine.close()


def test_probation_rebenches_dishonest_backend(zoo, rng):
    """A dirty probe re-quarantines — and the probe batch itself is still
    recovered (enclave recompute), so no client sees a wrong answer."""
    from repro.core.integrity import IntegrityPolicy
    from repro.runtime.faults import DishonestDevice, FaultSpec
    cfg, params = zoo["vgg16"]
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=20.0,
                                        probation_after=2))
    entry = engine.register_model(
        "vgg16", cfg, params, integrity=IntegrityPolicy.full(1),
        fault=DishonestDevice(FaultSpec("bit_flip")))
    try:
        entry.quarantined = True
        entry.trusted_streak = 2
        req, key = _request(cfg, 310, rng)
        resp = engine.submit("vgg16", req).result(timeout=300)
        assert resp.ok and resp.flagged        # served, device blamed
        assert entry.quarantined               # dirty probe: benched again
        assert entry.probations == 1 and entry.restores == 0
        assert entry.trusted_streak == 0       # probation clock restarted
        snap = engine.stats.snapshot(engine)
        assert snap["integrity"]["probations"] == 1
        assert snap["integrity"]["probation_restores"] == 0
        assert snap["integrity"]["recomputes"] == 1
    finally:
        engine.close()


def test_sampled_policy_never_probes(zoo, rng):
    """A probe routes real client traffic back to a convicted backend, so
    it is only safe under FULL verification — a sampled policy would let
    unchecked ops carry corrupt logits to clients and could restore the
    backend off a lucky probe. Such models stay benched."""
    from repro.core.integrity import IntegrityPolicy
    cfg, params = zoo["vgg16"]
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=20.0,
                                        probation_after=1))
    entry = engine.register_model(
        "vgg16", cfg, params, integrity=IntegrityPolicy.sampled(0.5))
    try:
        entry.quarantined = True
        entry.trusted_streak = 10              # well past probation
        req, _ = _request(cfg, 315, rng)
        resp = engine.submit("vgg16", req).result(timeout=300)
        assert resp.ok
        assert entry.quarantined and entry.probations == 0
        assert engine.stats.trusted_batches == 1
    finally:
        engine.close()


def test_trusted_streak_counts_toward_probation(zoo, rng):
    cfg, params = zoo["vgg16"]
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=20.0,
                                        probation_after=5))
    entry = engine.register_model("vgg16", cfg, params)
    try:
        entry.quarantined = True
        req, _ = _request(cfg, 320, rng)
        resp = engine.submit("vgg16", req).result(timeout=300)
        assert resp.ok
        assert entry.trusted_streak == 1       # still quarantined, aging
        assert entry.quarantined and entry.probations == 0
        assert engine.stats.trusted_batches == 1
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# sharded multi-device models: quarantine is per-DEVICE, not per-model
# ---------------------------------------------------------------------------

def test_sharded_model_quarantines_device_not_model(zoo, rng):
    from repro.runtime.devices import DeviceHealthConfig, DevicePool
    from repro.runtime.faults import DishonestDevice, FaultSpec
    cfg, params = zoo["vgg16"]
    pool = DevicePool(2, faults={1: DishonestDevice(FaultSpec("bit_flip"))},
                      health=DeviceHealthConfig(quarantine_after=1,
                                                probation_after=10 ** 6))
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=20.0))
    entry = engine.register_model("vgg16", cfg, params, devices=pool)
    try:
        req, _ = _request(cfg, 330, rng)
        resp = engine.submit("vgg16", req).result(timeout=300)
        assert resp.ok and resp.flagged
        assert not entry.quarantined           # model keeps offloading
        assert pool.slots[1].quarantined       # the bad DEVICE is benched
        assert not pool.slots[0].quarantined
        snap = engine.stats.snapshot(engine)
        assert snap["integrity"]["shard_failures"] >= 1
        assert snap["integrity"]["shard_retries"] >= 1
        assert snap["integrity"]["recomputes"] == 0   # shard-local recovery
        devs = snap["devices"]["vgg16"]["pool"]["slots"]
        assert devs[1]["quarantined"] and not devs[0]["quarantined"]
        assert not snap["models"]["vgg16"]["quarantined"]
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# session pool
# ---------------------------------------------------------------------------

def test_session_pool_never_reuses_across_refills():
    pool = SessionPool(None, depth=3, background=False)
    seen = set()
    for _ in range(4):                        # several refill cycles deep
        pool.prime()
        for _ in range(3):
            kb = np.asarray(pool.acquire()).tobytes()
            assert kb not in seen
            seen.add(kb)
    assert len(seen) == 12
    s = pool.stats()
    assert s["consumed"] == 12 and s["reuse_checked"] == 12
    pool.close()


def test_session_pool_reuse_guard_trips():
    pool = SessionPool(None, depth=2, background=False)
    pool.acquire()
    pool._head = 0                            # simulate a counter rollback
    with pytest.raises(SessionReuseError):
        pool.acquire()
    pool.close()


def test_session_pool_acquire_outruns_refill_thread():
    """acquire() faster than the refill thread: the ``_head > _next`` bump
    must keep the prefetch counter ahead so the refill never regenerates
    an already-issued counter (which the reuse guard would fatally trip
    on) — the multi-device plane makes burst acquisition the common
    case."""
    pool = SessionPool(None, depth=2, background=False)
    keys = [np.asarray(pool.acquire()).tobytes() for _ in range(7)]
    assert len(set(keys)) == 7
    assert pool._next == pool._head == 7          # refill counter caught up
    pool.prime()                                  # refill resumes from 7
    more = [np.asarray(pool.acquire()).tobytes() for _ in range(4)]
    assert len(set(keys + more)) == 11
    assert pool.stats()["consumed"] == 11
    pool.close()


def test_session_pool_concurrent_acquire_never_reuses():
    """The reuse guard under concurrent acquire from many threads: every
    key unique, every acquire checked, no SessionReuseError."""
    import threading
    pool = SessionPool(None, depth=4)             # background refill ON
    n_threads, per_thread = 8, 25
    out: list = [None] * n_threads
    errors: list = []

    def worker(i):
        try:
            out[i] = [np.asarray(pool.acquire()).tobytes()
                      for _ in range(per_thread)]
        except Exception as exc:  # noqa: BLE001 — surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    issued = [k for ks in out for k in ks]
    assert len(set(issued)) == n_threads * per_thread
    s = pool.stats()
    assert s["consumed"] == n_threads * per_thread
    assert s["reuse_checked"] == n_threads * per_thread
    pool.close()


def test_session_pool_refills_executor_cache(zoo, rng):
    """After the first batch builds the layer cache, the background refill
    keeps factor sets prefetched so acquire() stops missing."""
    cfg, params = zoo["vgg16"]
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=20.0,
                                        session_pool_depth=3))
    entry = engine.register_model("vgg16", cfg, params)
    try:
        reqs = [_request(cfg, 80 + i, rng)[0] for i in range(2)]
        [f.result(timeout=300)
         for f in [engine.submit("vgg16", r) for r in reqs]]
        assert entry.executor.cache is not None
        entry.pool.prime()                    # deterministic refill
        assert entry.pool.ready() >= 1
        stats = entry.pool.stats()
        assert stats["refilled"] >= 1
    finally:
        engine.close()
