"""Compile-once AOT serving (DESIGN.md §15): exactly-once compiles under
concurrency, shape-bucket dispatch, and persistent-cache fail-closed
behavior (disk hit on a clean entry, fresh compile on a corrupted one)."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M
from repro.runtime.aot import (CompileCache, bucket_for, bucket_ladder,
                               code_version, shape_signature)
from repro.runtime.engine import EngineConfig, ServingEngine
from repro.runtime.serving import PrivateInferenceServer, Request


@pytest.fixture(scope="module")
def vgg16():
    cfg = get_smoke("vgg16")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _request(cfg, rid, rng):
    from repro.privacy.data import make_batch
    img = make_batch(rid, 1, cfg.image_size)[0]
    key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, img, rid)
    return Request(rid=rid, box=box, shape=img.shape, session_key=key), key


# ---------------------------------------------------------------------------
# pure pieces: the bucket ladder and the cache key
# ---------------------------------------------------------------------------

def test_bucket_ladder_powers_of_two():
    assert bucket_ladder(4) == (1, 2, 4)
    assert bucket_ladder(8) == (1, 2, 4, 8)
    # non-power max_batch terminates the ladder exactly at max
    assert bucket_ladder(6) == (1, 2, 4, 6)
    assert bucket_ladder(1) == (1,)


def test_bucket_for_is_occupancy_driven():
    assert [bucket_for(n, 4) for n in (1, 2, 3, 4)] == [1, 2, 4, 4]
    assert bucket_for(5, 6) == 6  # clamped to max, not to 8
    with pytest.raises(AssertionError):
        bucket_for(0, 4)
    with pytest.raises(AssertionError):
        bucket_for(5, 4)


def test_entry_key_separates_kind_shape_and_plan():
    cache = CompileCache()
    a = np.zeros((4, 8), np.float32)
    b = np.zeros((2, 8), np.float32)
    k = cache.entry_key("digest0", "blinded", (a,))
    assert k != cache.entry_key("digest0", "trusted", (a,))
    assert k != cache.entry_key("digest0", "blinded", (b,))
    assert k != cache.entry_key("digest1", "blinded", (a,))
    assert k == cache.entry_key("digest0", "blinded", (a,))


def test_shape_signature_and_code_version_stable():
    tree = {"x": np.zeros((2, 3), np.int32)}
    assert shape_signature(tree) == "2x3:int32"
    assert code_version() == code_version()
    assert len(code_version()) == 16


def test_compile_once_exactly_once_under_races():
    cache = CompileCache()
    built = []

    def build():
        built.append(1)
        return "exe"

    results = []

    def worker():
        results.append(cache.compile_once("k", build))

    ts = [threading.Thread(target=worker) for _ in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert len(built) == 1
    assert all(r[0] == "exe" for r in results)
    assert sum(fresh for _, fresh in results) == 1
    assert cache.counters["compiles"] == 1
    assert cache.counters["memo_hits"] == 7


# ---------------------------------------------------------------------------
# engine integration: exactly-once per (plan digest, shape bucket)
# ---------------------------------------------------------------------------

def test_concurrent_register_compiles_each_bucket_once(vgg16):
    """Two models sharing one plan digest, registered concurrently with
    AOT warm: the shared CompileCache compiles each (digest, kind, bucket)
    exactly once — the losing thread memo-hits every signature."""
    cfg, params = vgg16
    engine = ServingEngine(EngineConfig(max_batch=4, aot_warm=True))
    errs = []

    def register(name):
        try:
            engine.register_model(name, cfg, params)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    try:
        ts = [threading.Thread(target=register, args=(n,))
              for n in ("vgg16-a", "vgg16-b")]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs
        c = engine.aot.counters
        # ladder (1,2,4) x (blinded, trusted) = 6 signatures; the second
        # registration resolves all 6 from the memo, never recompiling
        assert c["compiles"] == 6, c
        assert c["memo_hits"] == 6, c
        assert engine.aot.request_compile_seconds == 0.0
    finally:
        engine.close()


def test_mixed_shape_submits_compile_each_bucket_once(vgg16, rng):
    """Unwarmed engine: a full bucket-4 wave, a lone bucket-1 request and
    a repeat bucket-4 wave compile exactly two executables (one per
    bucket), with the repeat wave served entirely from the memo."""
    cfg, params = vgg16
    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=500.0))
    engine.register_model("vgg16", cfg, params)
    try:
        reqs = [_request(cfg, i, rng)[0] for i in range(9)]
        waves = [reqs[0:4], reqs[4:5], reqs[5:9]]
        for wave in waves:
            got = [f.result(timeout=300) for f in
                   [engine.submit("vgg16", r) for r in wave]]
            assert all(r.ok for r in got)
        c = engine.aot.counters
        # bucket 4 + bucket 1 — and NOT a third for the repeat wave: the
        # executor's own signature memo resolves it before the cache
        assert c["compiles"] == 2, c
        snap = engine.snapshot()
        assert set(snap["buckets"]) == {1, 4}
        assert snap["buckets"][4]["batches"] == 2
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# persistent cache: disk hit on reboot, fail-closed on corruption
# ---------------------------------------------------------------------------

def _serve_one(cache_dir, cfg, params, rng, rid):
    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=50.0,
                                        compile_cache_dir=str(cache_dir)))
    engine.register_model("vgg16", cfg, params)
    try:
        req, key = _request(cfg, rid, rng)
        resp = engine.submit("vgg16", req).result(timeout=300)
        assert resp.ok, resp.error
        logits = PrivateInferenceServer.client_open(
            key, resp.box, (cfg.num_classes,))
        return logits, dict(engine.aot.counters)
    finally:
        engine.close()


def test_disk_cache_reboot_and_corruption(vgg16, rng, tmp_path):
    cfg, params = vgg16
    cache_dir = tmp_path / "aot"

    # cold boot: fresh compile, persisted
    logits0, c0 = _serve_one(cache_dir, cfg, params, rng, 7000)
    assert c0["compiles"] >= 1
    if c0["stores"] == 0:
        pytest.skip("jax build lacks serialize_executable: memo-only cache")

    # warm boot (new engine = empty memo): loaded from disk, zero compiles,
    # bit-exact logits
    logits1, c1 = _serve_one(cache_dir, cfg, params, rng, 7000)
    assert c1["compiles"] == 0, c1
    assert c1["disk_hits"] >= 1, c1
    np.testing.assert_array_equal(logits0, logits1)

    # corrupt every persisted entry: the loader must fail closed to a
    # fresh compile (counted), never to a failed request
    entries = list(cache_dir.glob("*.xc"))
    assert entries
    for p in entries:
        p.write_bytes(b"not a pickle")
    logits2, c2 = _serve_one(cache_dir, cfg, params, rng, 7000)
    assert c2["disk_errors"] >= 1, c2
    assert c2["compiles"] >= 1, c2
    np.testing.assert_array_equal(logits0, logits2)
