"""Integrity subsystem: Freivalds soundness per fault class, completeness
on honest devices, engine quarantine/retry recovery, and precomputed-fold
bit-exactness vs a live W_q @ s oracle (DESIGN.md §9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.integrity import IntegrityPolicy, fold_stream
from repro.core.origami import OrigamiExecutor
from repro.kernels.limb_matmul import ref as FR
from repro.models import model as M
from repro.privacy.data import make_batch
from repro.runtime.engine import EngineConfig, ServingEngine
from repro.runtime.faults import KINDS, DishonestDevice, FaultSpec
from repro.runtime.serving import PrivateInferenceServer, Request


@pytest.fixture(scope="module")
def vgg():
    cfg = get_smoke("vgg16")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch(vgg):
    cfg, _ = vgg
    rng = np.random.default_rng(3)
    return {"images": jnp.asarray(
        rng.normal(size=(2, cfg.image_size, cfg.image_size,
                         cfg.image_channels)) * 0.5, jnp.float32)}


@pytest.fixture(scope="module")
def honest_logits(vgg, batch):
    cfg, params = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    return np.asarray(ex.infer(batch,
                               session_key=jax.random.PRNGKey(7)).logits)


def _request(cfg, rid, rng):
    img = make_batch(rid, 1, cfg.image_size)[0]
    key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, img, rid)
    return Request(rid=rid, box=box, shape=img.shape, session_key=key), key


# ---------------------------------------------------------------------------
# soundness: every injected corruption from every fault class is detected
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_full_policy_detects_every_fault_class(vgg, batch, honest_logits,
                                               kind):
    cfg, params = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                         integrity=IntegrityPolicy.full(1),
                         fault=DishonestDevice(FaultSpec(kind)))
    rep = ex.infer(batch, session_key=jax.random.PRNGKey(7)).integrity
    checked = np.asarray(rep.checked)
    failed = np.asarray(rep.failed)
    corrupted = np.asarray(rep.corrupted)
    assert rep.n_ops == 2 and checked.all()
    # detection == ground truth: every corrupted op flagged, no false
    # positives on clean ops
    np.testing.assert_array_equal(failed, corrupted)
    if kind == "adaptive":
        # full verification neutralizes the adaptive adversary entirely:
        # it never finds an unverified op to corrupt
        assert rep.n_corrupted == 0
    else:
        assert rep.n_corrupted == 2 and rep.n_failed == 2


@pytest.mark.parametrize("kind", ["bit_flip", "stale"])
def test_unfused_impl_detects_too(vgg, batch, kind):
    """The seed (unfused) data path verifies in the blinded domain
    (y_b @ s vs x_b @ ws) — same detection guarantee."""
    cfg, params = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", impl="unfused",
                         integrity=IntegrityPolicy.full(1),
                         fault=DishonestDevice(FaultSpec(kind)))
    rep = ex.infer(batch, session_key=jax.random.PRNGKey(7)).integrity
    assert rep.n_corrupted == 2 and rep.n_failed == 2


# ---------------------------------------------------------------------------
# completeness: an honest device is never flagged
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", [IntegrityPolicy.full(1),
                                    IntegrityPolicy.full(2),
                                    IntegrityPolicy.sampled(0.5, 1)])
def test_honest_device_never_flagged_across_seeds(vgg, batch, honest_logits,
                                                  policy):
    cfg, params = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                         integrity=policy)
    for seed in range(6):
        r = ex.infer(batch, session_key=jax.random.PRNGKey(40 + seed))
        assert r.integrity.n_failed == 0, seed
        assert r.integrity.n_corrupted == 0
    # verification must not perturb the data path
    r7 = ex.infer(batch, session_key=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(r7.logits), honest_logits)


def test_sampled_detection_rate_at_least_expected(vgg, batch):
    """sampled(rate) detects an oblivious persistent corruptor at ≥ rate
    (each op's check decision is an independent Bernoulli(rate), and a
    checked corrupted op is detected with prob 1 − 1/p ≈ 1)."""
    cfg, params = vgg
    rate = 0.5
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                         integrity=IntegrityPolicy.sampled(rate),
                         fault=DishonestDevice(FaultSpec("bit_flip")))
    checked = corrupted = detected = 0
    for seed in range(12):              # 24 ops total
        rep = ex.infer(batch,
                       session_key=jax.random.PRNGKey(60 + seed)).integrity
        checked += rep.n_checked
        corrupted += rep.n_corrupted
        detected += rep.n_failed
    assert corrupted == 24
    assert 0 < checked < 24             # genuinely sampling
    assert detected == checked          # every checked corruption caught
    # measured rate ≥ expected with slack for the finite Bernoulli sample
    assert detected / corrupted >= rate - 0.25


def test_adaptive_adversary_evades_sampling_but_not_full(vgg, batch):
    """The policy table's sharp edge: an adversary that knows the sampling
    schedule corrupts only unverified ops — sampled() never detects it,
    full() never lets it corrupt."""
    cfg, params = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                         integrity=IntegrityPolicy.sampled(0.5),
                         fault=DishonestDevice(FaultSpec("adaptive")))
    corrupted = detected = 0
    for seed in range(8):
        rep = ex.infer(batch,
                       session_key=jax.random.PRNGKey(80 + seed)).integrity
        corrupted += rep.n_corrupted
        detected += rep.n_failed
    assert corrupted > 0 and detected == 0


# ---------------------------------------------------------------------------
# recovery: enclave recompute is bit-exact vs the honest blinded path
# ---------------------------------------------------------------------------
def test_trusted_recompute_bit_exact(vgg, batch, honest_logits):
    cfg, params = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    r = ex.infer(batch, session_key=jax.random.PRNGKey(123), trusted=True)
    np.testing.assert_array_equal(np.asarray(r.logits), honest_logits)
    assert r.trusted and ex.telemetry.trusted_matmuls == 2


def test_serve_batch_recovers_corrupted_responses(vgg, rng):
    """Legacy serving path: a dishonest device corrupts, the shared
    sealed-batch primitive detects, recomputes, and the client still
    opens logits bit-identical to an honest server's."""
    cfg, params = vgg
    honest = PrivateInferenceServer(cfg, params, mode="origami", max_batch=4)
    faulty = PrivateInferenceServer(
        cfg, params, mode="origami", max_batch=4,
        integrity=IntegrityPolicy.full(1),
        fault=DishonestDevice(FaultSpec("stale")))
    reqs, keys = zip(*[_request(cfg, i, rng) for i in range(4)])
    want = honest.serve_batch(list(reqs))
    got = faulty.serve_batch(list(reqs))
    assert faulty.integrity_totals.failures > 0
    assert faulty.integrity_totals.recomputes == 1
    for w, g in zip(want, got):
        assert g.ok and g.flagged and not w.flagged
        lw = PrivateInferenceServer.client_open(keys[w.rid], w.box,
                                                (cfg.num_classes,))
        lg = PrivateInferenceServer.client_open(keys[g.rid], g.box,
                                                (cfg.num_classes,))
        np.testing.assert_array_equal(lw, lg)


def test_engine_quarantines_persistent_failures_and_stays_correct(vgg, rng):
    """Persistently failing backend: each batch fails -> device retry
    fails -> enclave recomputes; after quarantine_after consecutive
    failures the engine stops offloading entirely, and every response
    (before and after quarantine) is bit-exact vs an honest server."""
    cfg, params = vgg
    honest = PrivateInferenceServer(cfg, params, mode="origami", max_batch=4)
    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=200.0,
                                        quarantine_after=2))
    engine.register_model("vgg16", cfg, params,
                          integrity=IntegrityPolicy.full(1),
                          fault=DishonestDevice(FaultSpec("bit_flip")))
    reqs, keys = zip(*[_request(cfg, i, rng) for i in range(16)])
    want = []
    for i in range(0, 16, 4):
        want += honest.serve_batch(list(reqs[i:i + 4]))
    try:
        futures = [engine.submit("vgg16", r) for r in reqs]
        got = [f.result(timeout=300) for f in futures]
        snap = engine.stats.snapshot(engine)
    finally:
        engine.close()
    assert all(r.ok for r in got)
    for w, g in zip(want, got):
        lw = PrivateInferenceServer.client_open(keys[w.rid], w.box,
                                                (cfg.num_classes,))
        lg = PrivateInferenceServer.client_open(keys[g.rid], g.box,
                                                (cfg.num_classes,))
        np.testing.assert_array_equal(lw, lg, err_msg=f"rid {w.rid}")
    integ = snap["integrity"]
    assert integ["verify_checks"] > 0
    assert integ["verify_failures"] > 0
    assert integ["device_retries"] >= 2
    assert integ["recomputes"] >= 2          # pre-quarantine recoveries
    assert integ["quarantines"] == 1
    assert integ["trusted_batches"] >= 1     # post-quarantine dispatches
    assert snap["models"]["vgg16"]["quarantined"]
    # pre-quarantine responses are flagged, post-quarantine ones clean
    assert any(r.flagged for r in got) and not got[-1].flagged


def test_transient_fault_clears_on_device_retry(vgg, batch, rng):
    """A transient fault (session-keyed, prob < 1) clears on the fresh-
    session device retry: the batch recovers WITHOUT an enclave recompute,
    and the responses are still bit-exact vs an honest server."""
    from repro.runtime.serving import execute_sealed_batch

    cfg, params = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                         integrity=IntegrityPolicy.full(1),
                         fault=DishonestDevice(FaultSpec("bit_flip",
                                                         prob=0.4)))
    # the corruption gate is a pure function of (session key, op) — probe
    # for one session that faults and one that is clean, then hand exactly
    # that pair to the retry machinery
    bad = good = None
    for seed in range(5000, 5040):
        k = jax.random.PRNGKey(seed)
        n = ex.infer(batch, session_key=k).integrity.n_corrupted
        if n > 0 and bad is None:
            bad = k
        if n == 0 and good is None:
            good = k
        if bad is not None and good is not None:
            break
    assert bad is not None and good is not None
    sessions = iter([bad, good])
    reqs, keys = zip(*[_request(cfg, i, rng) for i in range(2)])
    boxes, n_valid, _, integ = execute_sealed_batch(
        ex, list(reqs), input_key="images", max_batch=2,
        session_key=lambda: next(sessions))
    assert n_valid == 2
    assert integ.failures > 0 and integ.retried and not integ.recomputed
    honest = PrivateInferenceServer(cfg, params, mode="origami", max_batch=2)
    want = honest.serve_batch(list(reqs))
    for w, box, r in zip(want, boxes, reqs):
        lw = PrivateInferenceServer.client_open(keys[w.rid], w.box,
                                                (cfg.num_classes,))
        lg = PrivateInferenceServer.client_open(keys[r.rid], box,
                                                (cfg.num_classes,))
        np.testing.assert_array_equal(lw, lg)


# ---------------------------------------------------------------------------
# precomputed folds: cache vs live oracle
# ---------------------------------------------------------------------------
def test_precomputed_fold_bit_exact_vs_live_oracle(vgg, batch):
    """The cache's ws must equal a live (W_q @ s) mod p computed through
    the pure-ref oracle, and its s must equal the in-trace derivation —
    otherwise cached and on-the-fly verification would diverge."""
    cfg, params = vgg
    pol = IntegrityPolicy.full(2)
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                         integrity=pol)
    ex.build_cache(batch)
    key = jax.random.PRNGKey(17)
    factors = ex.cache.session_factors(key)
    assert ex.cache.fold_matmuls == ex.cache.num_layers
    for i, (lyr, f) in enumerate(zip(ex.cache.layers, factors)):
        s_live = fold_stream(key, i, 0, lyr.d_out, pol.k)
        np.testing.assert_array_equal(np.asarray(f["s"]),
                                      np.asarray(s_live))
        ws_oracle = FR.field_matmul_ref(jnp.asarray(lyr.w_q), s_live)
        np.testing.assert_array_equal(np.asarray(f["ws"]),
                                      np.asarray(ws_oracle))
        assert f["s"].shape == (lyr.d_out, pol.k)
        assert f["ws"].shape == (lyr.d_in, pol.k)


def test_cached_and_live_verification_bit_identical(vgg, batch):
    """Same session key, with and without the precompute cache: same check
    decisions, same outcomes, same logits (the fold vectors derive from
    the same keys either way)."""
    cfg, params = vgg
    pol = IntegrityPolicy.sampled(0.5)
    key = jax.random.PRNGKey(21)
    a = OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                        integrity=pol).infer(batch, session_key=key)
    b = OrigamiExecutor(cfg, params, mode="origami", precompute=False,
                        integrity=pol).infer(batch, session_key=key)
    np.testing.assert_array_equal(np.asarray(a.logits), np.asarray(b.logits))
    np.testing.assert_array_equal(np.asarray(a.integrity.checked),
                                  np.asarray(b.integrity.checked))
    np.testing.assert_array_equal(np.asarray(a.integrity.failed),
                                  np.asarray(b.integrity.failed))


def test_policy_off_reports_empty_and_costs_nothing(vgg, batch):
    cfg, params = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    r = ex.infer(batch, session_key=jax.random.PRNGKey(7))
    assert r.integrity.n_ops == 0 and r.integrity.ok
    assert ex.telemetry.verify_ops == 0 and ex.telemetry.verify_flops == 0
