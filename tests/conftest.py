import os
import sys
import types
from pathlib import Path

# NOTE: no xla_force_host_platform_device_count here — smoke tests must see
# exactly 1 device (the 512-device flag belongs to launch/dryrun.py only).
# Multi-device tests spawn subprocesses (tests/_subproc.py helpers).
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import pytest


# ---------------------------------------------------------------------------
# hypothesis fallback shim
# ---------------------------------------------------------------------------
# The property tests only use @settings(max_examples=..., deadline=None) and
# @given(...) over st.integers(lo, hi) / st.sampled_from(seq) strategies —
# no strategy combinators (|, maps, flatmaps). When hypothesis is not
# installed, install a deterministic-examples stand-in: each @given test runs
# against `max_examples` seeded draws (always including the strategy
# endpoints), so the suite collects and exercises the same properties with a
# fixed corpus instead of failing at import time.
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _make_hypothesis_shim():
        class _Strategy:
            def __init__(self, draw, endpoints=()):
                self.draw = draw          # fn(rng) -> value
                self.endpoints = endpoints

        class _St(types.ModuleType):
            @staticmethod
            def integers(min_value, max_value):
                return _Strategy(
                    lambda rng: int(rng.integers(min_value, max_value + 1)),
                    endpoints=(min_value, max_value))

            @staticmethod
            def sampled_from(elements):
                seq = list(elements)
                return _Strategy(
                    lambda rng: seq[int(rng.integers(0, len(seq)))],
                    endpoints=tuple(seq[:2]))

        def settings(max_examples=10, **_kw):
            def deco(fn):
                fn._shim_max_examples = max_examples
                return fn
            return deco

        def given(*strategies):
            def deco(fn):
                def runner():
                    n = getattr(runner, "_shim_max_examples",
                                getattr(fn, "_shim_max_examples", 10))
                    n = min(n, 12)        # bounded corpus for CPU CI
                    rng = np.random.default_rng(0xC0FFEE)
                    for i in range(n):
                        if i < min(len(s.endpoints) for s in strategies):
                            vals = [s.endpoints[i] for s in strategies]
                        else:
                            vals = [s.draw(rng) for s in strategies]
                        fn(*vals)
                # plain zero-arg function: pytest must not see the property
                # args as fixtures, so no functools.wraps/__wrapped__ here.
                runner.__name__ = fn.__name__
                runner.__doc__ = fn.__doc__
                runner.__module__ = fn.__module__
                runner.hypothesis = types.SimpleNamespace(inner_test=fn)
                return runner
            return deco

        mod = types.ModuleType("hypothesis")
        mod.given = given
        mod.settings = settings
        mod.strategies = _St("hypothesis.strategies")
        mod.__version__ = "0.0-shim"
        sys.modules["hypothesis"] = mod
        sys.modules["hypothesis.strategies"] = mod.strategies

    _make_hypothesis_shim()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
