import os
import sys
from pathlib import Path

# NOTE: no xla_force_host_platform_device_count here — smoke tests must see
# exactly 1 device (the 512-device flag belongs to launch/dryrun.py only).
# Multi-device tests spawn subprocesses (tests/_subproc.py helpers).
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
