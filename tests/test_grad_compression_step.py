"""Compressed training step: converges comparably to uncompressed."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw
from repro.parallel import compression as GC


def test_compressed_step_trains():
    cfg = get_smoke("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    results = {}
    for compress in (False, True):
        tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2,
                           total_steps=20, grad_compression=compress)
        p = params
        opt = adamw.init(p, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg))
        res = GC.init_residual(p) if compress else None
        losses = []
        for _ in range(12):
            if compress:
                p, opt, metrics, res = step(p, opt, batch, res)
            else:
                p, opt, metrics = step(p, opt, batch)
            losses.append(float(metrics["loss"]))
        results[compress] = losses
    # both overfit the fixed batch; compressed within 15% of uncompressed
    assert results[True][-1] < results[True][0] * 0.9
    assert abs(results[True][-1] - results[False][-1]) \
        < 0.15 * results[False][-1] + 0.2
