"""Observability plane: metrics registry atomicity, span-tree tracing over
a full blinded+verified+sharded request, mandatory redaction (fail-closed
attach + byte-scan of the serialized trace), registry/legacy agreement,
and the shared BENCH_*.json metadata envelope."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import plan as PL
from repro.core import tracing
from repro.core.integrity import IntegrityPolicy
from repro.core.tracing import RedactionError, Tracer, redact
from repro.models import model as M
from repro.runtime.devices import DevicePool
from repro.runtime.engine import EngineConfig, EngineStats, ServingEngine
from repro.runtime.faults import DishonestDevice, FaultSpec
from repro.runtime.observability import MetricsRegistry
from repro.runtime.serving import PrivateInferenceServer, Request

SENTINEL = 0.98765432  # seeds the plaintext input the byte-scan hunts for


# -- metrics registry ------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    assert reg.inc("engine.submitted") == 1
    assert reg.inc("engine.submitted", 4) == 5
    reg.inc_many(**{"shard.checks": 3, "shard.failures": 1, "noop": 0})
    assert reg.get("shard.checks") == 3
    assert reg.get("noop") == 0          # zero deltas are not materialized
    reg.gauge("engine.queue_depth", 7)
    for v in (0.1, 0.2, 0.3, 0.9):
        reg.observe("engine.latency_s", v)
    snap = reg.snapshot()
    assert snap["counters"]["engine.submitted"] == 5
    assert snap["gauges"]["engine.queue_depth"] == 7
    h = snap["histograms"]["engine.latency_s"]
    assert h["count"] == 4 and h["max"] == 0.9 and h["p50"] == 0.2
    assert reg.quantile("engine.latency_s", 0.95) == 0.9
    reg.reset("shard.")
    assert reg.get("shard.checks") == 0
    assert reg.get("engine.submitted") == 5


def test_engine_stats_concurrent_hammer():
    """Satellite 1: the old bare `+=` counters lost increments under
    concurrency; the registry-backed facade must not. Hammer from many
    threads through every legacy mutation spelling and diff exact totals."""
    stats = EngineStats()
    n_threads, iters = 8, 400

    def worker():
        for _ in range(iters):
            stats.inc("submitted")
            stats.inc_many(batches=1, batched_requests=2, padded_slots=1)
            with stats.lock:                # legacy compound block
                stats.inc("completed")
                stats.inc("verify_checks", 3)
            stats.record_done(0.01)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = n_threads * iters
    assert stats.submitted == total
    assert stats.batches == total
    assert stats.batched_requests == 2 * total
    assert stats.padded_slots == total
    assert stats.completed == 2 * total      # inc + record_done
    assert stats.verify_checks == 3 * total
    assert len(stats.latencies) == min(total, EngineStats.LAT_WINDOW)


def test_engine_stats_lock_is_registry_lock():
    stats = EngineStats()
    assert stats.lock is stats.registry.lock


# -- redaction: fail closed at attach time ---------------------------------

def test_redact_allowlist_passes_scalars_and_containers():
    assert redact(None) is None
    assert redact(True) is True
    assert redact(7) == 7
    assert redact(0.5) == 0.5
    assert redact("digest:ab12") == "digest:ab12"
    assert redact([1, 2, (3, "x")]) == [1, 2, [3, "x"]]
    assert redact({"shape": [224, 224, 3]}) == {"shape": [224, 224, 3]}
    long = "x" * 10_000
    assert len(redact(long)) == 513          # truncated, ellipsis appended


def test_redact_rejects_secret_bearing_types():
    for bad in (np.zeros(4, np.int32), jnp.zeros((2, 2)),
                b"\x00keymaterial", bytearray(b"kk"),
                memoryview(b"kk"), object()):
        with pytest.raises(RedactionError):
            redact(bad)
    # nested inside an allowed container: still rejected
    with pytest.raises(RedactionError):
        redact({"ok": 1, "oops": np.arange(3)})
    with pytest.raises(RedactionError):
        redact([[[[1]]]])                    # too deep
    with pytest.raises(RedactionError):
        redact(list(range(100)))             # too long


def test_span_attach_fails_closed():
    """A disallowed attach raises AND stores nothing — the span never
    enters the store with the secret, and annotate-after keeps the span
    clean of the rejected attribute."""
    tr = Tracer()
    with pytest.raises(RedactionError):
        tr.start_span("bad", "step", r=np.arange(8, dtype=np.int32))
    assert tr.spans() == []                  # rejected before the append
    s = tr.start_span("ok", "step", n=1)
    with pytest.raises(RedactionError):
        tr.annotate(s, leak=jnp.ones(3))
    assert s.attrs == {"n": 1}
    tr.end(s)


def test_profiled_kernel_records_only_when_concrete():
    from repro.kernels.limb_matmul.ops import field_matmul
    from repro.kernels.limb_matmul.ref import P
    tr = Tracer()                            # kernel_spans on by default
    x = jnp.asarray(np.random.default_rng(0).integers(
        0, P, (8, 8), dtype=np.int32))
    w = jnp.asarray(np.random.default_rng(1).integers(
        0, P, (8, 8), dtype=np.int32))
    with tr.span("request", "request"):
        field_matmul(x, w)
        jax.jit(lambda a, b: field_matmul(a, b))(x, w)  # traced: no span
    kernels = [s for s in tr.spans() if s.kind == "kernel"]
    assert [s.name for s in kernels] == ["kernel.limb_matmul"]
    assert kernels[0].attrs["shapes"] == [[8, 8], [8, 8]]
    assert kernels[0].t1 is not None
    # no ambient tracer: plain call, nothing recorded anywhere
    before = len(tr.spans())
    field_matmul(x, w)
    assert len(tr.spans()) == before


# -- the acceptance run: one traced request, mixed + verified + sharded ----

@pytest.fixture(scope="module")
def traced_run():
    """One engine request through a mixed blinded+enclave+verified-open
    plan (``bbevvooo`` — inexpressible as any legacy mode) with full
    Freivalds verification, row-sharded over 2 simulated devices with
    device 1 flipping bits — so the trace must cover queue -> batch ->
    session -> plan steps (all three regimes) -> shard dispatches
    (including the verify-failed attempt and its retry) -> verify ->
    unseal."""
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tracer = Tracer()                        # kernel spans on
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=20.0),
                           tracer=tracer)
    entry = engine.register_model(
        "vgg16", cfg, params,
        placement=PL.from_string(cfg, "bbevvooo",
                                 verify=IntegrityPolicy.full(1)),
        integrity=IntegrityPolicy.full(1),
        devices=DevicePool(2, faults={1: DishonestDevice(
            FaultSpec("bit_flip"))}),
        shard="rows")
    img = np.full((cfg.image_size, cfg.image_size, 3), SENTINEL,
                  np.float32)
    key = np.array([0xDEADBEEF, 0x12345678], dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, img, 7)
    resp = engine.submit("vgg16", Request(
        rid=7, box=box, shape=img.shape, session_key=key)).result(
        timeout=300)
    assert resp.ok, resp.error
    logits = PrivateInferenceServer.client_open(key, resp.box,
                                                (cfg.num_classes,))
    snap = engine.snapshot()
    issued = [np.frombuffer(kb, np.uint32).copy()
              for kb in entry.pool._issued]
    factors = entry.executor.cache.session_factors(
        jnp.asarray(issued[0])) if issued else []
    tele = entry.executor.telemetry_blinded
    tele_cut = {"blinded_bytes": tele.blinded_bytes,
                "offloaded_flops": tele.offloaded_flops}
    engine.close()
    return {"tracer": tracer, "snap": snap, "client_key": key,
            "img": img, "logits": logits, "issued": issued,
            "factors": factors, "resp": resp, "tele": tele_cut}


def test_span_tree_connected_and_complete(traced_run):
    tr = traced_run["tracer"]
    spans = tr.spans()
    roots = tr.roots()
    assert len(roots) == 1 and roots[0].name == "request"
    root = roots[0]
    # every span connects to the single request root (one trace, one tree)
    by_id = tr.by_id()
    for s in spans:
        cur = s
        hops = 0
        while cur.parent_id is not None:
            cur = by_id[cur.parent_id]
            hops += 1
            assert hops < 50
        assert cur.span_id == root.span_id, f"{s.name} detached from root"
        assert s.trace_id == root.trace_id
    # no dangling spans: everything closed by the time the future resolved
    assert [s.name for s in spans if s.t1 is None] == []
    names = {s.name for s in spans}
    required = {"request", "queue", "batch", "unseal", "session.acquire",
                "infer", "plan.segment", "op.blinded", "shard.matmul",
                "shard.dispatch", "verify", "seal",
                "kernel.limb_matmul", "kernel.fold"}
    assert required <= names, f"missing spans: {required - names}"
    # the dishonest device forces a failed attempt and a retry dispatch
    dispatches = [s for s in spans if s.name == "shard.dispatch"]
    outcomes = {s.attrs.get("outcome") for s in dispatches}
    attempts = {s.attrs.get("attempt") for s in dispatches}
    assert "verify_failed" in outcomes and "verified" in outcomes
    assert "retry" in attempts
    # both offload regimes are traced: blinded ops AND verified-open ops
    ops = [s for s in spans if s.name == "op.blinded"]
    assert any(s.attrs.get("verified_open") for s in ops)
    assert any(not s.attrs.get("verified_open") for s in ops)
    # parent/child sanity: timing nests inside the request root
    for s in spans:
        assert s.t0 >= root.t0 - 1e-6
        assert s.t1 <= (root.t1 or float("inf")) + 1e-6


def test_trace_exports_valid_chrome_json(traced_run, tmp_path):
    tr = traced_run["tracer"]
    out = tmp_path / "trace.json"
    n = tr.dump_chrome(out)
    doc = json.loads(out.read_text())
    ev = doc["traceEvents"]
    assert len(ev) == n
    xs = [e for e in ev if e["ph"] == "X"]
    assert len(xs) == len(tr.spans())
    for e in xs:
        assert e["dur"] >= 0 and e["ts"] >= 0
        assert {"trace_id", "span_id", "parent_id"} <= set(e["args"])
        assert e["cat"] in tracing.KINDS
    assert doc["otherData"]["dropped_spans"] == 0
    # JSONL export round-trips too
    outl = tmp_path / "trace.jsonl"
    assert tr.dump_jsonl(outl) == len(tr.spans())
    lines = [json.loads(ln) for ln in outl.read_text().splitlines()]
    assert {ln["name"] for ln in lines} == {s.name for s in tr.spans()}


def test_serialized_trace_carries_no_secret_material(traced_run, tmp_path):
    """Satellite 3: byte-scan the serialized trace for the run's actual
    secrets — blinding-factor field elements, session-key material (both
    the client sealing key and every pool-issued blinding key), and
    plaintext input / logit values (the input is sentinel-seeded so a leak
    cannot hide in noise)."""
    tr = traced_run["tracer"]
    chrome = json.dumps(tr.to_chrome())
    jsonl = "\n".join(json.dumps(s.as_dict()) for s in tr.spans())
    blob_text = chrome + "\n" + jsonl
    blob = blob_text.encode()

    # raw-byte forms (a binary smuggle would be a bug even in JSON)
    forbidden_bytes = [traced_run["client_key"].tobytes(),
                       traced_run["img"].tobytes()[:4096],
                       traced_run["logits"].tobytes()]
    for k in traced_run["issued"]:
        forbidden_bytes.append(k.tobytes())
    for e in traced_run["factors"]:
        r = e.get("r")
        if r is not None:
            forbidden_bytes.append(np.asarray(r).tobytes()[:4096])
    for fb in forbidden_bytes:
        assert fb not in blob

    # text forms (JSON serializes numbers as decimal text)
    forbidden_text = [f"{SENTINEL:.8f}"[:9]]          # plaintext input
    for k in traced_run["issued"] + [traced_run["client_key"]]:
        forbidden_text += [str(int(w)) for w in k if int(w) > 10 ** 6]
    for e in traced_run["factors"]:
        r = e.get("r")
        if r is not None:
            flat = np.asarray(r).ravel()[:64]
            forbidden_text += [str(int(v)) for v in flat
                               if int(v) > 10 ** 6][:16]
    for v in np.asarray(traced_run["logits"]).ravel():
        if abs(v) > 1e-3:
            forbidden_text.append(np.format_float_positional(
                v, precision=6, trim="-"))
    assert forbidden_text, "scan list unexpectedly empty"
    # delimiter-aware: a leaked value serializes as a standalone JSON
    # number/string token, while timestamp digit runs may contain any
    # short digit sequence as a substring — don't flake on those
    import re
    for ft in forbidden_text:
        pat = re.compile(rf"(?<![\d.]){re.escape(ft)}(?![\d.])")
        assert not pat.search(blob_text), \
            f"secret text {ft!r} leaked into trace"


def test_registry_agrees_with_legacy_surfaces(traced_run):
    """The consolidated registry must read back the same totals the legacy
    snapshot surfaces report — one accounting, two spellings."""
    snap = traced_run["snap"]
    metrics = snap["metrics"]
    c, g = metrics["counters"], metrics["gauges"]
    integ = snap["integrity"]
    assert c["integrity.verify_checks"] == integ["verify_checks"]
    assert c["shard.checks"] == integ["shard_checks"] > 0
    assert c["shard.failures"] == integ["shard_failures"] > 0
    assert c["shard.retries"] == integ["shard_retries"] > 0
    assert c["engine.submitted"] == snap["submitted"] == 1
    assert c["engine.completed"] == snap["completed"] == 1
    assert c["engine.batches"] == snap["batches"]
    # telemetry bridge: executor Telemetry == model.* gauges
    tele = traced_run["tele"]
    assert g["model.vgg16.telemetry.blinded_bytes"] == \
        tele["blinded_bytes"] > 0
    assert g["model.vgg16.telemetry.offloaded_flops"] == \
        tele["offloaded_flops"]
    # shard plane bridge: plane lifetime totals == model.*.shard gauges
    shard = snap["devices"]["vgg16"]["totals"]
    assert g["model.vgg16.shard.checks"] == shard["checks"]
    assert g["model.vgg16.shard.failures"] == shard["failures"]
    # latency histogram carries the one completed request
    assert metrics["histograms"]["engine.latency_s"]["count"] == 1


def test_device_and_watchdog_gauges_exported(traced_run):
    """Satellite 2: per-device breaker/quarantine state and the watchdog
    EWMAs are queryable as registry gauges (and still in the legacy
    snapshot)."""
    snap = traced_run["snap"]
    g = snap["metrics"]["gauges"]
    slots = snap["devices"]["vgg16"]["pool"]["slots"]
    for idx, slot in enumerate(slots):
        pre = f"device.vgg16.{idx}"
        assert g[f"{pre}.dispatches"] == slot["dispatches"]
        assert g[f"{pre}.quarantined"] == int(slot["quarantined"])
        assert g[f"{pre}.breaker_state"] in (0, 1, 2)
    # device 1 (the bit-flipper) was caught shard-locally; 0 stayed clean
    # (one request = one failed dispatch — below the quarantine threshold,
    # which the serve.py sharded drill exercises over a longer stream)
    assert g["device.vgg16.1.verify_failures"] >= 1
    assert g["device.vgg16.0.verify_failures"] == 0
    assert g["device.vgg16.0.quarantined"] == 0
    wd = snap["devices"]["vgg16"]["watchdog"]
    assert g["model.vgg16.shard.watchdog.p50_s"] == wd["p50_s"]
    assert g["model.vgg16.shard.watchdog.samples"] == wd["samples"]
    # the hard dispatch timeout has a cold fallback, so it is always a
    # number (the hedge deadline is None until the watchdog warms and is
    # then published too)
    assert g["model.vgg16.shard.watchdog.dispatch_timeout_s"] == \
        wd["dispatch_timeout_s"] > 0
    if wd["hedge_deadline_s"] is not None:
        assert g["model.vgg16.shard.watchdog.hedge_deadline_s"] == \
            wd["hedge_deadline_s"]
    assert "engine.watchdog.p50_s" in g


# -- bench metadata envelope ----------------------------------------------

def test_bench_meta_envelope(tmp_path):
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    try:
        from benchmarks import bench_meta
    finally:
        sys.path.pop(0)
    out = bench_meta.write_bench(tmp_path / "BENCH_x.json", "x",
                                 {"row": {"us": 1.0}}, config={"iters": 3})
    doc = json.loads(out.read_text())
    assert doc["meta"]["schema_version"] == bench_meta.SCHEMA_VERSION
    assert doc["meta"]["suite"] == "x"
    assert doc["meta"]["config"] == {"iters": 3}
    assert doc["meta"]["backend"] == jax.default_backend()
    assert doc["results"] == {"row": {"us": 1.0}}


# -- nearest-rank quantile helper (satellite: ONE rank-math impl) ----------

def test_nearest_rank_boundaries():
    from repro.runtime.observability import HIST_WINDOW, nearest_rank
    assert nearest_rank([], 0.5) == 0.0
    # n=1: the only sample answers every quantile
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        assert nearest_rank([42.0], q) == 42.0
    # exact ranks on a full window: ceil(q*n)-th order statistic
    vals = list(range(1, HIST_WINDOW + 1))          # sorted 1..4096
    assert nearest_rank(vals, 0.0) == 1             # clamped to min
    assert nearest_rank(vals, 0.50) == 2048
    assert nearest_rank(vals, 0.95) == 3892         # ceil(0.95*4096)
    assert nearest_rank(vals, 0.99) == 4056         # ceil(0.99*4096)
    assert nearest_rank(vals, 1.0) == 4096


def test_hist_window_wraps_and_quantiles_follow():
    """Past HIST_WINDOW samples the ring drops the OLDEST: quantiles are
    computed over the surviving window, not the full stream."""
    from repro.runtime.observability import HIST_WINDOW
    reg = MetricsRegistry()
    for v in range(HIST_WINDOW + 100):              # 0..4195, keeps 100..4195
        reg.observe("lat", float(v))
    vals = reg.hist_values("lat")
    assert len(vals) == HIST_WINDOW
    assert min(vals) == 100.0 and max(vals) == float(HIST_WINDOW + 99)
    h = reg.snapshot()["histograms"]["lat"]
    assert h["count"] == HIST_WINDOW
    assert h["p50"] == 100.0 + 2048 - 1             # rank math on the window
    assert h["p99"] == 100.0 + 4056 - 1
    assert h["mean"] == pytest.approx(sum(vals) / HIST_WINDOW)
    assert reg.quantile("lat", 1.0) == h["max"]


# -- bounded-tracer truncation markers (satellite 2) -----------------------

def test_tracer_truncation_markers(tmp_path):
    tr = Tracer(max_spans=3)
    for i in range(5):
        tr.end(tr.start_span(f"s{i}", parent=None))
    assert len(tr.spans()) == 3 and tr.dropped == 2
    doc = tr.to_chrome()
    assert doc["otherData"]["truncated"] is True
    assert doc["otherData"]["dropped_spans"] == 2
    lines = []
    p = tmp_path / "t.jsonl"
    assert tr.dump_jsonl(p) == 3
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert len(lines) == 4                          # 3 spans + marker
    assert lines[-1] == {"truncated": True, "dropped_spans": 2}
    # an unbounded-enough tracer emits NO marker anywhere
    tr2 = Tracer(max_spans=10)
    tr2.end(tr2.start_span("only", parent=None))
    assert tr2.to_chrome()["otherData"]["truncated"] is False
    tr2.dump_jsonl(p)
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert len(lines) == 1 and "truncated" not in lines[0]
